"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Roofline numbers (the dry-run
artifacts) are summarized from experiments/dryrun JSONs when present.
"""
from __future__ import annotations

import glob
import json
import os
import sys
import traceback

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import (bench_code_cache, bench_coldstart, bench_efficiency,
                        bench_isolate_scaling, bench_latency, bench_serving,
                        bench_startup, bench_trace)

MODULES = [
    ("fig1_startup", bench_startup),
    ("fig3_isolate_scaling", bench_isolate_scaling),
    ("fig4_code_cache", bench_code_cache),
    ("fig5_fig8_coldstart", bench_coldstart),
    ("fig6_efficiency", bench_efficiency),
    ("fig7_latency", bench_latency),
    ("fig9_fig10_trace", bench_trace),
    ("serving_density", bench_serving),
]


def roofline_rows() -> list:
    rows = []
    for path in sorted(glob.glob("experiments/dryrun/*.json")):
        d = json.load(open(path))
        if d.get("tag"):
            continue
        r = d["roofline"]
        rows.append({
            "name": f"roofline.{d['mesh']}.{d['arch']}.{d['shape']}",
            "us_per_call": r["t_bound"] * 1e6,
            "derived": (f"bottleneck={r['bottleneck']};"
                        f"t_c={r['t_compute_s']:.5f};"
                        f"t_m={r['t_memory_s']:.5f};"
                        f"t_n={r['t_collective_s']:.5f};"
                        f"useful={d['useful_flops_frac']:.3f};"
                        f"fit_gb={d['hbm_fit_bytes']/2**30:.2f}"),
        })
    return rows


def main() -> None:
    print("name,us_per_call,derived")
    failures = []
    for tag, mod in MODULES:
        try:
            for row in mod.run():
                print(f"{row['name']},{row['us_per_call']:.1f},"
                      f"{row['derived']}", flush=True)
        except Exception as e:
            failures.append((tag, repr(e)))
            traceback.print_exc(file=sys.stderr)
    for row in roofline_rows():
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    if failures:
        print(f"# {len(failures)} benchmark failures: {failures}",
              file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
