"""Fig 9 + Fig 10 analog: Azure trace replay — RSS-over-time and
end-to-end latency CDF for OpenWhisk / Photons / Hydra runtime models,
plus the HydraPlatform layer (``hydra-pool``: pre-warmed instance pool,
cross-tenant colocation, snapshot-based function install) and the
HydraCluster layer (``hydra-cluster``: cross-machine placement + spill,
snapshot transfer, adaptive per-node pools).

Two workloads:

  * the synthetic Shahrad-calibrated trace (``gen_trace``) — the
    paper-headline comparisons and the 1-8 node cluster sweep;
  * a real Azure Functions 2019-format trace (``--trace-file``; the
    tiny ``benchmarks/data/azure_sample.csv`` ships in-repo for CI) —
    replayed across ALL registered models at fleet pressure, with
    density (ops/GB-sec) ordering hydra-cluster >= hydra-pool >= hydra
    reported as ``trace.azure.density_ordering``.

``--calibration cal.json`` overrides the paper's startup/memory
constants with values measured on this host by
``bench_startup --emit-calibration`` (see ``repro.core.calibrate``).
``--live`` additionally replays the (thinned) trace through the REAL
gateway stack (``repro.gateway``) and reports live-vs-sim rows —
``trace.live.gateway`` / ``trace.live.sim`` / ``trace.live.vs_sim``
(see docs/benchmarks.md for the methodology); adding
``--calibrate-from-live`` closes the gateway -> calibration -> sim
round trip: the sim re-runs with costs measured from that very replay
and ``trace.live.calibrated_sim`` / ``trace.live.roundtrip`` report
whether it tracks live at least as tightly as the paper-constant sim.

  PYTHONPATH=src python benchmarks/bench_trace.py \\
      --trace-file benchmarks/data/azure_sample.csv \\
      --calibration benchmarks/data/calibration_example.json

Paper headlines to validate: Hydra cuts memory ~83% and p99 tail ~68% vs
OpenWhisk and beats Photons on both; the platform layer then eliminates
the remaining runtime cold starts (strictly fewer cold starts and lower
p99 than plain Hydra on the default trace); the cluster layer beats a
statically partitioned fleet of hydra-pool nodes on cold starts, fleet
p99, and ops/GB-sec at the same aggregate memory.

The cluster rows run under fleet pressure: the trace is the paper's
scaled-down Azure workload, so the per-runtime budget (192 MB) and fleet
memory (3 GB) are scaled to match — keeping instances-per-node and
pool churn at the paper's ratios instead of leaving a 16 GB fleet >90%
idle.
"""
from __future__ import annotations

import argparse
import math
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.calibrate import apply_calibration
from repro.core.tracesim import (GB, MB, MODELS, SimParams, Trace, compare,
                                 discover_azure_tables, gen_trace, simulate,
                                 simulate_partitioned)

# scaled-down fleet-pressure regime for the multi-node rows (see module
# docstring); the fleet total stays constant as the node count sweeps
FLEET_PARAMS = dict(runtime_cap=192 * MB, machine_cap=3 * GB)
NODE_SWEEP = (1, 2, 4, 8)

# azure-replay regime: same fleet pressure; the single-node fixed pool is
# sized for the fleet's peak warm capacity (pool_size = n_nodes *
# pool_max) while the cluster's EWMA policy floats between pool_min and
# pool_max per node — the ROADMAP's adaptive-vs-fixed-at-equal-peak
# methodology
AZURE_PARAMS = dict(runtime_cap=192 * MB, machine_cap=3 * GB, n_nodes=4,
                    pool_size=8, pool_min=1, pool_max=2)

DATA_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "data")
AZURE_SAMPLE = os.path.join(DATA_DIR, "azure_sample.csv")


def load_trace_file(path: str, durations: str = None, memory: str = None,
                    target_rps: float = None, max_minutes: int = None,
                    seed: int = 0, stream: bool = False,
                    top_k: int = None, select: str = "top"):
    """Load an Azure-format trace; sibling ``<stem>_durations.csv`` /
    ``<stem>_memory.csv`` tables are auto-discovered when not given.
    ``stream=True`` returns the lazily-expanded ``StreamingTrace``
    (identical invocations, bounded memory — required for ``top_k``
    selection); the default materializes a ``Trace``."""
    found = discover_azure_tables(path)
    durations = durations or found.get("durations_csv")
    memory = memory or found.get("memory_csv")
    if stream or top_k is not None:
        return Trace.stream_azure(path, durations_csv=durations,
                                  memory_csv=memory, target_rps=target_rps,
                                  max_minutes=max_minutes, seed=seed,
                                  top_k=top_k, select=select)
    return Trace.from_azure(path, durations_csv=durations,
                            memory_csv=memory, target_rps=target_rps,
                            max_minutes=max_minutes, seed=seed)


def azure_rows(trace, params: SimParams, models=None) -> list:
    """Replay an Azure-format trace (materialized or streaming) across
    ``models`` (default: all)."""
    res = compare(trace, params, models=models)
    d = trace.describe()
    rows = [{
        "name": "trace.azure.workload",
        "us_per_call": 0.0,
        "derived": (f"invocations={d['invocations']};"
                    f"fns={d['functions']};tenants={d['tenants']};"
                    f"rps={d['mean_rps']:.2f};"
                    f"thinning_keep={d.get('thinning_keep', 1.0):.3f}"),
    }]
    for model, s in res.items():
        rows.append({
            "name": f"trace.azure.{model}",
            "us_per_call": s["p99_s"] * 1e6,
            "derived": (f"requests={s['requests']};"
                        f"ops_per_gb_s={s['ops_per_gb_s']:.3f};"
                        f"mean_mem_mb={s['mean_mem_mb']:.0f};"
                        f"cold_rt={s['cold_runtime']};"
                        f"pool_claims={s['pool_claims']};"
                        f"transfers={s['transfers']};"
                        f"dropped={s['dropped']}"),
        })
    if all(m in res for m in ("hydra", "hydra-pool", "hydra-cluster")):
        hy, hp, hc = (res[m]["ops_per_gb_s"]
                      for m in ("hydra", "hydra-pool", "hydra-cluster"))
        rows.append({
            "name": "trace.azure.density_ordering",
            "us_per_call": 0.0,
            "derived": (f"cluster={hc:.3f}>=pool={hp:.3f}>=hydra={hy:.3f};"
                        f"holds={hc >= hp >= hy}"),
        })
    return rows


def synthetic_rows() -> list:
    trace = gen_trace()
    res = compare(trace)
    rows = []
    for model, s in res.items():
        rows.append({
            "name": f"trace.{model}",
            "us_per_call": s["p99_s"] * 1e6,
            "derived": (f"mean_mem_mb={s['mean_mem_mb']:.0f};"
                        f"peak_mem_mb={s['peak_mem_mb']:.0f};"
                        f"overhead_p99_ms={s['overhead_p99_ms']:.1f};"
                        f"runtimes={s['mean_runtimes']:.1f};"
                        f"cold_rt={s['cold_runtime']};"
                        f"pool_claims={s['pool_claims']};"
                        f"dropped={s['dropped']}"),
        })
    ow, ph = res["openwhisk"], res["photons"]
    hy, hp = res["hydra"], res["hydra-pool"]
    rows.append({
        "name": "trace.hydra_vs_openwhisk",
        "us_per_call": 0.0,
        "derived": (f"mem_reduction={100*(1-hy['mean_mem_mb']/ow['mean_mem_mb']):.0f}%;"
                    f"ovh_p99_reduction="
                    f"{100*(1-hy['overhead_p99_ms']/ow['overhead_p99_ms']):.0f}%"),
    })
    rows.append({
        "name": "trace.hydra_vs_photons",
        "us_per_call": 0.0,
        "derived": (f"mem_reduction={100*(1-hy['mean_mem_mb']/ph['mean_mem_mb']):.0f}%;"
                    f"ovh_p99_reduction="
                    f"{100*(1-hy['overhead_p99_ms']/ph['overhead_p99_ms']):.0f}%"),
    })
    rows.append({
        "name": "trace.pool_vs_hydra",
        "us_per_call": 0.0,
        "derived": (f"cold_rt={hp['cold_runtime']}_vs_{hy['cold_runtime']};"
                    f"p99_delta_ms={1e3*(hy['p99_s']-hp['p99_s']):.1f};"
                    f"mem_reduction="
                    f"{100*(1-hp['mean_mem_mb']/hy['mean_mem_mb']):.0f}%"),
    })

    # ---- cluster: 1 -> 8 node sweep at constant fleet memory ----
    sweep = {}
    for n in NODE_SWEEP:
        p = SimParams(n_nodes=n, **FLEET_PARAMS)
        s = simulate(trace, "hydra-cluster", p).summary()
        sweep[n] = s
        rows.append({
            "name": f"trace.cluster_{n}node",
            "us_per_call": s["p99_s"] * 1e6,
            "derived": (f"cold_rt={s['cold_runtime']};"
                        f"ops_per_gb_s={s['ops_per_gb_s']:.2f};"
                        f"mean_mem_mb={s['mean_mem_mb']:.0f};"
                        f"mean_pool_mb={s['mean_pool_mem_mb']:.0f};"
                        f"transfers={s['transfers']};"
                        f"dropped={s['dropped']}"),
        })

    # ---- cluster vs 4 statically partitioned hydra-pool nodes ----
    p4 = SimParams(n_nodes=4, **FLEET_PARAMS)
    cl = sweep[4]
    st = simulate_partitioned(trace, 4, p4).summary()
    fx = simulate(trace, "hydra-cluster",
                  SimParams(n_nodes=4, adaptive_pool=False,
                            **FLEET_PARAMS)).summary()
    rows.append({
        "name": "trace.cluster_vs_static4",
        "us_per_call": 0.0,
        "derived": (f"cold_rt={cl['cold_runtime']}_vs_{st['cold_runtime']};"
                    f"p99_delta_ms={1e3*(st['p99_s']-cl['p99_s']):.1f};"
                    f"ops_gain="
                    f"{cl['ops_per_gb_s']/st['ops_per_gb_s']:.2f}x"),
    })
    rows.append({
        "name": "trace.adaptive_vs_fixed_pool",
        "us_per_call": 0.0,
        "derived": (f"mean_pool_mb={cl['mean_pool_mem_mb']:.0f}"
                    f"_vs_{fx['mean_pool_mem_mb']:.0f};"
                    f"peak_pool_mb={cl['peak_pool_mem_mb']:.0f}"
                    f"_vs_{fx['peak_pool_mem_mb']:.0f};"
                    f"cold_rt={cl['cold_runtime']}_vs_{fx['cold_runtime']}"),
    })
    return rows


def live_rows(trace_file: str = AZURE_SAMPLE, compress: float = 120.0,
              target_rps: float = 2.0, max_minutes: int = 10,
              pool_size: int = 4, seed: int = 0,
              calibrate_from_live: bool = False,
              calibration_out: str = None) -> list:
    """Live-vs-sim section: replay one thinned trace through the REAL
    gateway stack (``repro.gateway``) and the simulator, and report both
    plus their deltas — the wall-clock counterpart of every simulated
    row above. The cold-start and p99 deltas are the metrics
    ``gateway/validate.py`` enforces in CI.

    ``calibrate_from_live`` closes the round trip: the live replay's
    CalibrationProbe payload becomes a ``hydra-calibration/v1`` overlay,
    the sim re-runs with it, and a ``trace.live.calibrated_sim`` /
    ``trace.live.roundtrip`` row pair reports whether the calibrated sim
    tracks live at least as tightly as the uncalibrated one
    (``calibration_out`` optionally persists the derived JSON for later
    ``--calibration`` runs)."""
    from repro.gateway import load_trace, run_validation

    trace = load_trace(trace_file, target_rps=target_rps,
                       max_minutes=max_minutes, seed=seed)
    report = run_validation(trace, compress=compress, pool_size=pool_size,
                            round_trip=calibrate_from_live)
    live, sim = report["live"], report["sim"]
    tol = report["tolerance"]
    rows = []
    for name, s in (("trace.live.gateway", live), ("trace.live.sim", sim)):
        rows.append({
            "name": name,
            "us_per_call": s["p99_s"] * 1e6,
            "derived": (f"requests={s['requests']};"
                        f"cold_rt={s['cold_runtime']};"
                        f"pool_claims={s['pool_claims']};"
                        f"mean_mem_mb={s['mean_mem_mb']:.0f};"
                        f"dropped={s['dropped']}"),
        })
    rows.append({
        "name": "trace.live.vs_sim",
        "us_per_call": 0.0,
        "derived": (f"cold_rt={tol['cold_live']}_vs_{tol['cold_sim']};"
                    f"cold_tolerance={tol['limit']:.1f};"
                    f"cold_within_tolerance={tol['passed']};"
                    f"p99_delta_s={live['p99_s'] - sim['p99_s']:.3f};"
                    f"compress={compress:g}"),
    })
    if calibrate_from_live and "round_trip" not in report:
        # derivation failed (probe measured nothing): say so loudly and
        # emit a non-finite roundtrip row so validate_rows turns the
        # missing requested artifact into a non-zero exit, not a silent
        # green run
        msg = "; ".join(report.get("failures", [])) \
            or "calibration unavailable"
        print(f"# bench_trace: round trip unavailable: {msg}",
              file=sys.stderr)
        rows.append({
            "name": "trace.live.roundtrip",
            "us_per_call": float("nan"),
            "derived": "calibrated_at_least_as_close=False",
        })
    elif calibrate_from_live:
        cal = report["calibrated_sim"]
        rt = report["round_trip"]
        rows.append({
            "name": "trace.live.calibrated_sim",
            "us_per_call": cal["p99_s"] * 1e6,
            "derived": (f"requests={cal['requests']};"
                        f"cold_rt={cal['cold_runtime']};"
                        f"pool_claims={cal['pool_claims']};"
                        f"mean_mem_mb={cal['mean_mem_mb']:.0f};"
                        f"dropped={cal['dropped']}"),
        })
        rows.append({
            "name": "trace.live.roundtrip",
            "us_per_call": 0.0,
            "derived": (
                f"cold_cal_delta={rt['cold_runtime']['cal_delta']};"
                f"cold_uncal_delta={rt['cold_runtime']['uncal_delta']};"
                f"p99_cal_delta_s={rt['p99_s']['cal_delta']:.3f};"
                f"p99_uncal_delta_s={rt['p99_s']['uncal_delta']:.3f};"
                f"calibrated_at_least_as_close={rt['passed']}"),
        })
        if calibration_out and "calibration" in report:
            from repro.core.calibrate import write_calibration_doc
            write_calibration_doc(calibration_out, report["calibration"])
    return rows


def azure_section(trace_file: str, calibration: str = None,
                  durations: str = None, memory: str = None,
                  target_rps: float = None, max_minutes: int = None,
                  seed: int = 0, models=None, stream: bool = False,
                  top_k: int = None, select: str = "top") -> list:
    """One azure-replay section: fleet-pressure params (optionally
    calibrated), trace load, rows — shared by run() and the CLI."""
    params = SimParams(**AZURE_PARAMS)
    if calibration:
        params = apply_calibration(params, calibration)
    trace = load_trace_file(trace_file, durations=durations, memory=memory,
                            target_rps=target_rps, max_minutes=max_minutes,
                            seed=seed, stream=stream, top_k=top_k,
                            select=select)
    return azure_rows(trace, params, models=models)


def run(trace_file: str = AZURE_SAMPLE, calibration: str = None) -> list:
    """Driver entry point (benchmarks/run.py): synthetic sections plus —
    when the bundled sample (or ``trace_file``) exists — the azure-replay
    section."""
    rows = synthetic_rows()
    if trace_file and os.path.exists(trace_file):
        rows += azure_section(trace_file, calibration)
    return rows


def validate_rows(rows: list) -> list:
    """Sanity gate for CI (sim-smoke): NaN metrics or a replay that
    served zero invocations are failures, not output."""
    errors = []
    if not rows:
        return ["no benchmark rows produced"]
    for row in rows:
        if not math.isfinite(row["us_per_call"]):
            errors.append(f"{row['name']}: non-finite us_per_call")
        for pair in row["derived"].split(";"):
            key, _, val = pair.partition("=")
            if any(tok in ("nan", "-nan", "inf", "-inf")
                   for tok in val.lower().split("_")):
                errors.append(f"{row['name']}: non-finite {key}={val}")
            if key in ("requests", "invocations") and val == "0":
                errors.append(f"{row['name']}: zero invocations replayed")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace-file", default=AZURE_SAMPLE,
                    help="Azure Functions 2019-format invocations CSV "
                         "(default: the bundled sample)")
    ap.add_argument("--durations", default=None,
                    help="durations percentile CSV (default: "
                         "<trace>_durations.csv when present)")
    ap.add_argument("--memory", default=None,
                    help="app memory percentile CSV (default: "
                         "<trace>_memory.csv when present)")
    ap.add_argument("--calibration", default=None,
                    help="hydra-calibration/v1 JSON from bench_startup "
                         "--emit-calibration")
    ap.add_argument("--target-rps", type=float, default=None,
                    help="deterministically thin the trace to this mean "
                         "rps (seeded binomial per function-minute)")
    ap.add_argument("--max-minutes", type=int, default=None,
                    help="replay only the first N minutes of the trace")
    ap.add_argument("--seed", type=int, default=0,
                    help="thinning/expansion seed")
    ap.add_argument("--stream", action="store_true",
                    help="replay through the chunked streaming loader "
                         "(bounded memory; byte-identical invocations)")
    ap.add_argument("--top-k", type=int, default=None,
                    help="keep only K function rows of the trace "
                         "(implies --stream; see --select)")
    ap.add_argument("--select", default="top", choices=("top", "stratified"),
                    help="top-K policy: the K busiest rows, or one "
                         "seeded pick per popularity stratum")
    ap.add_argument("--emit-bench", default=None, metavar="PATH",
                    help="also write the schema-versioned "
                         "BENCH_trace.json artifact here (validated "
                         "against the hydra-bench/v1 schema first; see "
                         "benchmarks/bench_artifact.py)")
    ap.add_argument("--models", default=None,
                    help=f"comma-separated subset of {list(MODELS)}")
    ap.add_argument("--synthetic", action="store_true",
                    help="also run the synthetic-trace sections")
    ap.add_argument("--live", action="store_true",
                    help="also replay the (thinned) trace through the "
                         "REAL gateway stack and report live-vs-sim "
                         "deltas (see repro.gateway)")
    ap.add_argument("--live-compress", type=float, default=None,
                    help="wall-clock compression for the --live replay "
                         "(default 120)")
    ap.add_argument("--calibrate-from-live", action="store_true",
                    help="with --live: derive a calibration from the "
                         "live replay itself, re-simulate with it, and "
                         "report trace.live.calibrated_sim / "
                         "trace.live.roundtrip rows (the gateway -> "
                         "calibration -> sim loop)")
    ap.add_argument("--calibration-out", default=None, metavar="PATH",
                    help="with --calibrate-from-live: also write the "
                         "derived hydra-calibration/v1 JSON here")
    args = ap.parse_args(argv)

    if args.calibrate_from_live and not args.live:
        print("bench_trace: --calibrate-from-live requires --live",
              file=sys.stderr)
        return 2
    if args.live_compress is not None and not args.live:
        print("bench_trace: --live-compress requires --live",
              file=sys.stderr)
        return 2
    if args.calibration_out and not args.calibrate_from_live:
        print("bench_trace: --calibration-out requires "
              "--calibrate-from-live", file=sys.stderr)
        return 2

    if args.select != "top" and args.top_k is None:
        print("bench_trace: --select requires --top-k", file=sys.stderr)
        return 2
    if not os.path.isfile(args.trace_file):
        print(f"bench_trace: trace file not found: {args.trace_file}",
              file=sys.stderr)
        return 2
    if not os.access(args.trace_file, os.R_OK):
        print(f"bench_trace: trace file not readable: {args.trace_file}",
              file=sys.stderr)
        return 2

    try:
        rows = azure_section(
            args.trace_file, calibration=args.calibration,
            durations=args.durations, memory=args.memory,
            target_rps=args.target_rps, max_minutes=args.max_minutes,
            seed=args.seed,
            models=args.models.split(",") if args.models else None,
            stream=args.stream, top_k=args.top_k, select=args.select)
    except ValueError as e:
        # unusable trace/window (empty expansion, malformed schema,
        # no minutes in range): a clean diagnostic, not a traceback
        print(f"bench_trace: {e}", file=sys.stderr)
        return 2
    if args.synthetic:
        rows += synthetic_rows()
    if args.live:
        rows += live_rows(args.trace_file,
                          compress=args.live_compress or 120.0,
                          target_rps=args.target_rps or 2.0,
                          max_minutes=args.max_minutes or 10,
                          seed=args.seed,
                          calibrate_from_live=args.calibrate_from_live,
                          calibration_out=args.calibration_out)

    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    errors = validate_rows(rows)

    if args.emit_bench:
        from benchmarks.bench_artifact import (build_artifact,
                                               validate_artifact,
                                               write_artifact)
        try:
            doc = build_artifact(args.trace_file,
                                 calibration=args.calibration,
                                 target_rps=args.target_rps,
                                 max_minutes=args.max_minutes,
                                 seed=args.seed, top_k=args.top_k,
                                 select=args.select)
        except ValueError as e:
            print(f"bench_trace: --emit-bench: {e}", file=sys.stderr)
            return 2
        bench_errors = validate_artifact(doc)
        if bench_errors:
            # an artifact that fails its own schema is never written
            errors += [f"emit-bench: {e}" for e in bench_errors]
        else:
            write_artifact(doc, args.emit_bench)

    for e in errors:
        print(f"# FAIL {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
