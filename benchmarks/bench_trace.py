"""Fig 9 + Fig 10 analog: Azure-like trace replay — RSS-over-time and
end-to-end latency CDF for OpenWhisk / Photons / Hydra runtime models.

Paper headline to validate: Hydra cuts memory ~83% and p99 tail ~68% vs
OpenWhisk, and beats Photons on both (memory via multi-function
consolidation, tail via fewer cold starts).
"""
from __future__ import annotations

from repro.core.tracesim import SimParams, compare, gen_trace


def run() -> list:
    trace = gen_trace(n_functions=200, n_tenants=20, duration_s=600,
                      mean_rps=10.0, seed=0)
    params = SimParams(keepalive_s=600.0)
    res = compare(trace, params)
    rows = []
    for model, s in res.items():
        rows.append({
            "name": f"trace.{model}",
            "us_per_call": s["p99_s"] * 1e6,
            "derived": (f"mean_mem_mb={s['mean_mem_mb']:.0f};"
                        f"peak_mem_mb={s['peak_mem_mb']:.0f};"
                        f"overhead_p99_ms={s['overhead_p99_ms']:.1f};"
                        f"runtimes={s['mean_runtimes']:.1f};"
                        f"cold_rt={s['cold_runtime']};"
                        f"dropped={s['dropped']}"),
        })
    ow, hy = res["openwhisk"], res["hydra"]
    ph = res["photons"]
    rows.append({
        "name": "trace.hydra_vs_openwhisk",
        "us_per_call": 0.0,
        "derived": (f"mem_reduction={100*(1-hy['mean_mem_mb']/ow['mean_mem_mb']):.0f}%;"
                    f"ovh_p99_reduction="
                    f"{100*(1-hy['overhead_p99_ms']/ow['overhead_p99_ms']):.0f}%"),
    })
    rows.append({
        "name": "trace.hydra_vs_photons",
        "us_per_call": 0.0,
        "derived": (f"mem_reduction={100*(1-hy['mean_mem_mb']/ph['mean_mem_mb']):.0f}%;"
                    f"ovh_p99_reduction="
                    f"{100*(1-hy['overhead_p99_ms']/ph['overhead_p99_ms']):.0f}%"),
    })
    return rows
