"""Fig 9 + Fig 10 analog: Azure-like trace replay — RSS-over-time and
end-to-end latency CDF for OpenWhisk / Photons / Hydra runtime models,
plus the HydraPlatform layer (``hydra-pool``: pre-warmed instance pool,
cross-tenant colocation, snapshot-based function install) and the
HydraCluster layer (``hydra-cluster``: cross-machine placement + spill,
snapshot transfer, adaptive per-node pools).

Paper headlines to validate: Hydra cuts memory ~83% and p99 tail ~68% vs
OpenWhisk and beats Photons on both; the platform layer then eliminates
the remaining runtime cold starts (strictly fewer cold starts and lower
p99 than plain Hydra on the default trace); the cluster layer beats a
statically partitioned fleet of hydra-pool nodes on cold starts, fleet
p99, and ops/GB-sec at the same aggregate memory.

The cluster rows run under fleet pressure: the trace is the paper's
scaled-down Azure workload, so the per-runtime budget (192 MB) and fleet
memory (3 GB) are scaled to match — keeping instances-per-node and
pool churn at the paper's ratios instead of leaving a 16 GB fleet >90%
idle.
"""
from __future__ import annotations

from repro.core.tracesim import (MB, GB, SimParams, compare, gen_trace,
                                 simulate, simulate_partitioned)

# scaled-down fleet-pressure regime for the multi-node rows (see module
# docstring); the fleet total stays constant as the node count sweeps
FLEET_PARAMS = dict(runtime_cap=192 * MB, machine_cap=3 * GB)
NODE_SWEEP = (1, 2, 4, 8)


def run() -> list:
    trace = gen_trace()
    res = compare(trace)
    rows = []
    for model, s in res.items():
        rows.append({
            "name": f"trace.{model}",
            "us_per_call": s["p99_s"] * 1e6,
            "derived": (f"mean_mem_mb={s['mean_mem_mb']:.0f};"
                        f"peak_mem_mb={s['peak_mem_mb']:.0f};"
                        f"overhead_p99_ms={s['overhead_p99_ms']:.1f};"
                        f"runtimes={s['mean_runtimes']:.1f};"
                        f"cold_rt={s['cold_runtime']};"
                        f"pool_claims={s['pool_claims']};"
                        f"dropped={s['dropped']}"),
        })
    ow, ph = res["openwhisk"], res["photons"]
    hy, hp = res["hydra"], res["hydra-pool"]
    rows.append({
        "name": "trace.hydra_vs_openwhisk",
        "us_per_call": 0.0,
        "derived": (f"mem_reduction={100*(1-hy['mean_mem_mb']/ow['mean_mem_mb']):.0f}%;"
                    f"ovh_p99_reduction="
                    f"{100*(1-hy['overhead_p99_ms']/ow['overhead_p99_ms']):.0f}%"),
    })
    rows.append({
        "name": "trace.hydra_vs_photons",
        "us_per_call": 0.0,
        "derived": (f"mem_reduction={100*(1-hy['mean_mem_mb']/ph['mean_mem_mb']):.0f}%;"
                    f"ovh_p99_reduction="
                    f"{100*(1-hy['overhead_p99_ms']/ph['overhead_p99_ms']):.0f}%"),
    })
    rows.append({
        "name": "trace.pool_vs_hydra",
        "us_per_call": 0.0,
        "derived": (f"cold_rt={hp['cold_runtime']}_vs_{hy['cold_runtime']};"
                    f"p99_delta_ms={1e3*(hy['p99_s']-hp['p99_s']):.1f};"
                    f"mem_reduction="
                    f"{100*(1-hp['mean_mem_mb']/hy['mean_mem_mb']):.0f}%"),
    })

    # ---- cluster: 1 -> 8 node sweep at constant fleet memory ----
    sweep = {}
    for n in NODE_SWEEP:
        p = SimParams(n_nodes=n, **FLEET_PARAMS)
        s = simulate(trace, "hydra-cluster", p).summary()
        sweep[n] = s
        rows.append({
            "name": f"trace.cluster_{n}node",
            "us_per_call": s["p99_s"] * 1e6,
            "derived": (f"cold_rt={s['cold_runtime']};"
                        f"ops_per_gb_s={s['ops_per_gb_s']:.2f};"
                        f"mean_mem_mb={s['mean_mem_mb']:.0f};"
                        f"mean_pool_mb={s['mean_pool_mem_mb']:.0f};"
                        f"transfers={s['transfers']};"
                        f"dropped={s['dropped']}"),
        })

    # ---- cluster vs 4 statically partitioned hydra-pool nodes ----
    p4 = SimParams(n_nodes=4, **FLEET_PARAMS)
    cl = sweep[4]
    st = simulate_partitioned(trace, 4, p4).summary()
    fx = simulate(trace, "hydra-cluster",
                  SimParams(n_nodes=4, adaptive_pool=False,
                            **FLEET_PARAMS)).summary()
    rows.append({
        "name": "trace.cluster_vs_static4",
        "us_per_call": 0.0,
        "derived": (f"cold_rt={cl['cold_runtime']}_vs_{st['cold_runtime']};"
                    f"p99_delta_ms={1e3*(st['p99_s']-cl['p99_s']):.1f};"
                    f"ops_gain="
                    f"{cl['ops_per_gb_s']/st['ops_per_gb_s']:.2f}x"),
    })
    rows.append({
        "name": "trace.adaptive_vs_fixed_pool",
        "us_per_call": 0.0,
        "derived": (f"mean_pool_mb={cl['mean_pool_mem_mb']:.0f}"
                    f"_vs_{fx['mean_pool_mem_mb']:.0f};"
                    f"peak_pool_mb={cl['peak_pool_mem_mb']:.0f}"
                    f"_vs_{fx['peak_pool_mem_mb']:.0f};"
                    f"cold_rt={cl['cold_runtime']}_vs_{fx['cold_runtime']}"),
    })
    return rows
