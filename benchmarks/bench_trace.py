"""Fig 9 + Fig 10 analog: Azure-like trace replay — RSS-over-time and
end-to-end latency CDF for OpenWhisk / Photons / Hydra runtime models,
plus the HydraPlatform layer (``hydra-pool``: pre-warmed instance pool,
cross-tenant colocation, snapshot-based function install).

Paper headlines to validate: Hydra cuts memory ~83% and p99 tail ~68% vs
OpenWhisk and beats Photons on both; the platform layer then eliminates
the remaining runtime cold starts (strictly fewer cold starts and lower
p99 than plain Hydra on the default trace).
"""
from __future__ import annotations

from repro.core.tracesim import compare, gen_trace


def run() -> list:
    trace = gen_trace()
    res = compare(trace)
    rows = []
    for model, s in res.items():
        rows.append({
            "name": f"trace.{model}",
            "us_per_call": s["p99_s"] * 1e6,
            "derived": (f"mean_mem_mb={s['mean_mem_mb']:.0f};"
                        f"peak_mem_mb={s['peak_mem_mb']:.0f};"
                        f"overhead_p99_ms={s['overhead_p99_ms']:.1f};"
                        f"runtimes={s['mean_runtimes']:.1f};"
                        f"cold_rt={s['cold_runtime']};"
                        f"pool_claims={s['pool_claims']};"
                        f"dropped={s['dropped']}"),
        })
    ow, ph = res["openwhisk"], res["photons"]
    hy, hp = res["hydra"], res["hydra-pool"]
    rows.append({
        "name": "trace.hydra_vs_openwhisk",
        "us_per_call": 0.0,
        "derived": (f"mem_reduction={100*(1-hy['mean_mem_mb']/ow['mean_mem_mb']):.0f}%;"
                    f"ovh_p99_reduction="
                    f"{100*(1-hy['overhead_p99_ms']/ow['overhead_p99_ms']):.0f}%"),
    })
    rows.append({
        "name": "trace.hydra_vs_photons",
        "us_per_call": 0.0,
        "derived": (f"mem_reduction={100*(1-hy['mean_mem_mb']/ph['mean_mem_mb']):.0f}%;"
                    f"ovh_p99_reduction="
                    f"{100*(1-hy['overhead_p99_ms']/ph['overhead_p99_ms']):.0f}%"),
    })
    rows.append({
        "name": "trace.pool_vs_hydra",
        "us_per_call": 0.0,
        "derived": (f"cold_rt={hp['cold_runtime']}_vs_{hy['cold_runtime']};"
                    f"p99_delta_ms={1e3*(hy['p99_s']-hp['p99_s']):.1f};"
                    f"mem_reduction="
                    f"{100*(1-hp['mean_mem_mb']/hy['mean_mem_mb']):.0f}%"),
    })
    return rows
