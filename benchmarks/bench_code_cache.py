"""Fig 4 analog: executable-cache (JIT code cache) sharing ON vs OFF.

Registering N tenants of the same function family with a shared cache
compiles once; the unshared baseline (per-context JIT) compiles N times —
the paper's memory/alloc-time/warm-up effect, here measured as compile
work and registration latency.
"""
from __future__ import annotations

import time

from benchmarks.functions import catalog, example_args
from repro.core import ExecutableCache, HydraRuntime

N_TENANTS = 6


def _run_mode(shared: bool) -> dict:
    rt = HydraRuntime(executable_cache=ExecutableCache(shared=shared),
                      janitor=False)
    spec = catalog()["py/thumbnail"]
    reg_times = []
    for t in range(N_TENANTS):
        t0 = time.perf_counter()
        rt.register_function(f"t{t}/thumb", spec, tenant=f"t{t}")
        reg_times.append(time.perf_counter() - t0)
    # first-invoke latency for the LAST tenant (warm-up elimination)
    t0 = time.perf_counter()
    rt.invoke(f"t{N_TENANTS-1}/thumb", example_args(spec))
    first_invoke = time.perf_counter() - t0
    stats = rt.exe_cache.stats()
    rt.shutdown()
    return {"reg_total_s": sum(reg_times), "reg_last_s": reg_times[-1],
            "first_invoke_s": first_invoke,
            "compiles": stats["entries"],
            "compile_s": stats["total_compile_s"]}


def run() -> list:
    shared = _run_mode(True)
    unshared = _run_mode(False)
    return [
        {"name": "code_cache.shared_reg_total",
         "us_per_call": shared["reg_total_s"] * 1e6,
         "derived": f"compiles={shared['compiles']}"},
        {"name": "code_cache.unshared_reg_total",
         "us_per_call": unshared["reg_total_s"] * 1e6,
         "derived": f"compiles={unshared['compiles']};"
                    f"compile_work_x={unshared['compile_s']/max(shared['compile_s'],1e-9):.1f}"},
        {"name": "code_cache.shared_last_reg",
         "us_per_call": shared["reg_last_s"] * 1e6,
         "derived": f"vs_unshared={unshared['reg_last_s']/max(shared['reg_last_s'],1e-9):.1f}x"},
        {"name": "code_cache.shared_first_invoke",
         "us_per_call": shared["first_invoke_s"] * 1e6,
         "derived": "warm_code_cache"},
    ]
