"""Fig 7 analog: function invocation latency — Hydra runtime path vs a bare
jitted call (the "native runtime" bound). The virtualization layer should
add only queue/arena overhead (paper: Graalvisor within ~22% of native)."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.functions import catalog, example_args
from repro.core import HydraRuntime

REPS = 20


def run() -> list:
    rows = []
    specs = catalog()
    rt = HydraRuntime(janitor=False)
    for name, spec in specs.items():
        args = example_args(spec)
        rt.register_function(name, spec)
        rt.invoke(name, args)                       # warm
        lat = []
        for _ in range(REPS):
            t0 = time.perf_counter()
            rt.invoke(name, args)
            lat.append(time.perf_counter() - t0)
        # native bound: direct pre-compiled call
        fn = jax.jit(spec.fn)
        jax.block_until_ready(fn(spec.params, args))
        nat = []
        for _ in range(REPS):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(spec.params, args))
            nat.append(time.perf_counter() - t0)
        hyd, nav = float(np.median(lat)), float(np.median(nat))
        rows.append({"name": f"latency.{name.replace('/', '_')}",
                     "us_per_call": hyd * 1e6,
                     "derived": f"native_us={nav*1e6:.0f};"
                                f"overhead={100*(hyd-nav)/max(nav,1e-9):.0f}%"})
    rt.shutdown()
    return rows
