"""CI-tracked benchmark artifact: the trace-replay trajectory as one
schema-versioned JSON document.

``bench_trace`` prints rows for humans; this module emits (and checks)
``BENCH_trace.json`` — the committed, machine-diffable record of the
reproduction's headline numbers: per-model density (ops/GB-s), p50/p99,
cold starts, and mean/peak memory from the full streaming replay of the
bundled Azure sample, plus trace provenance (file digest, thinning,
selection), the streaming loader's peak buffered invocations, an
optional live gateway smoke leg, and the git SHA that produced it.

The CI ``bench-artifact`` job regenerates the document on every PR and
fails on **schema drift** (the committed and regenerated documents must
have the same key structure — a metric silently disappearing is a
regression of the artifact contract) or a **density-ordering
regression** (the paper's ``hydra-cluster >= hydra-pool >= hydra``
ordering must keep holding). Metric *values* are expected to move as the
models evolve — that moving history, committed PR over PR, is the
trajectory, comparable against the paper's Fig 9/10 shapes.

CLI::

    PYTHONPATH=src python benchmarks/bench_artifact.py \\
        --out BENCH_trace.json --gateway-smoke \\
        --check-against BENCH_trace.json
"""
from __future__ import annotations

import argparse
import hashlib
import json
import math
import os
import subprocess
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.bench_trace import AZURE_PARAMS, AZURE_SAMPLE
from repro.core.calibrate import apply_calibration
from repro.core.tracing import SUMMARY_KEYS
from repro.core.tracesim import (MODELS, SimParams, Trace,
                                 discover_azure_tables, simulate)

SCHEMA = "hydra-bench/v2"
DENSITY_ORDER = ("hydra-cluster", "hydra-pool", "hydra")
# per-model metrics carried into the artifact (summary-schema keys)
MODEL_KEYS = ("requests", "p50_s", "p99_s", "cold_runtime", "cold_isolate",
              "warm_isolate", "mean_mem_mb", "peak_mem_mb", "mean_runtimes",
              "pool_claims", "transfers", "dropped", "ops_per_gb_s")
# counters may legitimately be zero; these must be finite AND positive
POSITIVE_KEYS = ("requests", "p99_s", "mean_mem_mb", "ops_per_gb_s")


def git_sha() -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True, timeout=10,
                             cwd=os.path.dirname(os.path.abspath(__file__)))
        if out.returncode == 0:
            return out.stdout.strip()
    except OSError:
        pass
    return "unknown"


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def build_artifact(trace_file: str = AZURE_SAMPLE, calibration: str = None,
                   target_rps: float = None, max_minutes: int = None,
                   seed: int = 0, top_k: int = None, select: str = "top",
                   chunk_rows: int = 4096, gateway_smoke: bool = False,
                   gateway_compress: float = 120.0) -> dict:
    """Run the full-model streaming sweep (plus the optional live
    gateway leg) and assemble the artifact document. Raises
    ``ValueError`` for an unusable trace/window — the caller owns the
    clean-exit contract."""
    params = SimParams(**AZURE_PARAMS)
    if calibration:
        params = apply_calibration(params, calibration)
    trace = Trace.stream_azure(trace_file,
                               **discover_azure_tables(trace_file),
                               target_rps=target_rps,
                               max_minutes=max_minutes, seed=seed,
                               top_k=top_k, select=select,
                               chunk_rows=chunk_rows)
    models = {}
    for m in MODELS:
        s = simulate(trace, m, params).summary()
        models[m] = {k: s[k] for k in MODEL_KEYS}
    density = {m: models[m]["ops_per_gb_s"] for m in DENSITY_ORDER}
    provenance = trace.describe()      # exact: the sweep iterated fully
    provenance["path"] = os.path.basename(trace_file)
    provenance["sha256"] = _sha256(trace_file)

    doc = {
        "schema": SCHEMA,
        "git_sha": git_sha(),
        "trace": provenance,
        "params": dict(AZURE_PARAMS),
        "streaming": {"chunk_rows": chunk_rows,
                      "peak_buffered": trace.peak_buffered},
        "models": models,
        "density_ordering": {
            "order": list(DENSITY_ORDER),
            "values": density,
            "holds": density["hydra-cluster"] >= density["hydra-pool"]
            >= density["hydra"],
        },
        "gateway": _gateway_leg(trace_file, seed, gateway_compress)
        if gateway_smoke else None,
    }
    return doc


def _gateway_leg(trace_file: str, seed: int, compress: float) -> dict:
    """One thinned live replay through the real gateway stack (the CI
    gateway-smoke regime), reduced to the artifact's fixed key set."""
    from repro.gateway import load_trace, run_validation

    trace = load_trace(trace_file, target_rps=2.0, max_minutes=10,
                       seed=seed)
    # attribute=True traces every request of the live leg, so the
    # artifact carries per-phase latency columns (hydra-bench/v2) and
    # the measured dominant phase of the p99 tail
    report = run_validation(trace, compress=compress, pool_size=4,
                            attribute=True)
    live, sim = report["live"], report["sim"]
    extras = report.get("extras") or {}
    overhead = extras.get("request_overhead_ms") or {}
    exe = extras.get("exe_cache") or {}
    tracing = extras.get("tracing") or {}
    # fixed tracing vocabulary (Tracer.summary emits every key, None
    # when a phase never fired) -> run-stable key shape for the drift
    # gate; wall milliseconds
    phases = {name: {"p50_ms": s.get("p50_ms"), "p99_ms": s.get("p99_ms")}
              for name, s in (tracing.get("phases") or {}).items()}
    att = (report.get("attribution") or {}).get("p99") or {}
    return {
        "compress": compress,
        "requests": live["requests"],
        "p99_s": live["p99_s"],
        "cold_runtime": live["cold_runtime"],
        "pool_claims": live["pool_claims"],
        "dropped": live["dropped"],
        # per-request gateway overhead (latency - emulated duration) in
        # WALL ms — the request-path cost this repo's slab allocator +
        # compile caches keep flat; the CI overhead budget gates on the
        # bench_hotpath twin of this number
        "request_overhead_ms": {"mean": overhead.get("mean"),
                                "p99": overhead.get("p99")},
        "exe_compiles": exe.get("compiles"),
        "exe_disk_hits": exe.get("disk_hits"),
        "exe_cache_hits": exe.get("cache_hits"),
        # hydra-bench/v2: per-phase wall-ms latency columns from a
        # fully-sampled request trace of the smoke replay, plus the
        # measured dominant phase of the p99 tail (docs/observability.md)
        "phases": phases,
        "p99_dominant_phase": att.get("dominant"),
        "sim_p99_s": sim["p99_s"],
        "sim_cold_runtime": sim["cold_runtime"],
        "cold_within_tolerance": report["gates"]["cold_runtime"]["passed"],
        "p99_within_tolerance": report["gates"]["p99_s"]["passed"],
    }


# ---------------------------------------------------------------------------
def _key_shape(doc, prefix: str = "") -> set:
    """The recursive key structure of a JSON document — what schema
    drift is measured against. Leaf values (and list contents) don't
    contribute; a dict turning into a scalar/null or keys
    appearing/disappearing does."""
    shape = set()
    if isinstance(doc, dict):
        for k, v in sorted(doc.items()):
            shape.add(f"{prefix}{k}")
            shape |= _key_shape(v, f"{prefix}{k}.")
    return shape


def validate_artifact(doc: dict) -> list:
    """Internal consistency errors (empty list = valid): schema tag,
    required sections, finite/positive metrics for every model, the
    density ordering actually holding."""
    errors = []
    if doc.get("schema") != SCHEMA:
        errors.append(f"schema: expected {SCHEMA!r}, "
                      f"got {doc.get('schema')!r}")
    for section in ("git_sha", "trace", "params", "streaming", "models",
                    "density_ordering"):
        if section not in doc:
            errors.append(f"missing section: {section}")
    models = doc.get("models") or {}
    missing = [m for m in MODELS if m not in models]
    if missing:
        errors.append(f"models missing from sweep: {missing}")
    for m, metrics in models.items():
        for k in MODEL_KEYS:
            v = metrics.get(k)
            if not isinstance(v, (int, float)) or not math.isfinite(v):
                errors.append(f"models.{m}.{k}: non-finite {v!r}")
            elif k in POSITIVE_KEYS and v <= 0:
                errors.append(f"models.{m}.{k}: expected > 0, got {v!r}")
    ordering = doc.get("density_ordering") or {}
    if not ordering.get("holds", False):
        errors.append(f"density ordering violated: "
                      f"{ordering.get('values')}")
    trace = doc.get("trace") or {}
    if not trace.get("invocations"):
        errors.append("trace.invocations: zero invocations replayed")
    streaming = doc.get("streaming") or {}
    peak = streaming.get("peak_buffered", 0)
    n = trace.get("invocations") or 0
    if peak and n and peak > n:
        errors.append(f"streaming.peak_buffered={peak} exceeds "
                      f"invocations={n}")
    gateway = doc.get("gateway")
    if gateway is not None:
        for k in ("mean", "p99"):
            v = (gateway.get("request_overhead_ms") or {}).get(k)
            if not isinstance(v, (int, float)) or not math.isfinite(v) \
                    or v < 0:
                errors.append(
                    f"gateway.request_overhead_ms.{k}: expected finite "
                    f">= 0, got {v!r}")
        # v2: the per-phase columns must carry the FULL tracing
        # vocabulary (unfired phases are null, never absent) and the
        # end-to-end 'total' phase must have actually been observed
        phases = gateway.get("phases") or {}
        missing_phases = [k for k in SUMMARY_KEYS if k not in phases]
        if missing_phases:
            errors.append(f"gateway.phases missing vocabulary entries: "
                          f"{missing_phases}")
        total_p99 = (phases.get("total") or {}).get("p99_ms")
        if not isinstance(total_p99, (int, float)) \
                or not math.isfinite(total_p99) or total_p99 <= 0:
            errors.append(f"gateway.phases.total.p99_ms: expected finite "
                          f"> 0, got {total_p99!r}")
    return errors


def check_against(new: dict, committed: dict) -> list:
    """CI gate: schema drift between the regenerated and committed
    documents, or a density-ordering regression. Values may move; the
    contract may not."""
    errors = []
    if new.get("schema") != committed.get("schema"):
        errors.append(f"schema drift: committed {committed.get('schema')!r}"
                      f" vs regenerated {new.get('schema')!r}")
    new_shape, old_shape = _key_shape(new), _key_shape(committed)
    for key in sorted(old_shape - new_shape):
        errors.append(f"schema drift: key disappeared: {key}")
    for key in sorted(new_shape - old_shape):
        errors.append(f"schema drift: key appeared: {key}")
    was = (committed.get("density_ordering") or {}).get("holds", False)
    now = (new.get("density_ordering") or {}).get("holds", False)
    if was and not now:
        errors.append(
            f"density ordering regression: committed artifact held "
            f"cluster >= pool >= hydra, regenerated does not: "
            f"{(new.get('density_ordering') or {}).get('values')}")
    return errors


def write_artifact(doc: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


# ---------------------------------------------------------------------------
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the artifact JSON here (validated first; "
                         "nothing is written on a validation failure)")
    ap.add_argument("--check-against", default=None, metavar="PATH",
                    help="committed BENCH_trace.json to diff the "
                         "regenerated document against (schema drift / "
                         "density-ordering regression fail)")
    ap.add_argument("--trace-file", default=AZURE_SAMPLE,
                    help="Azure Functions 2019-format invocations CSV "
                         "(default: the bundled sample)")
    ap.add_argument("--calibration", default=None,
                    help="hydra-calibration/v1 JSON overriding the paper "
                         "constants for the sweep")
    ap.add_argument("--target-rps", type=float, default=None,
                    help="deterministically thin the trace to this mean "
                         "rps before the sweep")
    ap.add_argument("--max-minutes", type=int, default=None,
                    help="sweep only the first N minutes of the trace")
    ap.add_argument("--seed", type=int, default=0,
                    help="thinning/expansion seed")
    ap.add_argument("--top-k", type=int, default=None,
                    help="keep only K function rows (see --select)")
    ap.add_argument("--select", default="top", choices=("top", "stratified"),
                    help="top-K policy: K busiest rows, or one seeded "
                         "pick per popularity stratum")
    ap.add_argument("--chunk-rows", type=int, default=4096,
                    help="CSV ingestion chunk size (rows)")
    ap.add_argument("--gateway-smoke", action="store_true",
                    help="also run one thinned live replay through the "
                         "real gateway stack and record its leg")
    ap.add_argument("--gateway-compress", type=float, default=None,
                    help="wall-clock compression for the gateway leg "
                         "(default 120)")
    args = ap.parse_args(argv)

    if args.gateway_compress is not None and not args.gateway_smoke:
        print("bench_artifact: --gateway-compress requires --gateway-smoke",
              file=sys.stderr)
        return 2
    if not args.out and not args.check_against:
        print("bench_artifact: nothing to do (pass --out and/or "
              "--check-against)", file=sys.stderr)
        return 2
    if not os.path.isfile(args.trace_file):
        print(f"bench_artifact: trace file not found: {args.trace_file}",
              file=sys.stderr)
        return 2

    try:
        doc = build_artifact(args.trace_file, calibration=args.calibration,
                             target_rps=args.target_rps,
                             max_minutes=args.max_minutes, seed=args.seed,
                             top_k=args.top_k, select=args.select,
                             chunk_rows=args.chunk_rows,
                             gateway_smoke=args.gateway_smoke,
                             gateway_compress=args.gateway_compress
                             or 120.0)
    except ValueError as e:
        print(f"bench_artifact: {e}", file=sys.stderr)
        return 2

    errors = validate_artifact(doc)
    if args.check_against:
        try:
            with open(args.check_against) as f:
                committed = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"bench_artifact: cannot read committed artifact "
                  f"{args.check_against}: {e}", file=sys.stderr)
            return 2
        errors += check_against(doc, committed)

    for e in errors:
        print(f"# FAIL {e}", file=sys.stderr)
    if errors:
        return 1
    if args.out:
        write_artifact(doc, args.out)
        print(f"bench_artifact: wrote {args.out} "
              f"(git {doc['git_sha'][:12]})")
    else:
        print("bench_artifact: regenerated document matches the committed "
              "schema; density ordering holds")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
