"""Fig 1 analog: startup latency + memory footprint per virtualization layer.

Layers measured on this host:
  runtime-cold   build a HydraRuntime + compile a function (new process
                 worker = runtime boot + first JIT)
  exe-cache-warm registration that hits the shared executable cache
  arena-cold     first isolate allocation
  arena-warm     pooled isolate acquisition (paper: < 500 us)
  snap-restore   platform snapshot -> evict -> restore round trip (the
                 zero-recompile warm path)

``--emit-calibration out.json`` additionally writes the measurements as
a ``hydra-calibration/v1`` JSON (see ``repro.core.calibrate``) mapping
them onto the simulator's ``SimParams`` fields, so trace replays
(``bench_trace --calibration out.json``) use THIS host's costs instead
of the paper constants:

  PYTHONPATH=src python benchmarks/bench_startup.py \\
      --emit-calibration calibration.json
"""
from __future__ import annotations

import argparse
import os
import resource
import sys
import tempfile
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax.numpy as jnp

from benchmarks.functions import catalog
from repro.core import HydraPlatform, HydraRuntime
from repro.core.arena import ArenaPool

MB = 1 << 20


def measure() -> tuple:
    """Run the Fig-1 measurements; returns (csv rows, measured dict of
    calibratable SimParams fields)."""
    rows = []
    measured = {}
    specs = catalog()
    spec = specs["jv/filehashing"]

    # runtime cold: fresh runtime + fresh compile. The Fig-1 row reports
    # the combined wall time; the calibration splits it — the boot leg
    # maps onto hydra_runtime_cold_s (charged per simulated cold start)
    # and the first-install leg onto fn_register_s (charged per first
    # function load), so nothing is double-counted and the sim's cost
    # ordering (snapshot restore << full register) survives calibration.
    # The RSS high-water delta across the boot alone is a best-effort
    # stand-in for the runtime's base footprint (only trusted — and only
    # emitted — when the allocator actually grew the process image).
    rss_unit = 1 if sys.platform == "darwin" else 1024  # ru_maxrss: B vs KB
    rss0 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * rss_unit
    t0 = time.perf_counter()
    rt = HydraRuntime(janitor=False)
    boot_s = time.perf_counter() - t0
    rss1 = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * rss_unit
    rt.register_function("f", spec)
    cold_s = time.perf_counter() - t0
    rows.append({"name": "startup.runtime_cold", "us_per_call": cold_s * 1e6,
                 "derived": f"boot_us={boot_s*1e6:.0f};"
                            f"budget={rt.budget.used}B"})
    measured["hydra_runtime_cold_s"] = boot_s
    measured["fn_register_s"] = cold_s - boot_s
    if rss1 - rss0 > 8 * MB:
        measured["hydra_runtime_base"] = rss1 - rss0

    # warm registration (executable cache hit, second tenant)
    t0 = time.perf_counter()
    rt.register_function("f2", spec, tenant="t2")
    warm_s = time.perf_counter() - t0
    rows.append({"name": "startup.register_warm", "us_per_call": warm_s * 1e6,
                 "derived": f"speedup={cold_s/warm_s:.1f}x"})

    # arena cold vs warm. The process's first-ever allocation includes a
    # one-time jnp.zeros JIT; holding it while acquiring again forces a
    # second pool-miss WITHOUT that compile — the steady-state cold cost
    # the simulator charges per cold isolate (same boot-vs-install split
    # as the runtime leg above).
    pool = ArenaPool(ttl_s=60)
    factory = lambda: {"kv": jnp.zeros((256, 1024), jnp.float32)}  # 1 MB
    # hydralint: disable=HL009 — warmup is held ON PURPOSE so the next
    # acquire misses the pool (a release would turn the cold-path
    # measurement into a warm hit); the pool is function-local and dies
    # with the benchmark
    warmup = pool.acquire(("kv",), factory)      # one-time JIT happens here
    t0 = time.perf_counter()
    a = pool.acquire(("kv",), factory)           # pool empty: cold alloc
    cold_a = time.perf_counter() - t0
    pool.release(a)
    t0 = time.perf_counter()
    pool.acquire(("kv",), factory)               # pool hit: warm
    warm_a = time.perf_counter() - t0
    pool.release(warmup)
    rows.append({"name": "startup.arena_cold", "us_per_call": cold_a * 1e6,
                 "derived": f"bytes={a.nbytes}"})
    rows.append({"name": "startup.arena_warm", "us_per_call": warm_a * 1e6,
                 "derived": f"speedup={cold_a/max(warm_a,1e-9):.1f}x"})
    measured["isolate_cold_s"] = cold_a
    measured["isolate_warm_s"] = warm_a
    rt.shutdown()

    # platform snapshot -> evict -> restore round trip: the restore leg
    # is the sim's snapshot_restore_s (install a snapshotted fn vs a
    # first full register)
    with tempfile.TemporaryDirectory() as snapdir:
        plat = HydraPlatform(pool_size=1, snapshot_dir=snapdir)
        try:
            plat.register_function("cal/f", specs["jv/filehashing"],
                                   tenant="cal")
            plat.invoke("cal/f", spec.example_args)
            plat.snapshot("cal/f")
            plat.evict("cal/f")
            t0 = time.perf_counter()
            plat.restore("cal/f")
            restore_s = time.perf_counter() - t0
        finally:
            plat.shutdown()
    rows.append({"name": "startup.snapshot_restore",
                 "us_per_call": restore_s * 1e6,
                 "derived": f"vs_cold={cold_s/max(restore_s,1e-9):.1f}x"})
    measured["snapshot_restore_s"] = restore_s
    return rows, measured


def run() -> list:
    rows, _ = measure()
    return rows


def main(argv=None) -> list:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--emit-calibration", metavar="PATH", default=None,
                    help="write measured costs as a hydra-calibration/v1 "
                         "JSON usable by bench_trace --calibration")
    args = ap.parse_args(argv)
    rows, measured = measure()
    for row in rows:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    if args.emit_calibration:
        import platform as host_platform

        from repro.core.calibrate import write_calibration
        doc = write_calibration(
            args.emit_calibration, measured,
            meta={"source": "bench_startup",
                  "host": host_platform.node() or "unknown"})
        print(f"# wrote {args.emit_calibration}: "
              f"{sorted(doc['measured'])}")
    return rows


if __name__ == "__main__":
    main()
