"""Fig 1 analog: startup latency + memory footprint per virtualization layer.

Layers measured on this host:
  runtime-cold   build a HydraRuntime + compile a function (new process
                 worker = runtime boot + first JIT)
  exe-cache-warm registration that hits the shared executable cache
  arena-cold     first isolate allocation
  arena-warm     pooled isolate acquisition (paper: < 500 us)
"""
from __future__ import annotations

import time

import jax.numpy as jnp

from benchmarks.functions import catalog
from repro.core import ExecutableCache, HydraRuntime
from repro.core.arena import ArenaPool


def run() -> list:
    rows = []
    specs = catalog()
    spec = specs["jv/filehashing"]

    # runtime cold: fresh runtime + fresh compile
    t0 = time.perf_counter()
    rt = HydraRuntime(janitor=False)
    rt.register_function("f", spec)
    cold_s = time.perf_counter() - t0
    rows.append({"name": "startup.runtime_cold", "us_per_call": cold_s * 1e6,
                 "derived": f"budget={rt.budget.used}B"})

    # warm registration (executable cache hit, second tenant)
    t0 = time.perf_counter()
    rt.register_function("f2", spec, tenant="t2")
    warm_s = time.perf_counter() - t0
    rows.append({"name": "startup.register_warm", "us_per_call": warm_s * 1e6,
                 "derived": f"speedup={cold_s/warm_s:.1f}x"})

    # arena cold vs warm
    pool = ArenaPool(ttl_s=60)
    factory = lambda: {"kv": jnp.zeros((256, 1024), jnp.float32)}  # 1 MB
    t0 = time.perf_counter()
    a = pool.acquire(("kv",), factory)
    cold_a = time.perf_counter() - t0
    pool.release(a)
    t0 = time.perf_counter()
    b = pool.acquire(("kv",), factory)
    warm_a = time.perf_counter() - t0
    rows.append({"name": "startup.arena_cold", "us_per_call": cold_a * 1e6,
                 "derived": f"bytes={a.nbytes}"})
    rows.append({"name": "startup.arena_warm", "us_per_call": warm_a * 1e6,
                 "derived": f"speedup={cold_a/max(warm_a,1e-9):.1f}x"})
    rt.shutdown()
    return rows
