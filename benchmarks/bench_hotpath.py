"""Warm request-path overhead: claim -> args -> dispatch -> release.

Two levels, both in microseconds of pure platform overhead (no emulated
function duration — the program is a trivial affine kernel):

**Arena level** (before/after the slab allocator):

  hotpath.arena.legacy_devput  the pre-slab per-claim cost: mint host
                               zeros + ``device_put`` them on every
                               claim (what ``ArenaPool.acquire`` paid
                               before slabs existed — the "before")
  hotpath.arena.zeroed_reuse   slab handover across owners: pooled pop
                               + jitted donate-in-place zero fill (the
                               cross-tenant "after")
  hotpath.arena.donated_reuse  slab handover back to the same owner:
                               pooled pop only (the same-function
                               "after")

**Request level** (the budgeted numbers): wall latency of a fully warm
``HydraRuntime.invoke`` — registry lookup, slab claim, executable
dispatch, block, release — with host-side request args built per call
exactly as the gateway's ``TraceWorkload.args_for`` does. Reported as
mean/p99 ms over ``--iters`` serial invokes.

**Tracing level**: the same warm invoke through the request-tracing
layer (``repro.core.tracing``) — ``invoke_traced_off`` carries the
no-op ``NULL_TRACE`` an unsampled gateway request pays (delta vs plain
budget-gated at ~0) and ``invoke_traced_on`` the fully-sampled span
path (loose absolute budget; sampling is opt-in).

``--budget PATH`` compares the request-level numbers (and the zeroed
slab handover) against a committed budget JSON and exits non-zero on
any overrun — the CI ``bench-artifact`` job runs exactly that, so a
change that drags allocation, compilation, or host copies back onto
the warm path fails the build. Budgets are deliberately loose (5-10x
a dev-container measurement): they catch order-of-magnitude
regressions — an eager ``device_put`` or a per-request compile — not
machine jitter.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import numpy as np

from repro.core.arena import ArenaPool
from repro.core.registry import CallableSpec
from repro.core.runtime import HydraRuntime

DEFAULT_BUDGET = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              "data", "overhead_budget.json")
VEC = 64
ARENA_BYTES = 1 << 20            # 1 MB scratch slab, like a small function


def _affine(params, args):
    return {"y": args["x"] * params["w"] + params["b"]}


def _spec() -> CallableSpec:
    import jax.numpy as jnp
    return CallableSpec(name="hotpath", fn=_affine,
                        example_args={"x": jnp.ones((VEC,), jnp.float32)},
                        params={"w": jnp.full((VEC,), 2.0, jnp.float32),
                                "b": jnp.full((VEC,), 1.0, jnp.float32)},
                        arena_bytes=ARENA_BYTES)


def _percentile(sorted_vals: list, q: float) -> float:
    return sorted_vals[min(len(sorted_vals) - 1,
                           int(round(q * (len(sorted_vals) - 1))))]


def _series(fn, iters: int, warmup: int = 20) -> dict:
    for _ in range(warmup):
        fn()
    vals = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        vals.append(time.perf_counter() - t0)
    vals.sort()
    return {"iters": iters,
            "mean": sum(vals) / len(vals),
            "p50": _percentile(vals, 0.50),
            "p99": _percentile(vals, 0.99)}


def bench_arena(iters: int) -> dict:
    """The slab allocator's claim paths vs the pre-slab per-claim
    ``device_put`` allocation, isolated from dispatch."""
    nb = ARENA_BYTES

    # before: every claim minted host zeros and copied them to device
    def legacy_devput():
        jax.block_until_ready(jax.device_put(
            np.zeros((nb // 4,), np.float32)))

    pool = ArenaPool(ttl_s=1e9)
    sig = ("scratch", nb)
    factory = lambda: {"scratch": jax.device_put(
        np.zeros((nb // 4,), np.float32))}
    pool.prealloc(sig, factory, 1, owner="fn-a")

    flip = ["fn-a"]

    def zeroed_reuse():           # ownership changes on every claim
        flip[0] = "fn-b" if flip[0] == "fn-a" else "fn-a"
        pool.release(pool.acquire(sig, owner=flip[0]))

    def donated_reuse():          # same owner claims its slab back
        pool.release(pool.acquire(sig, owner="fn-a"))

    return {"legacy_devput": _series(legacy_devput, iters),
            "zeroed_reuse": _series(zeroed_reuse, iters),
            "donated_reuse": _series(donated_reuse, iters)}


def bench_invoke(iters: int) -> tuple:
    """Fully warm end-to-end invoke (the budgeted request path), plus
    the same invoke through the tracing layer — disabled (the
    ``NULL_TRACE`` every unsampled gateway request carries: one
    sampling decision + no-op spans, budget-gated at ~0 delta) and
    fully sampled (span objects + clock reads + breakdown, the opt-in
    ``--trace-sample`` cost, loose absolute budget)."""
    from repro.core.tracing import Tracer

    rt = HydraRuntime(n_workers=2, janitor=False)
    try:
        rt.register_function("hot/fn", _spec())
        rt.prewarm_arenas("hot/fn", 1)
        compiles0 = rt.exe_cache.stats()["compiles"]
        cold0 = rt.metrics.snapshot()["counters"].get("arena.cold", 0)

        def invoke():
            # host-side payload per request, as the gateway builds it
            rt.invoke("hot/fn", {"x": np.full((VEC,), 3.0, np.float32)})

        tracer_off = Tracer(0.0)

        def invoke_traced_off():
            ctx = tracer_off.start_request("hot/fn")
            rt.invoke("hot/fn", {"x": np.full((VEC,), 3.0, np.float32)},
                      ctx=ctx)
            ctx.finish("ok")

        # bounded export window: a long --iters run must not grow memory
        tracer_on = Tracer(1.0, max_traces=64, hist_max_samples=64)

        def invoke_traced_on():
            ctx = tracer_on.start_request("hot/fn")
            rt.invoke("hot/fn", {"x": np.full((VEC,), 3.0, np.float32)},
                      ctx=ctx)
            ctx.finish("ok")

        series = _series(invoke, iters)
        traced_off = _series(invoke_traced_off, iters)
        traced_on = _series(invoke_traced_on, iters)
        series["compiles_during"] = (rt.exe_cache.stats()["compiles"]
                                     - compiles0)
        series["cold_allocs"] = (rt.metrics.snapshot()["counters"]
                                 .get("arena.cold", 0) - cold0)
        return series, traced_off, traced_on
    finally:
        rt.shutdown()


def measure(iters: int) -> dict:
    plain, traced_off, traced_on = bench_invoke(iters)
    ms = lambda s: {k: (v * 1e3 if isinstance(v, float) else v)
                    for k, v in s.items()}
    off_ms, on_ms = ms(traced_off), ms(traced_on)
    plain_ms = ms(plain)
    return {"arena_us": {name: {k: (v * 1e6 if isinstance(v, float) else v)
                                for k, v in s.items()}
                         for name, s in bench_arena(iters).items()},
            "invoke_ms": plain_ms,
            "invoke_traced_ms": {
                "off": off_ms, "on": on_ms,
                # the gated number: what every UNSAMPLED request pays
                # for tracing being compiled in (expected ~0; negative
                # means jitter, which the budget treats as within)
                "off_delta_mean": off_ms["mean"] - plain_ms["mean"],
            }}


def check_budget(result: dict, budget_doc: dict) -> list:
    """Budget overruns (empty = within budget). Keys of
    ``budget_doc['budgets']`` name the gated numbers."""
    budgets = budget_doc.get("budgets") or {}
    gated = {
        "warm_invoke_ms_mean": result["invoke_ms"]["mean"],
        "warm_invoke_ms_p99": result["invoke_ms"]["p99"],
        "arena_zeroed_reuse_us_mean":
            result["arena_us"]["zeroed_reuse"]["mean"],
        "arena_donated_reuse_us_mean":
            result["arena_us"]["donated_reuse"]["mean"],
        "tracing_off_delta_ms_mean":
            result["invoke_traced_ms"]["off_delta_mean"],
        "traced_invoke_ms_mean":
            result["invoke_traced_ms"]["on"]["mean"],
    }
    errors = []
    for name, limit in budgets.items():
        got = gated.get(name)
        if got is None:
            errors.append(f"unknown budget key: {name}")
        elif not math.isfinite(got) or got > limit:
            errors.append(f"{name}: measured {got:.3f} exceeds "
                          f"budget {limit:.3f}")
    return errors


def run(iters: int = 200) -> list:
    """benchmarks/run.py entry: rows in the common csv shape."""
    res = measure(iters)
    rows = []
    for name, s in res["arena_us"].items():
        rows.append({"name": f"hotpath.arena.{name}",
                     "us_per_call": s["mean"],
                     "derived": f"p99_us={s['p99']:.1f}"})
    inv = res["invoke_ms"]
    rows.append({"name": "hotpath.invoke_warm",
                 "us_per_call": inv["mean"] * 1e3,
                 "derived": f"p99_ms={inv['p99']:.3f};"
                            f"compiles={inv['compiles_during']}"})
    tr = res["invoke_traced_ms"]
    rows.append({"name": "hotpath.invoke_traced_off",
                 "us_per_call": tr["off"]["mean"] * 1e3,
                 "derived": f"delta_ms={tr['off_delta_mean']:.4f}"})
    rows.append({"name": "hotpath.invoke_traced_on",
                 "us_per_call": tr["on"]["mean"] * 1e3,
                 "derived": f"p99_ms={tr['on']['p99']:.3f}"})
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--iters", type=int, default=300,
                    help="timed iterations per series (after 20 warmups)")
    ap.add_argument("--budget", metavar="PATH", nargs="?",
                    const=DEFAULT_BUDGET, default=None,
                    help="overhead budget JSON to gate against (no value: "
                         "the committed benchmarks/data/overhead_budget."
                         "json); exits 1 on any overrun")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also dump the raw measurement document here")
    args = ap.parse_args(argv)

    res = measure(args.iters)
    arena = res["arena_us"]
    legacy = arena["legacy_devput"]["mean"]
    print(f"# warm claim path, {args.iters} iters "
          f"(arena {ARENA_BYTES >> 20} MB)")
    for name in ("legacy_devput", "zeroed_reuse", "donated_reuse"):
        s = arena[name]
        print(f"hotpath.arena.{name},{s['mean']:.1f}us,"
              f"p99={s['p99']:.1f}us,"
              f"vs_legacy={legacy / max(s['mean'], 1e-9):.1f}x")
    inv = res["invoke_ms"]
    print(f"hotpath.invoke_warm,mean={inv['mean']:.3f}ms,"
          f"p99={inv['p99']:.3f}ms,compiles={inv['compiles_during']},"
          f"cold_allocs={inv['cold_allocs']}")
    tr = res["invoke_traced_ms"]
    print(f"hotpath.invoke_traced_off,mean={tr['off']['mean']:.3f}ms,"
          f"p99={tr['off']['p99']:.3f}ms,"
          f"delta_vs_plain={tr['off_delta_mean'] * 1e3:+.1f}us")
    print(f"hotpath.invoke_traced_on,mean={tr['on']['mean']:.3f}ms,"
          f"p99={tr['on']['p99']:.3f}ms")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=1, sort_keys=True)
            f.write("\n")
    if args.budget:
        with open(args.budget) as f:
            budget_doc = json.load(f)
        errors = check_budget(res, budget_doc)
        for e in errors:
            print(f"# FAIL {e}", file=sys.stderr)
        if errors:
            return 1
        print(f"# within budget ({os.path.basename(args.budget)}): "
              + ", ".join(sorted((budget_doc.get("budgets") or {}))))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
