"""Serverless benchmark functions (paper Table 1 analogs, in JAX).

SeBS/Photons-style workloads expressed as pure JAX callables so they run
inside the Hydra runtime as registered functions: helloworld, filehashing,
thumbnail, compress, video-processing, restapi, classify, uploader,
dynamic-html.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.registry import CallableSpec

_K = jax.random.PRNGKey(42)


def _hello(params, args):
    return {"msg": args["x"] * 0 + 1}


def _hash(params, args):
    """Polynomial rolling hash over a byte buffer (filehashing)."""
    x = args["data"].astype(jnp.uint32)
    powers = jnp.power(jnp.uint32(31), jnp.arange(x.shape[-1],
                                                  dtype=jnp.uint32))
    return {"digest": jnp.sum(x * powers, dtype=jnp.uint32)}


def _thumbnail(params, args):
    """Average-pool a 256x256x3 image to 64x64x3."""
    img = args["image"]
    h = img.reshape(64, 4, 64, 4, 3).mean(axis=(1, 3))
    return {"thumb": h}


def _compress(params, args):
    """FFT + top-k magnitude truncation (lossy compression)."""
    x = args["signal"]
    f = jnp.fft.rfft(x)
    mag = jnp.abs(f)
    thresh = jnp.percentile(mag, 90)
    return {"coeffs": jnp.where(mag >= thresh, f, 0)}


def _video(params, args):
    """Temporal smoothing conv over a frame stack (video-processing)."""
    frames = args["frames"]                   # (T, H, W)
    kern = jnp.array([0.25, 0.5, 0.25])
    pad = jnp.pad(frames, ((1, 1), (0, 0), (0, 0)), mode="edge")
    out = (pad[:-2] * kern[0] + pad[1:-1] * kern[1] + pad[2:] * kern[2])
    return {"out": out}


def _rest(params, args):
    """Token scoring (restapi): embed + dot + softmax."""
    scores = args["query"] @ params["table"].T
    return {"top": jnp.argmax(jax.nn.softmax(scores), axis=-1)}


def _classify(params, args):
    h = jax.nn.relu(args["features"] @ params["w1"])
    return {"label": jnp.argmax(h @ params["w2"], axis=-1)}


def _uploader(params, args):
    """Checksum + chunking of a payload (uploader)."""
    x = args["payload"]
    chunks = x.reshape(16, -1)
    return {"chunk_sums": jnp.sum(chunks, axis=1),
            "crc": jnp.sum(x, dtype=jnp.float32)}


def _html(params, args):
    """dynamic-html: template scatter of values into a page skeleton."""
    page = jnp.zeros((2048,), jnp.float32)
    idx = (args["slots"].astype(jnp.int32) % 2048)
    return {"page": page.at[idx].add(args["values"])}


def catalog() -> dict:
    ks = jax.random.split(_K, 4)
    return {
        "js/helloworld": CallableSpec(
            "helloworld", _hello, {"x": jnp.zeros((8,), jnp.float32)}),
        "jv/filehashing": CallableSpec(
            "filehashing", _hash,
            {"data": jnp.zeros((4096,), jnp.uint8)}),
        "py/thumbnail": CallableSpec(
            "thumbnail", _thumbnail,
            {"image": jnp.zeros((256, 256, 3), jnp.float32)}),
        "py/compress": CallableSpec(
            "compress", _compress, {"signal": jnp.zeros((8192,),
                                                        jnp.float32)}),
        "py/video": CallableSpec(
            "video", _video, {"frames": jnp.zeros((16, 64, 64),
                                                  jnp.float32)}),
        "jv/restapi": CallableSpec(
            "restapi", _rest, {"query": jnp.zeros((4, 64), jnp.float32)},
            params={"table": jax.random.normal(ks[0], (128, 64))}),
        "jv/classify": CallableSpec(
            "classify", _classify,
            {"features": jnp.zeros((8, 128), jnp.float32)},
            params={"w1": jax.random.normal(ks[1], (128, 256)) * 0.1,
                    "w2": jax.random.normal(ks[2], (256, 10)) * 0.1}),
        "js/uploader": CallableSpec(
            "uploader", _uploader, {"payload": jnp.zeros((65536,),
                                                         jnp.float32)}),
        "js/dynamic-html": CallableSpec(
            "html", _html, {"slots": jnp.zeros((64,), jnp.int32),
                            "values": jnp.ones((64,), jnp.float32)}),
    }


def example_args(spec: CallableSpec):
    return jax.tree.map(lambda x: x + 1 if x.dtype != jnp.uint8 else x,
                        spec.example_args)
