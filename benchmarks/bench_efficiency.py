"""Fig 6 analog: throughput per memory (ops/sec/GB), Hydra vs
one-runtime-per-function.

Hydra hosts ALL functions in one runtime/budget; the baseline dedicates a
runtime (and its budget) per function. Efficiency = aggregate ops/sec
divided by reserved GB.
"""
from __future__ import annotations

import time

from benchmarks.functions import catalog, example_args
from repro.core import HydraRuntime

N_CALLS = 30
GB = 1 << 30


def _throughput(rt, fids, args_map) -> float:
    t0 = time.perf_counter()
    futs = []
    for i in range(N_CALLS):
        fid = fids[i % len(fids)]
        futs.append(rt.invoke_async(fid, args_map[fid]))
    for f in futs:
        f.result()
    return N_CALLS / (time.perf_counter() - t0)


def run() -> list:
    rows = []
    specs = catalog()
    names = list(specs)

    # --- Hydra: one runtime hosting every function ---
    rt = HydraRuntime(janitor=False)
    args_map = {}
    for name in names:
        rt.register_function(name, specs[name])
        args_map[name] = example_args(specs[name])
    # warm one pass
    for name in names:
        rt.invoke(name, args_map[name])
    ops = _throughput(rt, names, args_map)
    hydra_gb = rt.budget.used / GB
    hydra_eff = ops / max(hydra_gb, 1e-9)
    rt.shutdown()

    # --- baseline: one runtime per function (stack redundancy) ---
    # each worker reserves the paper's standard 128 MB function budget
    per_fn_budget = 128 << 20
    baseline_rts = {}
    for name in names:
        r = HydraRuntime(janitor=False)
        r.register_function(name, specs[name], mem_budget=per_fn_budget)
        r.invoke(name, args_map[name])
        baseline_rts[name] = r
    t0 = time.perf_counter()
    futs = []
    for i in range(N_CALLS):
        name = names[i % len(names)]
        futs.append(baseline_rts[name].invoke_async(name, args_map[name]))
    for f in futs:
        f.result()
    base_ops = N_CALLS / (time.perf_counter() - t0)
    base_gb = sum(r.budget.used for r in baseline_rts.values()) / GB
    base_eff = base_ops / max(base_gb, 1e-9)
    for r in baseline_rts.values():
        r.shutdown()

    rows.append({"name": "efficiency.hydra_ops_per_gb",
                 "us_per_call": 1e6 / ops,
                 "derived": f"ops_per_sec_per_gb={hydra_eff:.1f};"
                            f"gb={hydra_gb:.3f}"})
    rows.append({"name": "efficiency.per_fn_runtime_ops_per_gb",
                 "us_per_call": 1e6 / base_ops,
                 "derived": f"ops_per_sec_per_gb={base_eff:.1f};"
                            f"gb={base_gb:.3f};"
                            f"hydra_gain={hydra_eff/max(base_eff,1e-9):.1f}x"})
    return rows
