"""Render the dry-run roofline JSONs into the EXPERIMENTS.md tables.

  PYTHONPATH=src:. python benchmarks/roofline_table.py [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json

MOVE = {
    "compute": "more useful-FLOP fraction (less remat/masked-attention "
               "waste) or lower precision",
    "memory": "fewer cache/activation passes (windowed KV reads, fused "
              "update-in-place, bf16 end-to-end)",
    "collective": "cheaper parallelism layout (less TP for small models, "
                  "sequence-parallel TP, bf16 reduce-scatter gradients)",
}


def load(mesh: str, tag: str = "") -> list:
    rows = []
    for p in sorted(glob.glob("experiments/dryrun/*.json")):
        d = json.load(open(p))
        if d["mesh"] != mesh or d.get("tag", "") != tag:
            continue
        rows.append(d)
    rows.sort(key=lambda d: (d["arch"], d["shape"]))
    return rows


def render(rows: list) -> str:
    out = ["| arch | shape | t_compute | t_memory | t_collective | bound | "
           "MODEL_FLOPS | useful | roofline | fit GB/dev |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for d in rows:
        r = d["roofline"]
        out.append(
            f"| {d['arch']} | {d['shape']} | {r['t_compute_s']:.4f}s | "
            f"{r['t_memory_s']:.4f}s | {r['t_collective_s']:.4f}s | "
            f"**{r['bottleneck'][:4]}** | {d['model_flops']:.2e} | "
            f"{d['useful_flops_frac']:.2f} | {d['roofline_frac']:.3f} | "
            f"{d['hbm_fit_bytes']/2**30:.2f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16",
                    help="mesh shape whose dryrun cells to tabulate")
    ap.add_argument("--tag", default="",
                    help="optional result-set tag suffix to load")
    args = ap.parse_args()
    rows = load(args.mesh, args.tag)
    print(f"### Roofline table — mesh {args.mesh} ({len(rows)} cells)\n")
    print(render(rows))
    print("\nPer-cell dominant-term notes:")
    for d in rows:
        r = d["roofline"]
        print(f"- **{d['arch']} x {d['shape']}** ({r['bottleneck']}-bound): "
              f"{MOVE[r['bottleneck']]}.")


if __name__ == "__main__":
    main()
