"""§Perf hillclimbing driver: runs tagged variants of the three chosen
cells and prints before/after roofline terms per iteration.

  PYTHONPATH=src:. python benchmarks/hillclimb.py --cell qwen_train --it 1
"""
from __future__ import annotations

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json

OUT = "experiments/hillclimb"


def run(cell: str, iteration: int):
    from repro.launch.dryrun import run_cell
    from repro.launch.mesh import make_production_mesh
    from repro.launch.sharding import default_rules

    mesh = make_production_mesh()

    if cell == "qwen_train":
        if iteration == 1:
            # H1: TP-16 activation all-reduces dominate (214 GB wire). Pure
            # FSDP/DP-256 (no TP) removes them; params gather instead.
            rules = dataclasses.replace(
                default_rules(mesh, fsdp=True),
                batch=("data", "model"), fsdp=("data", "model"),
                heads=None, ff=None, vocab=None, experts=None)
            # B=256 global over 256-way DP -> 1 seq/device, n_micro=1
            return run_cell("qwen2.5-3b", "train_4k", rules_override=rules,
                            tag="hc1_fsdp256", n_micro_override=1,
                            out_dir=OUT)
        if iteration == 2:
            # H2: fp32 param gathers waste 2x wire; cast to bf16 pre-gather.
            rules = dataclasses.replace(
                default_rules(mesh, fsdp=True),
                batch=("data", "model"), fsdp=("data", "model"),
                heads=None, ff=None, vocab=None, experts=None)
            return run_cell("qwen2.5-3b", "train_4k", rules_override=rules,
                            tag="hc2_fsdp256_bf16", cast_bf16=True,
                            n_micro_override=1, out_dir=OUT)
        if iteration == 3:
            # H3: gradient reduce-scatters still move fp32 (~25 GB wire);
            # differentiate wrt bf16 params so grad collectives are bf16.
            rules = dataclasses.replace(
                default_rules(mesh, fsdp=True),
                batch=("data", "model"), fsdp=("data", "model"),
                heads=None, ff=None, vocab=None, experts=None)
            return run_cell("qwen2.5-3b", "train_4k", rules_override=rules,
                            tag="hc3_fsdp256_bf16grads", cast_bf16=True,
                            grads_bf16=True, n_micro_override=1,
                            out_dir=OUT)
        if iteration == 4:
            # H4: full remat recomputes every matmul in the backward
            # (~8N·D vs 6N·D); checkpoint_dots saves matmul outputs
            # (memory allows at 1 seq/device) cutting compute ~25%.
            rules = dataclasses.replace(
                default_rules(mesh, fsdp=True),
                batch=("data", "model"), fsdp=("data", "model"),
                heads=None, ff=None, vocab=None, experts=None)
            return run_cell("qwen2.5-3b", "train_4k", rules_override=rules,
                            tag="hc4_fsdp256_dots", cast_bf16=True,
                            grads_bf16=True, n_micro_override=1,
                            remat_dots=True, out_dir=OUT)

        if iteration == 5:
            # H5: the CE gather over vocab-parallel logits all-gathers
            # (B,S,V); one-hot contraction keeps it local (+tiny psum).
            rules = dataclasses.replace(
                default_rules(mesh, fsdp=True),
                batch=("data", "model"), fsdp=("data", "model"),
                heads=None, ff=None, vocab=None, experts=None)
            return run_cell("qwen2.5-3b", "train_4k", rules_override=rules,
                            tag="hc5_fsdp256_onehot_ce", cast_bf16=True,
                            grads_bf16=True, n_micro_override=1,
                            remat_dots=True, ce_onehot=True, out_dir=OUT)

    if cell == "dbrx_decode":
        if iteration == 1:
            # H1: per-step FSDP weight gathers dominate decode. 2D expert
            # sharding (E over model, F over data) keeps every weight
            # resident and local; only tiny activation reduces remain.
            rules = dataclasses.replace(
                default_rules(mesh, fsdp=True),
                fsdp="data", moe_ff="data", kv_seq=("model",))
            return run_cell("dbrx-132b", "decode_32k", rules_override=rules,
                            tag="hc1_2dep", out_dir=OUT)

        if iteration == 2:
            # H2: remaining 50 ms wire = FSDP gathers of attn/embed params.
            # TP already shards them 16-way over `model`; drop fsdp so every
            # non-MoE weight is resident too (fits: ~0.6 GB/device).
            rules = dataclasses.replace(
                default_rules(mesh, fsdp=False), moe_ff="data",
                kv_seq=("model",))
            return run_cell("dbrx-132b", "decode_32k", rules_override=rules,
                            tag="hc2_2dep_tponly", out_dir=OUT)
        if iteration == 3:
            # H3: same as H2 + KV cache sequence sharded over `model`
            # (flash-decode) — the replicated cache of H1/H2 doesn't fit
            # HBM; sharding S also parallelizes the attention reads.
            rules = dataclasses.replace(
                default_rules(mesh, fsdp=False), moe_ff="data",
                kv_seq=("model",))
            return run_cell("dbrx-132b", "decode_32k", rules_override=rules,
                            tag="hc3_2dep_tponly_kvseq", out_dir=OUT)

    if cell == "gemma_decode":
        if iteration == 0:
            # BEFORE: naive decode reads the full 32k cache in every layer
            # (window masks applied after the fact) — force by treating all
            # layers as global.
            import repro.configs as C
            cfg = C.get_config("gemma3-1b")
            import repro.configs.gemma3_1b as G
            G.CONFIG = dataclasses.replace(cfg, sliding_window=None,
                                           global_every=None)
            try:
                return run_cell("gemma3-1b", "decode_32k",
                                tag="hc0_fullreads", out_dir=OUT)
            finally:
                G.CONFIG = cfg
        if iteration == 1:
            # H1: windowed KV reads (local layers read 512 of 32768 slots).
            # The optimization is in the model (static windows under
            # unroll); baseline JSONs predate it, so re-run = measure.
            return run_cell("gemma3-1b", "decode_32k", tag="hc1_windowed",
                            out_dir=OUT)

    raise SystemExit(f"unknown cell/iteration {cell}/{iteration}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    help="hillclimb cell name (arch/mesh pair) to run")
    ap.add_argument("--it", type=int, required=True,
                    help="iteration index within the cell's schedule")
    args = ap.parse_args()
    rec = run(args.cell, args.it)
    r = rec["roofline"]
    print(json.dumps({k: r[k] for k in
                      ("t_compute_s", "t_memory_s", "t_collective_s",
                       "bottleneck")}, indent=1))


def bonus_gemma_train():
    """Bonus cell: gemma3-1b train is the worst collective case relative to
    size (t_n=9.1 s for a 1B model) — its 262k vocab makes the CE gather
    over vocab-parallel logits brutal. One-hot CE keeps it local."""
    import os
    from repro.launch.dryrun import run_cell
    return run_cell("gemma3-1b", "train_4k", ce_onehot=True,
                    tag="bonus_onehot_ce", out_dir=OUT)
