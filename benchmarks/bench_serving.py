"""Domain-adaptation benchmark: LM serving density through the Hydra
runtime — continuous batching slots vs sequential decoding (the
many-isolates-per-runtime effect at the token level)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import HydraRuntime, LMSpec
from repro.core.scheduler import ContinuousBatcher
from repro.models.programs import ModelProgram

N_REQ = 6
MAX_NEW = 8


def run() -> list:
    cfg = get_config("qwen2.5-3b").reduced()
    prog = ModelProgram(cfg)
    params = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x,
        prog.init(jax.random.PRNGKey(0)))
    rt = HydraRuntime(memory_budget_bytes=4 << 30, janitor=False)
    rows = []
    try:
        rt.register_function("lm1", LMSpec(cfg=cfg, params=params,
                                           max_seq=64, slots=1))
        rt.register_function("lm4", LMSpec(cfg=cfg, params=params,
                                           max_seq=64, slots=4))
        prompt = list(range(8))
        rt.generate("lm1", prompt, max_new_tokens=MAX_NEW)   # warm compiles

        t0 = time.perf_counter()
        for _ in range(N_REQ):
            rt.generate("lm1", prompt, max_new_tokens=MAX_NEW)
        seq_s = time.perf_counter() - t0

        warm = ContinuousBatcher(rt, "lm4")
        wf = warm.submit(prompt, 2)
        warm.run_until_done()
        wf.result()
        warm.close()

        b = ContinuousBatcher(rt, "lm4")
        futs = [b.submit(prompt, MAX_NEW) for _ in range(N_REQ)]
        t0 = time.perf_counter()
        b.run_until_done()
        bat_s = time.perf_counter() - t0
        for f in futs:
            f.result()
        b.close()

        tok = N_REQ * MAX_NEW
        rows.append({"name": "serving.sequential",
                     "us_per_call": seq_s / tok * 1e6,
                     "derived": f"tok_per_s={tok/seq_s:.1f}"})
        rows.append({"name": "serving.continuous_batch4",
                     "us_per_call": bat_s / tok * 1e6,
                     "derived": f"tok_per_s={tok/bat_s:.1f};"
                                f"speedup={seq_s/bat_s:.2f}x"})
    finally:
        rt.shutdown()
    return rows
