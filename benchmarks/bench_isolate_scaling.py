"""Fig 3 analog: isolate (arena) startup time and per-isolate footprint as
concurrent isolates scale up."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.arena import ArenaPool


def run() -> list:
    rows = []
    factory = lambda: {"kv": jnp.zeros((256, 1024), jnp.float32)}  # 1 MB
    for n in (1, 8, 32, 128):
        pool = ArenaPool(ttl_s=3600)
        times = []
        arenas = []
        for _ in range(n):
            t0 = time.perf_counter()
            arenas.append(pool.acquire(("kv",), factory))
            times.append(time.perf_counter() - t0)
        per_iso = sum(a.nbytes for a in arenas) / n
        rows.append({
            "name": f"isolate_scaling.n{n}",
            "us_per_call": float(np.mean(times)) * 1e6,
            "derived": f"p99_us={float(np.percentile(times,99))*1e6:.0f};"
                       f"bytes_per_isolate={per_iso:.0f}",
        })
        for a in arenas:
            pool.release(a)
        pool.drain()
    return rows
