"""Roofline terms derived from the compiled dry-run (re-export).

See src/repro/launch/roofline.py for the implementation and formulas.
"""
from repro.launch.roofline import Roofline, analyze, collective_bytes

__all__ = ["Roofline", "analyze", "collective_bytes"]
