"""Fig 5 + Fig 8 analog: AOT-at-registration vs JIT-on-first-request, and
runtime-cold vs isolate-cold conversion.

Fig 5: CDF of the first 10 request latencies — Hydra compiles at
registration so request #1 is as fast as request #10; the baseline pays the
full compile on request #1.
Fig 8: cold-start hierarchy — new runtime vs new isolate vs pooled isolate.
"""
from __future__ import annotations

import tempfile
import time

import jax
import numpy as np

from benchmarks.functions import catalog, example_args
from repro.core import HydraPlatform, HydraRuntime


def run() -> list:
    rows = []
    spec = catalog()["jv/filehashing"]
    args = example_args(spec)

    # --- Hydra: AOT at registration ---
    rt = HydraRuntime(janitor=False)
    t_reg0 = time.perf_counter()
    rt.register_function("f", spec)
    runtime_cold_s = time.perf_counter() - t_reg0
    lat_aot = []
    for _ in range(10):
        t0 = time.perf_counter()
        rt.invoke("f", args)
        lat_aot.append(time.perf_counter() - t0)
    rt.shutdown()

    # --- baseline: compile on first request (per-worker JIT) ---
    raw = spec.fn
    fn = jax.jit(lambda p, a: raw(p, a))   # fresh closure: true cold compile
    lat_jit = []
    for i in range(10):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(spec.params, args))
        lat_jit.append(time.perf_counter() - t0)

    p99_aot = float(np.percentile(lat_aot, 99))
    p99_jit = float(np.percentile(lat_jit, 99))
    rows.append({"name": "coldstart.first10_aot_p99",
                 "us_per_call": p99_aot * 1e6,
                 "derived": f"first={lat_aot[0]*1e6:.0f}us"})
    rows.append({"name": "coldstart.first10_jit_p99",
                 "us_per_call": p99_jit * 1e6,
                 "derived": f"first={lat_jit[0]*1e6:.0f}us;"
                            f"tail_reduction={p99_jit/max(p99_aot,1e-9):.1f}x"})

    # --- Fig 8: runtime cold vs isolate cold/warm ---
    rt = HydraRuntime(janitor=False)
    rt.register_function("f", spec)
    rt.invoke("f", args)                       # arena cold happens here
    snap = rt.metrics.snapshot()
    arena_cold_s = snap["hists"]["arena.alloc_s"]["mean"]
    t0 = time.perf_counter()
    rt.invoke("f", args)                       # pooled arena
    warm_invoke_s = time.perf_counter() - t0
    rt.shutdown()
    rows.append({"name": "coldstart.runtime_cold",
                 "us_per_call": runtime_cold_s * 1e6,
                 "derived": f"vs_isolate_cold="
                            f"{runtime_cold_s/max(arena_cold_s,1e-9):.0f}x"})
    rows.append({"name": "coldstart.isolate_cold",
                 "us_per_call": arena_cold_s * 1e6, "derived": "arena_alloc"})
    rows.append({"name": "coldstart.isolate_warm_invoke",
                 "us_per_call": warm_invoke_s * 1e6, "derived": "pool_hit"})

    # --- platform layer: pre-warmed pool claim vs runtime cold boot, and
    # snapshot restore (shared-exe-cache hit) vs first registration ---
    with tempfile.TemporaryDirectory() as snap_dir:
        plat = HydraPlatform(pool_size=1, snapshot_dir=snap_dir,
                             refill=False)
        t0 = time.perf_counter()
        plat.register_function("f", spec)        # compiles (first install)
        plat.invoke("f", args)                   # claims the pooled runtime
        first_place_s = time.perf_counter() - t0
        boot_s = plat.metrics.hists["runtime_boot_s"].mean
        plat.snapshot("f")
        plat.evict("f")
        c0 = plat.exe_cache.stats()["compiles"]
        t0 = time.perf_counter()
        plat.restore("f")                        # re-register: cache hit
        restore_s = time.perf_counter() - t0
        recompiles = plat.exe_cache.stats()["compiles"] - c0
        plat.shutdown()
    rows.append({"name": "coldstart.pool_first_invoke",
                 "us_per_call": first_place_s * 1e6,
                 "derived": f"runtime_boot_off_path={boot_s*1e6:.0f}us"})
    rows.append({"name": "coldstart.snapshot_restore",
                 "us_per_call": restore_s * 1e6,
                 "derived": f"recompiles={recompiles}"})
    return rows
