"""Shared exception-aware dataflow engine for hydralint checkers.

The HL001–HL008 checkers are per-function syntactic walks; the bug
classes PR 4/5/8 fixed by hand (exception-unsafe ``_try_admit``
rollback, leaked claims on error paths) are *flow* properties: what
happens on the paths an exception takes.  This module provides the two
layers those checkers kept re-implementing badly or not at all:

* :func:`build_cfg` — an intraprocedural control-flow graph over a
  function body with explicit **exception edges**: every statement has
  normal successors (``succ``) and exceptional successors (``esucc``)
  leading to the matching ``except`` dispatch, through ``finally``
  blocks (duplicated per continuation, so a normal path through a
  ``finally`` is never conflated with an exceptional one), through
  ``with`` exits, and ultimately to the function's virtual ``raise``
  node.  ``return``/``break``/``continue`` are threaded through
  enclosing ``finally`` blocks the way the runtime threads them.

* :class:`Summaries` — an interprocedural may-summary layer over the
  same call-graph resolution HL002 uses (``purity._Graph``): a checker
  supplies a *direct* per-function summary extractor and the class runs
  the fixpoint so one-line helper wrappers (``def _teardown(self, rt):
  self._return_runtime(rt)``) are understood at their call sites.

Checkers built on top: HL009 (resource lifecycle, ``lifecycle.py``)
and HL010 (exception safety under locks, ``exsafety.py``).  The CFG is
deliberately over-approximate — extra edges, never missing ones —
except that exception edges are only *followed* by analyses for
statements that contain a call that can plausibly raise
(:func:`raising_calls`); ``x = a`` does not manufacture a phantom
error path.
"""
from __future__ import annotations

import ast
from collections import namedtuple
from typing import Callable, Optional

from tools.hydralint import dotted_name
from tools.hydralint.purity import RESOLVE_STOPLIST, _Graph, _import_aliases

__all__ = ["CFG", "CFGNode", "build_cfg", "raising_calls", "Summaries",
           "FlowGraph"]


# ---------------------------------------------------------------------------
# CFG

class CFGNode:
    """One CFG node.  ``kind`` is ``entry``/``exit``/``raise`` for the
    virtual boundary nodes, a statement kind otherwise.  ``stmt`` is the
    originating AST node (shared by the virtual nodes a compound
    statement expands into)."""

    __slots__ = ("idx", "stmt", "kind", "succ", "esucc")

    def __init__(self, idx: int, stmt, kind: str):
        self.idx = idx
        self.stmt = stmt
        self.kind = kind
        self.succ: list = []      # normal-completion successors
        self.esucc: list = []     # where control goes if this raises

    def __repr__(self):
        ln = getattr(self.stmt, "lineno", "-")
        return f"<CFGNode {self.idx} {self.kind} L{ln}>"


# Kinds whose node carries real user code an analysis should inspect.
STMT_KINDS = frozenset({"stmt", "return", "raise-stmt", "branch", "loop",
                        "with-enter", "break", "continue", "def", "except"})


class CFG:
    def __init__(self):
        self.nodes: list = []
        self.entry = self._new(None, "entry").idx
        self.exit = self._new(None, "exit").idx
        self.raise_ = self._new(None, "raise").idx

    def _new(self, stmt, kind: str) -> CFGNode:
        n = CFGNode(len(self.nodes), stmt, kind)
        self.nodes.append(n)
        return n

    # -- small query helpers (used by checkers and the CFG tests) ----------
    def nodes_at(self, lineno: int, kind: Optional[str] = None) -> list:
        out = []
        for n in self.nodes:
            if getattr(n.stmt, "lineno", None) != lineno:
                continue
            if kind is None or n.kind == kind:
                out.append(n)
        return out

    def has_path(self, src: int, dst: int, exceptional: bool = True) -> bool:
        """Is ``dst`` reachable from ``src`` (following exception edges
        too unless ``exceptional=False``)?"""
        seen, todo = set(), [src]
        while todo:
            i = todo.pop()
            if i == dst:
                return True
            if i in seen:
                continue
            seen.add(i)
            n = self.nodes[i]
            todo.extend(n.succ)
            if exceptional:
                todo.extend(n.esucc)
        return False


_Ctx = namedtuple("_Ctx", "exc ret brk cont")

_SUPPRESS_NAMES = {"suppress", "contextlib.suppress"}
_CATCH_ALL = {"Exception", "BaseException"}


def _is_suppress(w) -> bool:
    for item in w.items:
        e = item.context_expr
        if isinstance(e, ast.Call):
            name = dotted_name(e.func)
            if name in _SUPPRESS_NAMES:
                return True
    return False


class _Builder:
    def __init__(self, cfg: CFG):
        self.cfg = cfg

    def node(self, stmt, kind: str) -> CFGNode:
        return self.cfg._new(stmt, kind)

    def wire(self, preds, idx: int) -> None:
        for p in preds:
            if idx not in self.cfg.nodes[p].succ:
                self.cfg.nodes[p].succ.append(idx)

    def body(self, stmts, preds, ctx: _Ctx):
        for s in stmts:
            preds = self.stmt(s, preds, ctx)
            if not preds:       # everything after return/raise is dead
                break
        return preds

    def stmt(self, s, preds, ctx: _Ctx):
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef)):
            n = self.node(s, "def")      # nested scope: no flow into body
            self.wire(preds, n.idx)
            return {n.idx}
        if isinstance(s, ast.Return):
            n = self.node(s, "return")
            self.wire(preds, n.idx)
            n.succ.append(ctx.ret)
            n.esucc.append(ctx.exc)      # the return expression may raise
            return set()
        if isinstance(s, ast.Raise):
            n = self.node(s, "raise-stmt")
            self.wire(preds, n.idx)
            n.esucc.append(ctx.exc)
            return set()
        if isinstance(s, ast.Break):
            n = self.node(s, "break")
            self.wire(preds, n.idx)
            if ctx.brk is not None:
                n.succ.append(ctx.brk)
            return set()
        if isinstance(s, ast.Continue):
            n = self.node(s, "continue")
            self.wire(preds, n.idx)
            if ctx.cont is not None:
                n.succ.append(ctx.cont)
            return set()
        if isinstance(s, ast.If):
            n = self.node(s, "branch")
            self.wire(preds, n.idx)
            n.esucc.append(ctx.exc)
            out = self.body(s.body, {n.idx}, ctx)
            if s.orelse:
                out = out | self.body(s.orelse, {n.idx}, ctx)
            else:
                out = out | {n.idx}
            return out
        if isinstance(s, (ast.While, ast.For, ast.AsyncFor)):
            test = self.node(s, "loop")
            self.wire(preds, test.idx)
            test.esucc.append(ctx.exc)   # iterator / test may raise
            after = self.node(s, "loop-exit")
            inner = ctx._replace(brk=after.idx, cont=test.idx)
            out = self.body(s.body, {test.idx}, inner)
            self.wire(out, test.idx)
            if s.orelse:
                oout = self.body(s.orelse, {test.idx}, ctx)
                self.wire(oout, after.idx)
            else:
                test.succ.append(after.idx)
            return {after.idx}
        if isinstance(s, (ast.With, ast.AsyncWith)):
            enter = self.node(s, "with-enter")
            self.wire(preds, enter.idx)
            enter.esucc.append(ctx.exc)  # __enter__/ctx expr may raise
            exit_n = self.node(s, "with-exit")
            exc_n = self.node(s, "with-exit-exc")
            exc_n.succ.append(ctx.exc)   # __exit__ re-raises ...
            if _is_suppress(s):
                exc_n.succ.append(exit_n.idx)   # ... or swallows
            inner = ctx._replace(exc=exc_n.idx)
            out = self.body(s.body, {enter.idx}, inner)
            self.wire(out, exit_n.idx)
            return {exit_n.idx}
        if isinstance(s, ast.Try):
            return self.try_(s, preds, ctx)
        n = self.node(s, "stmt")
        self.wire(preds, n.idx)
        n.esucc.append(ctx.exc)
        return {n.idx}

    def try_(self, t: ast.Try, preds, ctx: _Ctx):
        after = self.node(t, "try-exit")

        if t.finalbody:
            memo: dict = {}

            def thread(target):
                """Route a continuation through a per-target copy of the
                finally body (copies keep normal and exceptional passes
                through the finally distinct)."""
                if target is None:
                    return None
                if target not in memo:
                    j = self.node(t, "finally")
                    memo[target] = j.idx
                    out = self.body(t.finalbody, {j.idx}, ctx)
                    self.wire(out, target)
                return memo[target]
        else:
            def thread(target):
                return target

        inner = _Ctx(exc=thread(ctx.exc), ret=thread(ctx.ret),
                     brk=thread(ctx.brk), cont=thread(ctx.cont))

        if t.handlers:
            dispatch = self.node(t, "except-dispatch")
            catch_all = any(
                h.type is None or
                (dotted_name(h.type) or "").split(".")[-1] in _CATCH_ALL
                for h in t.handlers)
            if not catch_all:
                dispatch.succ.append(inner.exc)   # may match no handler
            body_exc = dispatch.idx
        else:
            dispatch = None
            body_exc = inner.exc

        out = self.body(t.body, preds, inner._replace(exc=body_exc))
        if t.orelse:
            out = self.body(t.orelse, out, inner)
        hout: set = set()
        for h in t.handlers:
            hentry = self.node(h, "except")
            dispatch.succ.append(hentry.idx)
            hout |= self.body(h.body, {hentry.idx}, inner)
        tgt = thread(after.idx)
        self.wire(out | hout, tgt)
        return {after.idx}


def build_cfg(func) -> CFG:
    """CFG for a FunctionDef/AsyncFunctionDef body."""
    cfg = CFG()
    b = _Builder(cfg)
    ctx = _Ctx(exc=cfg.raise_, ret=cfg.exit, brk=None, cont=None)
    out = b.body(func.body, {cfg.entry}, ctx)
    b.wire(out, cfg.exit)
    return cfg


# ---------------------------------------------------------------------------
# "can this statement plausibly raise" — shared by HL009/HL010 so both
# checkers agree on which exception edges are real error paths.

# Call leaf names that do not raise under normal operation (container /
# sync primitives from HL002's stoplist, plus benign builtins, clock
# reads, metric emits, and span/trace plumbing that is pure by HL008).
BENIGN_CALLS = frozenset(RESOLVE_STOPLIST) | {
    "len", "isinstance", "issubclass", "getattr", "setattr", "hasattr",
    "min", "max", "abs", "sum", "sorted", "reversed", "list", "dict",
    "set", "tuple", "frozenset", "deque", "int", "float", "str", "bool",
    "repr", "id", "range", "zip", "enumerate", "print", "round", "vars",
    "perf_counter", "monotonic", "time", "now", "popleft", "appendleft",
    "span", "inc", "observe", "hist", "timeit", "debug", "info",
    "warning", "exception", "lower", "upper", "rstrip", "lstrip",
    "locked", "total_seconds", "bit_length", "hex",
    # clock/sleep + trace plumbing (pure by HL008) + RNG methods: none
    # of these raise under normal operation
    "sleep", "trace_now", "add_span", "randrange", "randint", "random",
    "uniform", "gauss", "choice", "shuffle", "getrandbits",
}
# Imported-module roots whose functions are treated as non-raising.
BENIGN_ROOTS = ("math", "bisect", "heapq", "itertools", "collections",
                "statistics", "logging", "random", "string", "re")


def raising_calls(tree, aliases: Optional[dict] = None) -> list:
    """Call nodes in ``tree`` that can plausibly raise.  Benign leaf
    names and calls rooted at benign stdlib modules are excluded, as are
    CapWords constructor calls (dataclass/exception construction)."""
    aliases = aliases or {}
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        if name is None:            # computed callee, e.g. factories[k]()
            out.append(node)
            continue
        parts = name.split(".")
        root = aliases.get(parts[0], parts[0]).split(".")[0]
        if root in BENIGN_ROOTS:
            continue
        leaf = parts[-1]
        if leaf in BENIGN_CALLS:
            continue
        bare = leaf.lstrip("_")
        if bare[:1].isupper():      # constructor / exception instantiation
            continue
        out.append(node)
    return out


def node_exprs(n: CFGNode) -> list:
    """The AST fragments a CFG node actually *executes* (a ``branch``
    node executes its test, not its body — the body has its own
    nodes)."""
    s = n.stmt
    if s is None:
        return []
    if n.kind == "branch":
        return [s.test]
    if n.kind == "loop":
        if isinstance(s, ast.While):
            return [s.test]
        return [s.iter, s.target]
    if n.kind == "with-enter":
        return [item.context_expr for item in s.items]
    if n.kind in ("stmt", "return", "raise-stmt", "except"):
        return [s]
    return []       # virtual joins, finally headers, defs


# ---------------------------------------------------------------------------
# Interprocedural summary layer

class FlowGraph:
    """Per-project cache of CFGs plus the HL002 name-resolved call
    graph, so checkers share both."""

    def __init__(self, project):
        self.project = project
        self.graph = _Graph(project)
        self._cfgs: dict = {}

    def cfg(self, path: str, fi) -> CFG:
        key = (path, fi.qualname)
        if key not in self._cfgs:
            self._cfgs[key] = build_cfg(fi.node)
        return self._cfgs[key]

    def aliases(self, path: str) -> dict:
        return self.graph.aliases.get(path, {})


class Summaries:
    """May-summaries over the project call graph.

    A checker provides ``direct(sf, fi) -> set`` extracting facts that
    hold *directly* in a function body (e.g. "releases parameter
    ``rt``"), expressed as ``(tag, param_name)`` pairs over the
    function's own parameters.  The fixpoint then lifts the facts
    through call sites: if ``g(self, x)`` passes its parameter ``x``
    to ``f`` at a position ``f`` summarizes, ``g`` inherits the fact —
    so helper wrappers around a release API are recognized wherever
    they are called.  Resolution is the HL002 one: over-approximate by
    method name, never through imported modules or stoplisted names.
    """

    def __init__(self, flowgraph: FlowGraph,
                 direct: Callable[[object, object], set]):
        self.fg = flowgraph
        g = flowgraph.graph
        # (path, qualname) -> {(tag, param_index)}
        self.facts: dict = {}
        params: dict = {}
        for (path, qn), (sf, fi) in g.by_qualname.items():
            names = [a.arg for a in fi.node.args.args]
            if names and names[0] in ("self", "cls"):
                names = names[1:]
            params[(path, qn)] = names
            got = set()
            for tag, pname in direct(sf, fi):
                if pname in names:
                    got.add((tag, names.index(pname)))
            if got:
                self.facts[(path, qn)] = got

        # fixpoint: lift through call sites
        changed = True
        while changed:
            changed = False
            for (path, qn), (sf, fi) in g.by_qualname.items():
                names = params[(path, qn)]
                if not names:
                    continue
                have = self.facts.setdefault((path, qn), set())
                for call in ast.walk(fi.node):
                    if not isinstance(call, ast.Call):
                        continue
                    for tgt in self._resolve(path, call):
                        for tag, i in self.facts.get(tgt, ()):
                            arg = self._pos_arg(call, i)
                            if isinstance(arg, ast.Name) \
                                    and arg.id in names:
                                fact = (tag, names.index(arg.id))
                                if fact not in have:
                                    have.add(fact)
                                    changed = True

    @staticmethod
    def _pos_arg(call: ast.Call, i: int):
        if i < len(call.args):
            return call.args[i]
        return None

    def _resolve(self, path: str, call: ast.Call) -> list:
        g = self.fg.graph
        name = dotted_name(call.func)
        if name is None:
            return []
        parts = name.split(".")
        aliases = g.aliases.get(path, {})
        out = []
        if len(parts) == 1:
            leaf = parts[0]
            key = (path, leaf)
            if key in g.by_qualname:
                out.append(key)
        else:
            if parts[0] in aliases and parts[0] not in ("self", "cls"):
                return []
            leaf = parts[-1]
            if leaf in RESOLVE_STOPLIST:
                return []
            for tgt in g.by_method.get(leaf, ()):
                if "." in tgt[1]:
                    out.append(tgt)
        return out

    def call_facts(self, path: str, call: ast.Call) -> set:
        """``(tag, arg_node)`` facts a call site triggers: for every
        resolved callee fact ``(tag, i)``, the argument actually passed
        at position ``i``."""
        out = set()
        for tgt in self._resolve(path, call):
            for tag, i in self.facts.get(tgt, ()):
                arg = self._pos_arg(call, i)
                if arg is not None:
                    out.add((tag, arg))
        return out


# re-exported for checkers that need import-alias maps without pulling
# purity's checker machinery
import_aliases = _import_aliases
