"""HL010 — exception safety under locks: no partial state on raise.

History: PR 4's ``_try_admit`` admitted optimistically — it set
``rec.runtime = rt`` under the platform lock, then the placement path
called ``rt.register_function(...)``; when registration raised, every
later cleanup write was skipped and the record stayed half-admitted
(claimed runtime, no registration), corrupting the density accounting
until PR 4 added the ``except BaseException: rec.runtime = None;
raise`` rollback by hand.  This checker machine-checks the class.

The shape flagged: inside a held-lock region (``with <lock>:``), a
state mutation **W1** (attribute/subscript write or container-mutator
call on an attribute), then a call **C** that can plausibly raise
(``flow.raising_calls``), then a further state mutation **W2** on the
same path.  If C raises, W1 is committed and W2 never happens — the
multi-field update tears.  Not flagged:

* W1 writes a bare constant (``rec.runtime = None`` is itself a
  rollback/reset — there is no partial state to tear);
* C sits inside a ``try`` whose handlers or ``finally`` write W1's
  target back (the PR 4 fix shape);
* local-variable writes (locals die with the frame — nothing shared
  tears).

Fix by reordering (do the raising work before the first mutation),
or by adding the rollback handler.  Suppress with ``# hydralint:
disable=HL010`` plus a justification when the intervening call is
provably non-raising.
"""
from __future__ import annotations

import ast
from typing import Optional

from tools.hydralint import Finding, Project, dotted_name
from tools.hydralint import flow

CODE = "HL010"

# with-context receivers that mean "a lock is held"
_LOCK_HINTS = ("lock", "_cv", "cv", "mutex")

_MUTATORS = {"append", "add", "extend", "insert", "appendleft", "put",
             "put_nowait", "setdefault", "update", "pop", "popleft",
             "remove", "discard", "clear"}


def _is_lockish_ctx(expr) -> bool:
    if isinstance(expr, ast.Call):       # e.g. with self._lock_for(x):
        expr = expr.func
    name = dotted_name(expr)
    if name is None:
        return False
    leaf = name.split(".")[-1].lower()
    return any(h in leaf for h in _LOCK_HINTS)


def _write_keys(stmt, constant_ok: bool = True) -> list:
    """State-mutation keys ``(base, attr)`` in one statement: attribute
    or subscript-of-attribute assignments, and container-mutator calls
    on attributes.  ``constant_ok=False`` drops writes of bare
    constants (resets), which cannot tear."""
    out = []

    def target_key(t) -> Optional[tuple]:
        while isinstance(t, ast.Subscript):
            t = t.value
        if isinstance(t, ast.Attribute):
            base = dotted_name(t.value)
            if base is not None:
                return (base, t.attr)
        return None

    for node in ast.walk(stmt):
        if isinstance(node, ast.Assign):
            if not constant_ok and isinstance(node.value, ast.Constant):
                continue
            for t in node.targets:
                k = target_key(t)
                if k is not None:
                    out.append(k)
        elif isinstance(node, ast.AugAssign):
            k = target_key(node.target)
            if k is not None:
                out.append(k)
        elif isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in _MUTATORS:
            k = target_key(node.func.value)
            if k is not None and (node.args or node.keywords):
                out.append(k)
    return out


class _RegionScan:
    """Ordered scan of one held-lock region, branch-sensitive (if/else
    arms scanned independently from a copy of the incoming state, then
    merged) and loop-body-once (under-approximate)."""

    def __init__(self, sf, fi, aliases):
        self.sf = sf
        self.fi = fi
        self.aliases = aliases
        self.findings: list = []
        self.flagged: set = set()

    def scan(self, stmts, writes: set, pending: list,
             protected: frozenset):
        for s in stmts:
            if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef)):
                continue
            if isinstance(s, ast.If):
                w1 = set(writes)
                p1 = list(pending)
                self.scan(s.body, w1, p1, protected)
                w2 = set(writes)
                p2 = list(pending)
                self.scan(s.orelse, w2, p2, protected)
                writes |= w1 | w2
                pending[:] = p1 + [p for p in p2 if p not in p1]
                continue
            if isinstance(s, (ast.While, ast.For, ast.AsyncFor)):
                self.scan(s.body, writes, pending, protected)
                self.scan(s.orelse, writes, pending, protected)
                continue
            if isinstance(s, (ast.With, ast.AsyncWith)):
                self.scan(s.body, writes, pending, protected)
                continue
            if isinstance(s, ast.Try):
                rollback = frozenset(
                    k for h in s.handlers for st in h.body
                    for k in _write_keys(st)) | frozenset(
                    k for st in s.finalbody for k in _write_keys(st))
                self.scan(s.body, writes, pending,
                          protected | rollback)
                self.scan(s.orelse, writes, pending, protected)
                for h in s.handlers:
                    self.scan(h.body, set(writes), list(pending),
                              protected)
                self.scan(s.finalbody, writes, pending, protected)
                continue
            self.simple(s, writes, pending, protected)

    def simple(self, s, writes: set, pending: list,
               protected: frozenset):
        # Flag pending calls from STRICTLY EARLIER statements before
        # arming this statement's own calls: a mutator call is its own
        # write (``self._q[k].appendleft(x)``) and cannot tear against
        # itself.
        w_armed = _write_keys(s, constant_ok=False)
        w_all = _write_keys(s)
        if w_all:
            for c, exposed in pending:
                key = id(c)
                if key in self.flagged:
                    continue
                self.flagged.add(key)
                w1 = ", ".join(sorted(f"{b}.{a}" for b, a in exposed))
                w2 = ", ".join(sorted({f"{b}.{a}" for b, a in w_all}))
                label = dotted_name(c.func) or "<call>"
                self.findings.append(Finding(
                    CODE, self.sf.path, c.lineno, c.col_offset,
                    f"{label}() may raise between state writes under a "
                    f"held lock in {self.fi.qualname}() — {w1} would "
                    f"stay committed while {w2} never happens; reorder "
                    f"or add a rollback except/finally",
                    f"{self.fi.qualname}:{label}:"
                    + "+".join(sorted(a for _b, a in exposed))))
            pending.clear()
        for c in flow.raising_calls(s, self.aliases):
            exposed = {w for w in writes if w not in protected}
            if exposed:
                pending.append((c, frozenset(exposed)))
        writes.update(w_armed)


def _own_withs(fn) -> list:
    """With statements in a function body, not descending into nested
    function/class scopes (those are scanned as their own functions)."""
    out: list = []
    todo = list(fn.body)
    while todo:
        node = todo.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        if isinstance(node, (ast.With, ast.AsyncWith)):
            out.append(node)
        todo.extend(ast.iter_child_nodes(node))
    return out


def check(project: Project) -> list:
    fg = flow.FlowGraph(project)
    findings = []
    for sf, fi in project.iter_funcs():
        aliases = fg.aliases(sf.path)
        scan = _RegionScan(sf, fi, aliases)
        for node in _own_withs(fi.node):
            if not any(_is_lockish_ctx(i.context_expr)
                       for i in node.items):
                continue
            scan.scan(node.body, set(), [], frozenset())
        findings.extend(scan.findings)
    return findings
