"""HL001 — lock discipline.

History: the ``Metrics`` histogram defaultdict race and the
``HydraPlatform`` optimistic-admission race (PR 4) were both the same
shape — an attribute written under ``self._lock`` in one method and
touched without it in another.

Two sub-rules, both per-class and purely syntactic:

  (a) *Mixed guarded access.*  If ``self._x`` is ever **written** inside
      a ``with self._lock:`` block (outside ``__init__``), then every
      read or write of ``self._x`` outside ``__init__`` must also hold
      that lock.  ``threading.Condition(self._lock)`` aliases to the
      same lock, and a private helper whose every in-class call site
      holds the lock is itself treated as lock-held (the documented
      "caller holds the lock" pattern, e.g. ``Gateway._next_request``).

  (b) *Unguarded read-modify-write in thread-owning classes.*  A class
      that spawns its own ``threading.Thread`` shares its attributes
      across threads by construction; ``self.x += 1`` outside any lock
      is a lost-update bug there even for "just a counter"
      (``Autoscaler.resizes`` / ``ClusterBalancer`` tick counters).
"""
from __future__ import annotations

import ast
from typing import Optional

from tools.hydralint import Finding, Project, dotted_name

CODE = "HL001"

_LOCK_FACTORIES = {"Lock", "RLock", "Condition", "Semaphore",
                   "BoundedSemaphore"}


def _lock_factory_name(call: ast.Call) -> Optional[str]:
    name = dotted_name(call.func)
    if name is None:
        return None
    base = name.split(".")[-1]
    return base if base in _LOCK_FACTORIES else None


class _ClassModel:
    """Lock attrs + every self-attr access site of one class."""

    def __init__(self, cls: ast.ClassDef):
        self.cls = cls
        self.lock_attrs: dict = {}     # attr -> canonical lock group name
        self.accesses: list = []       # (attr, method, line, col, write, aug, locked_groups)
        self.method_calls: dict = {}   # method -> [(callee, locked_groups)]
        self.methods: set = set()
        self.spawns_threads = False

    def group_of(self, attr: str) -> Optional[str]:
        return self.lock_attrs.get(attr)


def _collect_class(cls: ast.ClassDef) -> _ClassModel:
    model = _ClassModel(cls)

    # Pass 1: lock attributes (any method; normally __init__), with
    # Condition(self._lock) aliased to the wrapped lock's group.
    pending_alias = {}
    for node in ast.walk(cls):
        if not (isinstance(node, ast.Assign) and isinstance(node.value, ast.Call)):
            continue
        fac = _lock_factory_name(node.value)
        if fac is None:
            continue
        for tgt in node.targets:
            name = dotted_name(tgt)
            if not (name and name.startswith("self.") and name.count(".") == 1):
                continue
            attr = name.split(".", 1)[1]
            alias_of = None
            if fac == "Condition" and node.value.args:
                arg = dotted_name(node.value.args[0])
                if arg and arg.startswith("self."):
                    alias_of = arg.split(".", 1)[1]
            if alias_of is not None:
                pending_alias[attr] = alias_of
            else:
                model.lock_attrs[attr] = attr
    for attr, target in pending_alias.items():
        model.lock_attrs[attr] = model.lock_attrs.get(target, target)

    # Pass 2: per-method walk tracking which lock groups are held.
    for stmt in cls.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            model.methods.add(stmt.name)
            _walk_method(model, stmt)
    return model


def _with_lock_groups(model: _ClassModel, node: ast.With) -> set:
    groups = set()
    for item in node.items:
        expr = item.context_expr
        if isinstance(expr, ast.Call):   # e.g. self._lock.acquire() style: skip
            continue
        name = dotted_name(expr)
        if name and name.startswith("self.") and name.count(".") == 1:
            attr = name.split(".", 1)[1]
            grp = model.group_of(attr)
            if grp:
                groups.add(grp)
    return groups


_MUTATORS = {"append", "extend", "add", "update", "clear", "pop", "popitem",
             "remove", "discard", "insert", "setdefault", "appendleft"}


def _self_attr_of_container_write(node):
    """'x' when ``node`` mutates ``self.x`` through its container API:
    ``self.x[k] = v`` / ``del self.x[k]`` / ``self.x.append(...)``."""
    if isinstance(node, (ast.Assign, ast.AugAssign)):
        targets = node.targets if isinstance(node, ast.Assign) else [node.target]
        for tgt in targets:
            if isinstance(tgt, ast.Subscript):
                name = dotted_name(tgt.value)
                if name and name.startswith("self.") and name.count(".") == 1:
                    return name.split(".", 1)[1]
    if isinstance(node, ast.Delete):
        for tgt in node.targets:
            if isinstance(tgt, ast.Subscript):
                name = dotted_name(tgt.value)
                if name and name.startswith("self.") and name.count(".") == 1:
                    return name.split(".", 1)[1]
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
            and node.func.attr in _MUTATORS:
        name = dotted_name(node.func.value)
        if name and name.startswith("self.") and name.count(".") == 1:
            return name.split(".", 1)[1]
    return None


def _walk_method(model: _ClassModel, method) -> None:
    mname = method.name

    def visit(node, held: frozenset):
        if isinstance(node, ast.With):
            held = held | _with_lock_groups(model, node)
            for child in node.body:
                visit(child, held)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)) \
                and node is not method:
            # Nested defs/lambdas may run on another thread; analyze their
            # bodies as holding nothing.
            held = frozenset()
        if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
                and node.value.id == "self":
            write = isinstance(node.ctx, (ast.Store, ast.Del))
            model.accesses.append((node.attr, mname, node.lineno,
                                   node.col_offset, write, False, held))
        if isinstance(node, ast.AugAssign):
            name = dotted_name(node.target)
            if name and name.startswith("self.") and name.count(".") == 1:
                attr = name.split(".", 1)[1]
                model.accesses.append((attr, mname, node.lineno,
                                       node.col_offset, True, True, held))
        cw = _self_attr_of_container_write(node)
        if cw is not None:
            model.accesses.append((cw, mname, node.lineno,
                                   node.col_offset, True, False, held))
        if isinstance(node, ast.Call):
            cname = dotted_name(node.func)
            if cname and cname.startswith("self.") and cname.count(".") == 1:
                model.method_calls.setdefault(cname.split(".", 1)[1], []).append(
                    (mname, held))
            if cname and cname.split(".")[-1] == "Thread":
                model.spawns_threads = True
        for child in ast.iter_child_nodes(node):
            visit(child, held)

    for child in method.body:
        visit(child, frozenset())


def _lock_held_methods(model: _ClassModel) -> dict:
    """Fixpoint: private methods whose every in-class call site holds
    group G are treated as executing with G held ("caller holds the
    lock" helpers). Returns method -> frozenset(groups)."""
    held = {m: frozenset() for m in model.methods}
    changed = True
    while changed:
        changed = False
        for m in model.methods:
            if not m.startswith("_") or m in ("__init__", "__enter__", "__exit__"):
                continue
            sites = model.method_calls.get(m)
            if not sites:
                continue
            common = None
            for caller, site_held in sites:
                eff = site_held | held.get(caller, frozenset())
                common = eff if common is None else (common & eff)
            common = frozenset(common or ())
            if common and common != held[m]:
                held[m] = common
                changed = True
    return held


def check(project: Project) -> list:
    findings = []
    for sf in project.files:
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ClassDef):
                findings.extend(_check_class(sf.path, node))
    return findings


def _check_class(path: str, cls: ast.ClassDef) -> list:
    model = _collect_class(cls)
    out = []
    if not model.lock_attrs and not model.spawns_threads:
        return out
    extra_held = _lock_held_methods(model)

    # Rule (a): attrs written under a lock somewhere must always be
    # accessed under that lock.  Underscore attrs only — public attrs
    # are part of a cross-object surface the class can't police.
    guarded: dict = {}
    for attr, method, _ln, _col, write, _aug, held in model.accesses:
        eff = held | extra_held.get(method, frozenset())
        if write and method != "__init__" and attr.startswith("_") and eff:
            if model.group_of(attr):     # the lock objects themselves
                continue
            guarded.setdefault(attr, set()).update(eff)
    reported = set()
    for attr, method, ln, col, _write, _aug, held in model.accesses:
        if attr not in guarded or method == "__init__":
            continue
        eff = held | extra_held.get(method, frozenset())
        need = guarded[attr]
        if not (eff & need) and (method, attr) not in reported:
            reported.add((method, attr))
            lock = sorted(need)[0]
            out.append(Finding(
                CODE, path, ln, col,
                f"{cls.name}.{attr} is written under self.{lock} but accessed "
                f"in {method}() without it",
                f"{cls.name}.{method}:{attr}"))

    # Rule (b): read-modify-write outside any lock in a thread-owning class.
    if model.spawns_threads:
        seen = set()
        for attr, method, ln, col, _write, aug, held in model.accesses:
            if not aug or method == "__init__":
                continue
            eff = held | extra_held.get(method, frozenset())
            if eff or attr in guarded:
                continue     # guarded ones already handled by rule (a)
            k = (method, attr)
            if k in seen:
                continue
            seen.add(k)
            out.append(Finding(
                CODE, path, ln, col,
                f"{cls.name}.{attr} += ... in {method}() without a lock, but "
                f"{cls.name} spawns threads (lost-update race)",
                f"{cls.name}.{method}:{attr}:rmw"))
    return out
