"""HL004 — metric-vocabulary drift.

History: PR 5's calibration round trip works only because every live
metric the sim needs appears in the mapping layer (``gateway/targets``,
``gateway/recorder``, ``gateway/replay``, ``core/calibrate``); a new
live metric that never reaches that layer silently weakens
``validate --round-trip`` (the live run records it, the ``SimResult``
diff can't see it).  This checker makes the drift fail lint instead.

Three sub-checks:

  * every metric-name literal emitted through ``Metrics``
    (``.inc/.observe/.hist/.timeit`` on a ``metrics`` receiver) in the
    live stack must appear in a mapping-layer module or in the
    ``INTERNAL_DIAGNOSTICS`` allowlist below (each entry justified);
  * every counter name the mapping layer reads (``<...>.counters.get``
    / ``.hist("...")`` / the ``*_COSTS`` tuples) must be emitted
    somewhere — a read nobody writes is a typo;
  * the duck-typed ``counters()`` implementations in
    ``gateway/targets.py`` must all return the same literal key set
    (the SimResult-facing vocabulary must not fork per adapter).

A new metric is introduced by adding it to the mapping layer (preferred
— wire it into ``replay_trace`` extras or the recorder) or, for a
genuinely internal diagnostic, to ``INTERNAL_DIAGNOSTICS`` with a
one-line justification.
"""
from __future__ import annotations

import ast

from tools.hydralint import Finding, Project, dotted_name, str_const

CODE = "HL004"

EMIT_METHODS = {"inc", "observe", "hist", "timeit"}

# Files whose string literals count as "visible to the sim mapping".
MAP_FILES = ("gateway/targets.py", "gateway/recorder.py",
             "gateway/replay.py", "core/calibrate.py", "launch/serve.py")

# Files that emit live metrics (the live stack; the sim keeps its own
# SimResult schema and is exempt).
EMIT_EXCLUDE = ("core/sim/", "tests/", "tools/")

# Live metrics that deliberately have no SimResult counterpart.  Keep
# each entry justified; prefer mapping over growing this list.
INTERNAL_DIAGNOSTICS = {
    "registered": "registration tally; trace replays derive it from the workload",
    "deregistered": "teardown tally; no sim analog (sim never deregisters)",
    "invoke_latency_s": "per-runtime wall latency; gateway records trace-time latency itself",
    "runtime.boots": "counts prewarm + request-path boots; pool.miss is the mapped request-path subset",
    "pool.return": "pool hygiene detail; sim models pool occupancy, not handbacks",
    "pool.shrink": "autoscaler shrink detail; resize effects show up in mem/pool samples",
    "place.colocated": "placement-mix diagnostic; sim has no placement-kind counter",
    "place.spill": "placement-mix diagnostic; sim has no placement-kind counter",
    "arena.evicted": "isolate TTL evictions; SimResult tracks runtime-level eviction only",
    "snapshot_s": "snapshot cost is off the request path; sim models restore cost only",
    "snapshots": "snapshot lifecycle tally; see snapshot_s",
    "evictions": "snapshot-eviction tally; runtime.shutdowns is the mapped eviction signal",
    "restores": "restore tally; restore_s (mapped) carries the calibratable cost",
    "exports": "cross-node export tally; migrations (mapped) is the round-trip signal",
    "imports": "cross-node import tally; migrations (mapped) is the round-trip signal",
}


def _receiver_is_metrics(func: ast.Attribute) -> bool:
    name = dotted_name(func.value)
    return bool(name) and (name == "metrics" or name.endswith(".metrics"))


def _emitted(project: Project) -> dict:
    """metric name -> first (path, line, col) emission site."""
    out = {}
    for sf in project.files:
        if not sf.path.startswith("src/") or \
                any(part in sf.path for part in EMIT_EXCLUDE):
            continue
        for node in ast.walk(sf.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in EMIT_METHODS
                    and _receiver_is_metrics(node.func)
                    and node.args):
                continue
            name = str_const(node.args[0])
            if name is not None:
                out.setdefault(name, (sf.path, node.lineno, node.col_offset))
    return out


def _mapping_literals(project: Project) -> set:
    out = set()
    for sf in project.files:
        if not sf.path.endswith(MAP_FILES):
            continue
        for node in ast.walk(sf.tree):
            s = str_const(node)
            if s is not None:
                out.add(s)
    return out


def _consumed(project: Project) -> dict:
    """counter/hist names the mapping layer reads -> first site."""
    out = {}
    for sf in project.files:
        if not sf.path.endswith(MAP_FILES):
            continue
        counter_vars = set()
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                tname = node.targets[0].id
                vname = dotted_name(node.value)
                if vname and vname.endswith(".counters"):
                    counter_vars.add(tname)
                if tname.endswith("_COSTS") and isinstance(
                        node.value, (ast.Tuple, ast.List)):
                    for elt in node.value.elts:
                        s = str_const(elt)
                        if s is not None:
                            out.setdefault(s, (sf.path, elt.lineno,
                                               elt.col_offset))
                if tname == "LIVE_TO_MEASURED" and isinstance(
                        node.value, ast.Dict):
                    for k in node.value.keys:
                        s = str_const(k)
                        if s is not None:
                            out.setdefault(s, (sf.path, k.lineno,
                                               k.col_offset))
        for node in ast.walk(sf.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute) and node.args):
                continue
            s = str_const(node.args[0])
            if s is None:
                continue
            recv = dotted_name(node.func.value)
            if node.func.attr == "get" and recv and (
                    recv.endswith(".counters") or recv in counter_vars):
                out.setdefault(s, (sf.path, node.lineno, node.col_offset))
            elif node.func.attr == "hist" and recv and (
                    recv == "metrics" or recv.endswith(".metrics")
                    or recv.endswith("m")):
                out.setdefault(s, (sf.path, node.lineno, node.col_offset))
    return out


def _counters_key_sets(project: Project) -> list:
    """(class, path, line, frozenset(keys)) for each targets.py
    ``counters()`` returning a dict literal."""
    out = []
    for sf in project.files:
        if not sf.path.endswith("gateway/targets.py"):
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            for stmt in node.body:
                if not (isinstance(stmt, ast.FunctionDef)
                        and stmt.name == "counters"):
                    continue
                for ret in ast.walk(stmt):
                    if isinstance(ret, ast.Return) \
                            and isinstance(ret.value, ast.Dict):
                        keys = frozenset(
                            s for k in ret.value.keys
                            if (s := str_const(k)) is not None)
                        out.append((node.name, sf.path, stmt.lineno, keys))
    return out


def check(project: Project) -> list:
    findings = []
    emitted = _emitted(project)
    mapped = _mapping_literals(project)

    for name, (path, line, col) in sorted(emitted.items()):
        if name in mapped or name in INTERNAL_DIAGNOSTICS:
            continue
        findings.append(Finding(
            CODE, path, line, col,
            f"live metric \"{name}\" is never seen by the sim mapping layer "
            f"({'/'.join(MAP_FILES)}) — wire it into the SimResult extras "
            f"or add a justified INTERNAL_DIAGNOSTICS entry",
            f"unmapped:{name}"))

    for name, (path, line, col) in sorted(_consumed(project).items()):
        if name not in emitted:
            findings.append(Finding(
                CODE, path, line, col,
                f"mapping layer reads metric \"{name}\" that nothing in the "
                f"live stack emits (typo or dead mapping)",
                f"phantom:{name}"))

    key_sets = _counters_key_sets(project)
    concrete = [ks for ks in key_sets if ks[3]]
    if concrete:
        union = frozenset().union(*[ks[3] for ks in concrete])
        for cls, path, line, keys in concrete:
            missing = union - keys
            if missing:
                findings.append(Finding(
                    CODE, path, line, 0,
                    f"{cls}.counters() omits {sorted(missing)} — the "
                    f"SimResult-facing counter vocabulary must match across "
                    f"adapters",
                    f"counters-parity:{cls}"))
    return findings
