"""HL009 — resource lifecycle: acquire/release paired on ALL paths.

History: the density accounting rests on claims being returned — an
arena slab that misses its ``release`` keeps budget reserved forever,
a runtime claim that skips ``_return_runtime`` strands a worker, and an
unfinished ``RequestTrace`` never reaches the flight recorder.  PR 4
fixed exactly this class by hand (exception paths in ``_ensure_placed``
leaving ``rec.runtime`` claimed); PR 8's ``register_signature`` probe
had the same latent shape.  This checker walks the exception-aware CFG
(``flow.py``): for every acquire site, the claim must be released,
returned to the caller, or handed off to longer-lived state on *every*
path out of the function — including the paths a raising call takes.

The paired APIs are declared in :data:`RESOURCES`; adding a new paired
resource is a one-line registry addition.  Matching is deliberately
name-based (receiver suffix / enclosing class), mirroring HL002's
over-approximate resolution.

What counts as settling a claim:

* a release call — ``pool.release(a)`` / ``self._return_runtime(rt)``
  argument style, or ``ctx.finish()`` / ``f.close()`` method style
  (release calls themselves are assumed not to raise: they are the
  cleanup), including calls to project helpers that release one of
  their parameters (interprocedural summary);
* escape — the claim is returned/yielded, stored into an attribute,
  container, or constructor result, or aliased: ownership left the
  function, so pairing is the new owner's job;
* rebinding the variable (tracking stops).

Exception edges are only followed where the statement contains a call
that can plausibly raise (``flow.raising_calls``) — straight-line
arithmetic does not manufacture error paths.

Suppress with ``# hydralint: disable=HL009`` plus a justification when
a claim is intentionally left open (e.g. handed to a thread the
checker cannot see).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Optional

from tools.hydralint import Finding, Project, dotted_name
from tools.hydralint import flow

CODE = "HL009"


@dataclass(frozen=True)
class ResourceSpec:
    """One acquire/release pairing.  ``*_receivers`` are dotted-name
    leaf suffixes the receiver must match (empty = any receiver);
    ``acquire_classes`` lets bare ``self.<acquire_attr>`` match inside
    the owning class.  ``release_on_resource`` means the release is a
    method *of* the claim (``v.close()``) rather than taking it as an
    argument (``pool.release(v)``)."""
    name: str
    acquire_attr: str
    release_attr: str
    acquire_receivers: tuple = ()
    release_receivers: tuple = ()
    acquire_classes: tuple = ()
    release_classes: tuple = ()
    release_on_resource: bool = False
    acquire_is_name_call: bool = False      # builtin-style: v = open(...)


RESOURCES = (
    ResourceSpec("arena", "acquire", "release",
                 acquire_receivers=("arena_pool", "arenas", "pool"),
                 release_receivers=("arena_pool", "arenas", "pool"),
                 acquire_classes=("ArenaPool",),
                 release_classes=("ArenaPool",)),
    ResourceSpec("runtime-claim", "_claim_runtime", "_return_runtime"),
    ResourceSpec("request-trace", "start_request", "finish",
                 acquire_receivers=("tracer",),
                 release_on_resource=True),
    ResourceSpec("file-handle", "open", "close",
                 acquire_is_name_call=True,
                 release_on_resource=True),
)

# Receiver leaf suffixes that mark a manual ``<lock>.acquire()`` /
# ``<lock>.release()`` pair (the ``with`` form is HL001's territory and
# needs no pairing proof).
LOCK_RECEIVER_HINTS = ("lock", "_cv", "_meta")

_MUTATORS = {"append", "add", "extend", "insert", "appendleft", "put",
             "put_nowait", "setdefault", "update", "register"}


def _leaf(recv: Optional[str]) -> str:
    return (recv or "").split(".")[-1]


def _recv_matches(recv: Optional[str], suffixes: tuple,
                  classes: tuple, cls_name: Optional[str]) -> bool:
    if not suffixes:
        return True
    if _leaf(recv) in suffixes:
        return True
    return bool(recv == "self" and cls_name and cls_name in classes)


def _is_lockish(recv: Optional[str]) -> bool:
    leaf = _leaf(recv).lower()
    return any(h in leaf for h in LOCK_RECEIVER_HINTS)


def _uses(tree, var: str) -> bool:
    return any(isinstance(n, ast.Name) and n.id == var
               for n in ast.walk(tree))


def _calls_in(tree) -> list:
    return [n for n in ast.walk(tree) if isinstance(n, ast.Call)]


def _arg_of(call: ast.Call, var: str) -> bool:
    for a in list(call.args) + [kw.value for kw in call.keywords]:
        if isinstance(a, ast.Name) and a.id == var:
            return True
        if isinstance(a, (ast.Tuple, ast.List)) and _uses(a, var):
            return True
        if isinstance(a, ast.Starred) and _uses(a.value, var):
            return True
    return False


# ---------------------------------------------------------------------------
# per-site path analysis

class _Site:
    def __init__(self, spec: ResourceSpec, var: str, node_idx: int,
                 call: ast.Call):
        self.spec = spec
        self.var = var
        self.node_idx = node_idx
        self.call = call


class _FuncScan:
    def __init__(self, sf, fi, cfg, aliases, summaries):
        self.sf = sf
        self.fi = fi
        self.cfg = cfg
        self.aliases = aliases
        self.summaries = summaries
        self.cls_name = fi.cls.name if fi.cls is not None else None

    # -- acquire sites -----------------------------------------------------
    def sites(self) -> list:
        out = []
        for n in self.cfg.nodes:
            if n.kind != "stmt" or not isinstance(n.stmt, ast.Assign):
                continue
            s = n.stmt
            if len(s.targets) != 1 or not isinstance(s.targets[0], ast.Name):
                continue
            if not isinstance(s.value, ast.Call):
                continue
            spec = self._acquire_spec(s.value)
            if spec is not None:
                out.append(_Site(spec, s.targets[0].id, n.idx, s.value))
        return out

    def _acquire_spec(self, call: ast.Call) -> Optional[ResourceSpec]:
        func = call.func
        for spec in RESOURCES:
            if spec.acquire_is_name_call:
                if isinstance(func, ast.Name) and func.id == spec.acquire_attr:
                    return spec
                continue
            if not isinstance(func, ast.Attribute):
                continue
            if func.attr != spec.acquire_attr:
                continue
            recv = dotted_name(func.value)
            if spec.acquire_attr == "acquire" and _is_lockish(recv):
                continue        # lock pairing handled separately
            if _recv_matches(recv, spec.acquire_receivers,
                             spec.acquire_classes, self.cls_name):
                return spec
        # helper wrappers whose summary says "returns a fresh claim"
        if self.summaries is not None and isinstance(func, ast.Attribute):
            for tag, _arg in self.summaries.call_facts(self.sf.path, call):
                if tag.startswith("returns:"):
                    name = tag.split(":", 1)[1]
                    for spec in RESOURCES:
                        if spec.name == name:
                            return spec
        return None

    # -- settling a claim --------------------------------------------------
    def releases(self, exprs, site: _Site) -> bool:
        spec, var = site.spec, site.var
        for tree in exprs:
            for call in _calls_in(tree):
                func = call.func
                if spec.release_on_resource:
                    if isinstance(func, ast.Attribute) \
                            and func.attr == spec.release_attr \
                            and isinstance(func.value, ast.Name) \
                            and func.value.id == var:
                        return True
                if isinstance(func, ast.Attribute) \
                        and func.attr == spec.release_attr:
                    recv = dotted_name(func.value)
                    if _recv_matches(recv, spec.release_receivers,
                                     spec.release_classes, self.cls_name) \
                            and _arg_of(call, var):
                        return True
                if isinstance(func, ast.Name) \
                        and func.id == spec.release_attr \
                        and _arg_of(call, var):
                    return True
                if self.summaries is not None:
                    for tag, arg in self.summaries.call_facts(
                            self.sf.path, call):
                        if tag == f"releases:{spec.name}" \
                                and isinstance(arg, ast.Name) \
                                and arg.id == var:
                            return True
        return False

    def escapes(self, exprs, site: _Site) -> bool:
        var = site.var
        for tree in exprs:
            for node in ast.walk(tree):
                if isinstance(node, ast.Return) and node.value is not None \
                        and _uses(node.value, var):
                    return True
                if isinstance(node, (ast.Yield, ast.YieldFrom)) \
                        and node.value is not None \
                        and _uses(node.value, var):
                    return True
                if isinstance(node, ast.Assign):
                    val = node.value
                    if isinstance(val, ast.Name) and val.id == var:
                        return True          # alias: b = a
                    if isinstance(val, (ast.Tuple, ast.List, ast.Dict,
                                        ast.Set)) and _uses(val, var):
                        return True          # packed into a container
                    if any(isinstance(t, (ast.Attribute, ast.Subscript))
                           for t in node.targets) and _uses(val, var):
                        return True          # stored into attr/container
                    # claim consumed by a call whose result is kept
                    if any(_arg_of(c, var) for c in _calls_in(val)):
                        return True
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr in _MUTATORS \
                        and _arg_of(node, var):
                    return True              # queue.append(claim), ...
        return False

    def rebinds(self, n, site: _Site) -> bool:
        s = n.stmt
        var = site.var
        if n.kind == "stmt":
            if isinstance(s, ast.Assign):
                if any(isinstance(t, ast.Name) and t.id == var
                       for t in s.targets):
                    return True
            if isinstance(s, (ast.AugAssign, ast.AnnAssign)) \
                    and isinstance(s.target, ast.Name) \
                    and s.target.id == var:
                return True
            if isinstance(s, ast.Delete) \
                    and any(isinstance(t, ast.Name) and t.id == var
                            for t in s.targets):
                return True
        if n.kind == "loop" and isinstance(s, (ast.For, ast.AsyncFor)) \
                and _uses(s.target, var):
            return True
        return False

    # -- the walk ----------------------------------------------------------
    def leaks(self, site: _Site):
        """(normal_leak, exception_leak) for one acquire site."""
        cfg = self.cfg
        seen = set()
        todo = list(cfg.nodes[site.node_idx].succ)
        leak_norm = leak_exc = False
        while todo:
            i = todo.pop()
            if i in seen:
                continue
            seen.add(i)
            n = cfg.nodes[i]
            if n.kind == "exit":
                leak_norm = True
                continue
            if n.kind == "raise":
                leak_exc = True
                continue
            exprs = flow.node_exprs(n)
            if exprs:
                if i == site.node_idx:
                    pass                     # looped back to the acquire
                elif self.releases(exprs, site) \
                        or self.escapes(exprs, site) \
                        or self.rebinds(n, site):
                    continue                 # claim settled on this path
            todo.extend(n.succ)
            if n.kind == "raise-stmt" or any(
                    flow.raising_calls(e, self.aliases) for e in exprs):
                todo.extend(n.esucc)
        return leak_norm, leak_exc


# ---------------------------------------------------------------------------
# manual lock.acquire() pairing (resource == the receiver)

def _lock_findings(sf, fi, cfg, aliases) -> list:
    out = []
    sites = []
    for n in cfg.nodes:
        if n.kind != "stmt" or not isinstance(n.stmt, ast.Expr):
            continue
        call = n.stmt.value
        if not isinstance(call, ast.Call) \
                or not isinstance(call.func, ast.Attribute):
            continue
        if call.func.attr != "acquire":
            continue
        recv = dotted_name(call.func.value)
        if recv is not None and _is_lockish(recv):
            sites.append((n, recv))

    def settles(n, recv) -> bool:
        for tree in flow.node_exprs(n):
            for call in _calls_in(tree):
                if isinstance(call.func, ast.Attribute) \
                        and call.func.attr == "release" \
                        and dotted_name(call.func.value) == recv:
                    return True
        return False

    for site_n, recv in sites:
        seen, todo = set(), list(site_n.succ)
        leak_norm = leak_exc = False
        while todo:
            i = todo.pop()
            if i in seen:
                continue
            seen.add(i)
            n = cfg.nodes[i]
            if n.kind == "exit":
                leak_norm = True
                continue
            if n.kind == "raise":
                leak_exc = True
                continue
            exprs = flow.node_exprs(n)
            if exprs and i != site_n.idx and settles(n, recv):
                continue
            todo.extend(n.succ)
            if n.kind == "raise-stmt" or any(
                    flow.raising_calls(e, aliases) for e in exprs):
                todo.extend(n.esucc)
        if leak_norm or leak_exc:
            where = _path_phrase(leak_norm, leak_exc)
            out.append(Finding(
                CODE, sf.path, site_n.stmt.lineno, site_n.stmt.col_offset,
                f"manual {recv}.acquire() in {fi.qualname}() is not "
                f"released on {where} — use `with {recv}:` or a "
                f"try/finally",
                f"{fi.qualname}:lock:{recv}"))
    return out


def _path_phrase(norm: bool, exc: bool) -> str:
    if norm and exc:
        return "some normal and exception paths"
    if exc:
        return "an exception path"
    return "a normal path"


# ---------------------------------------------------------------------------

def _direct_summary(sf, fi) -> set:
    """Direct facts for flow.Summaries: which of the function's own
    parameters it releases, and whether it returns a fresh claim."""
    facts = set()
    cls_name = fi.cls.name if fi.cls is not None else None
    for node in ast.walk(fi.node):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        for spec in RESOURCES:
            if spec.release_on_resource:
                if isinstance(func, ast.Attribute) \
                        and func.attr == spec.release_attr \
                        and isinstance(func.value, ast.Name):
                    facts.add((f"releases:{spec.name}", func.value.id))
            elif isinstance(func, ast.Attribute) \
                    and func.attr == spec.release_attr \
                    and _recv_matches(dotted_name(func.value),
                                      spec.release_receivers,
                                      spec.release_classes, cls_name):
                for a in node.args[:1]:
                    if isinstance(a, ast.Name):
                        facts.add((f"releases:{spec.name}", a.id))
    return facts


def check(project: Project) -> list:
    fg = flow.FlowGraph(project)
    summaries = flow.Summaries(fg, _direct_summary)
    findings = []
    for sf, fi in project.iter_funcs():
        cfg = fg.cfg(sf.path, fi)
        aliases = fg.aliases(sf.path)
        scan = _FuncScan(sf, fi, cfg, aliases, summaries)
        counts: dict = {}
        for site in scan.sites():
            leak_norm, leak_exc = scan.leaks(site)
            if not (leak_norm or leak_exc):
                continue
            where = _path_phrase(leak_norm, leak_exc)
            k = (site.spec.name, site.var)
            i = counts.get(k, 0)
            counts[k] = i + 1
            findings.append(Finding(
                CODE, sf.path, site.call.lineno, site.call.col_offset,
                f"{site.spec.name} claim `{site.var}` in {fi.qualname}() "
                f"is not {site.spec.release_attr}()d on {where} — pair "
                f"the claim in a try/finally or settle it in an except",
                f"{fi.qualname}:{site.spec.name}:{site.var}:{i}"))
        findings.extend(_lock_findings(sf, fi, cfg, aliases))
    return findings
