"""HL008 — span discipline.

The request-tracing layer (``repro.core.tracing``) only yields a usable
conservation invariant if call sites follow three rules:

  * ``ctx.span(...)`` is a context manager: the span's end timestamp is
    taken in ``__exit__``, so a bare call (``ctx.span("x")``) times
    nothing and silently records a zero-length phase.  Cross-thread
    waits that cannot be a ``with`` block use ``add_span(name, t0, t1)``
    with two explicit timestamps instead.
  * Span names come from the closed ``PHASES`` registry in
    ``core/tracing.py`` — an ad-hoc name would aggregate into nothing
    (``summary()`` emits the fixed vocabulary) and break the
    ``BENCH_trace.json`` key-shape gate.
  * Sim code (the HL003 scope) never traces: the simulator models
    phases, it does not measure them, and a tracing import there would
    couple the deterministic event loop to wall-clock span timestamps.

The registry is read from the AST of ``src/repro/core/tracing.py``
itself (from the project when linted, else from disk under the project
root) so this checker can never drift from the vocabulary it enforces.
``core/tracing.py`` is exempt — it defines the machinery.
"""
from __future__ import annotations

import ast
from pathlib import Path

from tools.hydralint import Finding, Project, str_const
from tools.hydralint.determinism import _is_sim_file

CODE = "HL008"

TRACING_PATH = "src/repro/core/tracing.py"
TRACING_MODULE = "repro.core.tracing"
# methods of RequestTrace/_NullTrace that take a phase name first
NAMED_METHODS = ("span", "add_span")


def _load_phases(project: Project):
    """The ``PHASES`` tuple from core/tracing.py — from the parsed
    project when tracing.py is among the lint roots, else parsed off
    disk. None when unavailable (registry checks are skipped rather
    than guessed)."""
    sf = project.by_path.get(TRACING_PATH)
    tree = sf.tree if sf is not None else None
    if tree is None:
        p = Path(project.root) / TRACING_PATH
        try:
            tree = ast.parse(p.read_text(), filename=TRACING_PATH)
        except (OSError, SyntaxError):
            return None
    for node in tree.body:
        if not isinstance(node, ast.Assign):
            continue
        if not any(isinstance(t, ast.Name) and t.id == "PHASES"
                   for t in node.targets):
            continue
        if isinstance(node.value, (ast.Tuple, ast.List)):
            names = [str_const(e) for e in node.value.elts]
            if all(n is not None for n in names):
                return frozenset(names)
    return None


def check(project: Project) -> list:
    phases = _load_phases(project)
    findings = []
    for sf in project.files:
        if sf.path.endswith("core/tracing.py"):
            continue
        sim = _is_sim_file(sf)
        if sim:
            findings.extend(_check_sim_imports(sf))
        # calls that ARE a with-item context expression are compliant
        # context-manager uses; collect their identities first
        with_calls = set()
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    if isinstance(item.context_expr, ast.Call):
                        with_calls.add(id(item.context_expr))
        for node in ast.walk(sf.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in NAMED_METHODS):
                continue
            meth = node.func.attr
            if sim:
                findings.append(Finding(
                    CODE, sf.path, node.lineno, node.col_offset,
                    f".{meth}() tracing call in sim code — the simulator "
                    f"models phases, it must not measure them (HL003 "
                    f"scope)",
                    f"sim-tracing:{meth}:L{node.lineno}"))
                continue
            name = str_const(node.args[0]) if node.args else None
            if name is not None and phases is not None \
                    and name not in phases:
                findings.append(Finding(
                    CODE, sf.path, node.lineno, node.col_offset,
                    f"span name {name!r} is not in the PHASES registry "
                    f"(core/tracing.py) — ad-hoc names break the "
                    f"fixed-vocabulary aggregation",
                    f"unknown-phase:{name}"))
            if meth == "span" and id(node) not in with_calls:
                findings.append(Finding(
                    CODE, sf.path, node.lineno, node.col_offset,
                    f".span({name!r}) must be used as a context manager "
                    f"(with ctx.span(...) as sp:) — a bare call never "
                    f"records the end timestamp; for cross-thread waits "
                    f"use add_span(name, t0, t1)",
                    f"bare-span:{name}:L{node.lineno}"))
    return findings


def _check_sim_imports(sf) -> list:
    findings = []
    for node in ast.walk(sf.tree):
        bad = None
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.startswith(TRACING_MODULE):
                    bad = alias.name
        elif isinstance(node, ast.ImportFrom):
            if node.module and node.module.startswith(TRACING_MODULE):
                bad = node.module
        if bad is not None:
            findings.append(Finding(
                CODE, sf.path, node.lineno, node.col_offset,
                f"import of {bad} in sim code — sim modules must stay "
                f"tracing-free (deterministic event time only)",
                f"sim-import:{bad}"))
    return findings
