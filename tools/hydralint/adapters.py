"""HL005 — gateway target-adapter conformance.

The gateway fronts three duck-typed stacks through ``TargetAdapter``
(``RuntimeTarget`` / ``PlatformTarget`` / ``ClusterTarget``).  Nothing
but convention guarantees that the surface ``replay.py`` / ``recorder.py``
/ ``gateway.py`` actually touch (``invoke``, ``sample``, ``counters``,
``n_nodes``, ``platform_metrics``, ...) exists on every adapter — PR 5's
``recorder.finish()`` n_nodes default bug was exactly this class of
drift.

The checker computes the *used* protocol surface — every attribute
accessed on an expression named ``adapter`` / ``self.adapter`` inside
the gateway package — and requires that:

  * the ``TargetAdapter`` base defines every used name (method,
    property, or class attribute), so the surface is discoverable in
    one place; and
  * every concrete subclass overrides each base method whose body is
    just ``raise NotImplementedError`` (abstract-by-convention) that is
    in the used surface.
"""
from __future__ import annotations

import ast

from tools.hydralint import Finding, Project, dotted_name

CODE = "HL005"

BASE_CLASS = "TargetAdapter"
ADAPTER_FILE = "gateway/targets.py"
GATEWAY_DIR = "gateway/"
ADAPTER_NAMES = ("adapter", "self.adapter")


def _used_surface(project: Project) -> dict:
    """attr -> first (path, line) where gateway code touches adapter.attr."""
    used = {}
    for sf in project.files:
        if GATEWAY_DIR not in sf.path:
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Attribute):
                continue
            base = dotted_name(node.value)
            if base in ADAPTER_NAMES:
                used.setdefault(node.attr, (sf.path, node.lineno))
    return used


def _is_not_implemented(fn) -> bool:
    body = [s for s in fn.body
            if not (isinstance(s, ast.Expr)
                    and isinstance(s.value, ast.Constant))]  # docstring
    if len(body) != 1 or not isinstance(body[0], ast.Raise):
        return False
    exc = body[0].exc
    name = dotted_name(exc.func if isinstance(exc, ast.Call) else exc)
    return name == "NotImplementedError"


def check(project: Project) -> list:
    targets_sf = None
    for sf in project.files:
        if sf.path.endswith(ADAPTER_FILE):
            targets_sf = sf
            break
    if targets_sf is None:
        return []

    base = None
    subclasses = []
    for node in ast.walk(targets_sf.tree):
        if isinstance(node, ast.ClassDef):
            if node.name == BASE_CLASS:
                base = node
            elif any(dotted_name(b) == BASE_CLASS for b in node.bases):
                subclasses.append(node)
    if base is None:
        return []

    def class_names(cls) -> dict:
        """name -> def node (or None for plain attribute assignments)."""
        names = {}
        for stmt in cls.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                names[stmt.name] = stmt
            elif isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        names[t.id] = None
            elif isinstance(stmt, ast.AnnAssign) and isinstance(
                    stmt.target, ast.Name):
                names[stmt.target.id] = None
        # instance attributes assigned in __init__
        init = names.get("__init__")
        if init is not None:
            for sub in ast.walk(init):
                if isinstance(sub, (ast.Assign, ast.AnnAssign)):
                    tgts = sub.targets if isinstance(sub, ast.Assign) \
                        else [sub.target]
                    for t in tgts:
                        n = dotted_name(t)
                        if n and n.startswith("self.") and n.count(".") == 1:
                            names[n.split(".", 1)[1]] = None
        return names

    findings = []
    used = _used_surface(project)
    base_names = class_names(base)

    for attr, (path, line) in sorted(used.items()):
        if attr not in base_names:
            findings.append(Finding(
                CODE, targets_sf.path, base.lineno, 0,
                f"gateway code uses adapter.{attr} ({path}:{line}) but "
                f"{BASE_CLASS} does not define it — the adapter protocol "
                f"surface must be declared on the base",
                f"base-missing:{attr}"))

    abstract = {name for name, fn in base_names.items()
                if fn is not None and _is_not_implemented(fn)}
    for cls in subclasses:
        sub_names = class_names(cls)
        for attr in sorted(abstract & set(used)):
            if attr not in sub_names:
                findings.append(Finding(
                    CODE, targets_sf.path, cls.lineno, 0,
                    f"{cls.name} does not implement {attr}() — the base "
                    f"raises NotImplementedError and the gateway calls it",
                    f"unimplemented:{cls.name}.{attr}"))
    return findings
