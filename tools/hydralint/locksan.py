"""Runtime lock-order sanitizer (the dynamic half of hydralint).

Static HL001 proves attributes stay under their lock; it cannot prove
that two locks are always taken in the same ORDER.  With the platform
lock, per-record place locks, per-object metrics locks, and the cluster
condition all nesting on the request path, an A->B in one thread and
B->A in another is a deadlock waiting for load.  This module wraps
``threading.Lock`` / ``threading.RLock`` to record the acquisition-order
graph while the hammer tests run, then fails the test if the graph
contains a cycle — lockdep, in miniature.

Usage (armed in the tier-1 hammer tests)::

    from tools.hydralint import locksan

    with locksan.sanitized():      # patches threading.Lock/RLock,
        run_concurrent_workload()  # records order edges, checks at exit

Notes on fidelity:

  * Order is recorded *before* blocking on the inner acquire, so an
    ordering that would deadlock is still captured.
  * Re-entrant RLock acquires add no edge (no new ordering).
  * A plain Lock acquired twice by one thread, or released by a thread
    that never acquired it, is being used as a semaphore/handoff (e.g.
    ``Condition`` waiter locks) — ordering analysis does not apply to
    those, so they are excluded from the cycle check instead of
    producing false inversions.
"""
from __future__ import annotations

import _thread
import contextlib
import sys
import threading

__all__ = ["LockOrderSanitizer", "sanitized", "LockOrderViolation"]


class LockOrderViolation(AssertionError):
    pass


def _call_site() -> str:
    """file:line of the nearest frame outside this module."""
    f = sys._getframe(2)
    while f is not None and f.f_code.co_filename == __file__:
        f = f.f_back
    if f is None:
        return "?"
    return f"{f.f_code.co_filename.rsplit('/', 1)[-1]}:{f.f_lineno}"


class _SanLockBase:
    _reentrant = False

    def __init__(self, san: "LockOrderSanitizer", inner, name: str):
        self._san = san
        self._inner = inner
        self._lockid = san._register(self, name)

    # -- tracking ----------------------------------------------------------
    def _before_acquire(self) -> None:
        self._san._on_acquire_attempt(self)

    def _after_acquire(self) -> None:
        self._san._on_acquired(self)

    def _on_release(self) -> None:
        self._san._on_release(self)

    # -- lock API ----------------------------------------------------------
    def acquire(self, blocking: bool = True, timeout: float = -1):
        self._before_acquire()
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._after_acquire()
        return got

    def release(self):
        self._on_release()
        return self._inner.release()

    def locked(self):
        return self._inner.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def __repr__(self):
        return f"<{type(self).__name__} {self._san.name_of(self._lockid)!r}>"


class _SanLock(_SanLockBase):
    pass


class _SanRLock(_SanLockBase):
    _reentrant = True

    # Condition() duck-types on these three for RLocks.
    def _is_owned(self):
        return self._inner._is_owned()

    def _release_save(self):
        self._san._on_release(self, all_depths=True)
        return self._inner._release_save()

    def _acquire_restore(self, state):
        self._inner._acquire_restore(state)
        self._san._on_acquired(self)


class LockOrderSanitizer:
    """Acquisition-order graph over every lock created while patched."""

    def __init__(self):
        self._meta = _thread.allocate_lock()   # raw: never wrapped
        self._orig_lock = threading.Lock
        self._orig_rlock = threading.RLock
        self._next_id = 0
        self._names: dict = {}                 # id -> name
        self._edges: dict = {}                 # (a, b) -> "site" of first sight
        self._excluded: set = set()            # semaphore-style lock ids
        self._held = threading.local()
        self.locks_created = 0
        self.acquires = 0

    # -- wrapper plumbing --------------------------------------------------
    def _register(self, lock, name: str) -> int:
        with self._meta:
            lid = self._next_id
            self._next_id += 1
            self._names[lid] = name
            self.locks_created += 1
        return lid

    def name_of(self, lid: int) -> str:
        return self._names.get(lid, f"lock#{lid}")

    def _stack(self) -> list:
        st = getattr(self._held, "stack", None)
        if st is None:
            st = self._held.stack = []
        return st

    def _on_acquire_attempt(self, lock: _SanLockBase) -> None:
        st = self._stack()
        lid = lock._lockid
        if lid in st:
            if not lock._reentrant:
                # double-acquire of a plain Lock by one thread: it's a
                # handoff primitive, not a mutex — exclude from ordering
                with self._meta:
                    self._excluded.add(lid)
            return      # re-entrant: no new ordering information
        if st:
            site = _call_site()
            with self._meta:
                self.acquires += 1
                for held in st:
                    if held != lid:
                        self._edges.setdefault((held, lid), site)
        else:
            with self._meta:
                self.acquires += 1

    def _on_acquired(self, lock: _SanLockBase) -> None:
        self._stack().append(lock._lockid)

    def _on_release(self, lock: _SanLockBase, all_depths: bool = False) -> None:
        st = self._stack()
        lid = lock._lockid
        if lid not in st:
            # released by a thread that never acquired it: handoff usage
            with self._meta:
                self._excluded.add(lid)
            return
        if all_depths:
            while lid in st:
                st.remove(lid)
        else:
            # remove the innermost occurrence
            for i in range(len(st) - 1, -1, -1):
                if st[i] == lid:
                    del st[i]
                    break

    # -- factories ---------------------------------------------------------
    def make_lock(self, name: str = "") -> _SanLock:
        return _SanLock(self, self._orig_lock(),
                        name or f"Lock@{_call_site()}")

    def make_rlock(self, name: str = "") -> _SanRLock:
        return _SanRLock(self, self._orig_rlock(),
                         name or f"RLock@{_call_site()}")

    @contextlib.contextmanager
    def patched(self):
        """Swap ``threading.Lock``/``RLock`` (and ``queue``'s references)
        for sanitized factories."""
        orig_lock, orig_rlock = threading.Lock, threading.RLock
        threading.Lock = self.make_lock
        threading.RLock = self.make_rlock
        try:
            yield self
        finally:
            threading.Lock = orig_lock
            threading.RLock = orig_rlock

    # -- analysis ----------------------------------------------------------
    def order_graph(self) -> dict:
        """adjacency: lock id -> set of lock ids acquired while holding it
        (handoff-style locks excluded)."""
        with self._meta:
            edges = dict(self._edges)
            excluded = set(self._excluded)
        adj: dict = {}
        for (a, b) in edges:
            if a in excluded or b in excluded:
                continue
            adj.setdefault(a, set()).add(b)
        return adj

    def check(self) -> list:
        """Human-readable lock-order inversion reports (empty = clean)."""
        adj = self.order_graph()
        with self._meta:
            edges = dict(self._edges)

        def reachable(src, dst) -> bool:
            seen, todo = set(), [src]
            while todo:
                n = todo.pop()
                if n == dst:
                    return True
                if n in seen:
                    continue
                seen.add(n)
                todo.extend(adj.get(n, ()))
            return False

        out = []
        reported = set()
        for (a, b) in sorted(edges):
            if b not in adj.get(a, ()):   # excluded edge
                continue
            pair = (min(a, b), max(a, b))
            if pair in reported:
                continue
            if reachable(b, a):
                reported.add(pair)
                site_ab = edges.get((a, b), "?")
                site_ba = edges.get((b, a), "?")
                out.append(
                    f"lock-order inversion: {self.name_of(a)} -> "
                    f"{self.name_of(b)} (at {site_ab}) but also "
                    f"{self.name_of(b)} ->* {self.name_of(a)} "
                    f"(e.g. at {site_ba})")
        return out

    def assert_clean(self) -> None:
        violations = self.check()
        if violations:
            raise LockOrderViolation(
                "lock-order inversions detected:\n" + "\n".join(violations))


@contextlib.contextmanager
def sanitized():
    """Patch lock factories, run the body, fail on order inversions."""
    san = LockOrderSanitizer()
    with san.patched():
        yield san
    san.assert_clean()
