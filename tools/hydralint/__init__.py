"""hydralint: repo-specific static analysis for the Hydra reproduction.

The repo's last few PRs kept hand-fixing the same defect classes: shared
state racing past a lock, eager ``jnp`` work sneaking onto the replay
hot path, sim code drifting off determinism, and live metric names
falling out of the ``SimResult`` vocabulary the calibration round trip
depends on.  hydralint encodes each class as an AST checker (stdlib
``ast`` only — no new dependencies) so the invariant is enforced by CI
instead of reviewer memory.

Usage::

    python -m tools.hydralint src/ tests/ --baseline tools/hydralint/baseline.json

Checkers (see ``docs/development.md`` for rationale + history):

  HL001  lock discipline       tools/hydralint/lockcheck.py
  HL002  hot-path purity       tools/hydralint/purity.py
  HL003  sim determinism       tools/hydralint/determinism.py
  HL004  metric vocabulary     tools/hydralint/vocab.py
  HL005  adapter conformance   tools/hydralint/adapters.py
  HL006  docs references       tools/hydralint/docsref.py
  HL007  argparse hygiene      tools/hydralint/clihygiene.py
  HL008  span discipline       tools/hydralint/spans.py
  HL009  resource lifecycle    tools/hydralint/lifecycle.py
  HL010  lock exception safety tools/hydralint/exsafety.py
  HL011  accounting parity     tools/hydralint/parity.py

HL009/HL010 run on the shared exception-aware dataflow engine in
``tools/hydralint/flow.py`` (CFG with exception edges + interprocedural
summaries over the HL002 call graph).  The runtime companions are
``locksan`` (lock-order) and ``leaksan`` (resource leaks), armed inside
the tier-1 concurrency tests.

Suppression: append ``# hydralint: disable=HL00X`` (comma-separate for
several codes) to the offending line, with a short justification in the
same comment.  Placing the comment on a ``def``/``class`` line (or in a
multi-line signature) scopes it to the whole body; for HL002 a scoped
suppression also stops call-graph traversal through that function.

Baseline: ``baseline.json`` maps finding keys -> messages.  Findings in
the baseline do not fail lint, but the baseline may only shrink — an
entry that no longer matches any finding is itself an error, so fixed
debt cannot silently regress.
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Optional

DISABLE_RE = re.compile(r"#\s*hydralint:\s*disable=([A-Za-z0-9_, ]+)")
MARKER_RE = re.compile(r"#\s*hydralint:\s*([a-z-]+)\b")


@dataclass(frozen=True)
class Finding:
    """One lint finding.

    ``detail`` is the stable identity component (symbol names, not line
    numbers) so baseline entries survive unrelated edits to the file.
    """
    code: str
    path: str          # posix path relative to the project root
    line: int
    col: int
    message: str
    detail: str

    @property
    def key(self) -> str:
        return f"{self.code}::{self.path}::{self.detail}"

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"


@dataclass
class FuncInfo:
    qualname: str                      # e.g. "Gateway._serve" or "main"
    node: ast.AST                      # FunctionDef / AsyncFunctionDef
    cls: Optional[ast.ClassDef] = None # enclosing class, if a method


@dataclass
class SourceFile:
    path: str                          # posix, relative to root
    source: str
    tree: ast.Module
    lines: list = field(default_factory=list)
    line_disables: dict = field(default_factory=dict)   # line -> set(codes)
    scope_disables: list = field(default_factory=list)  # (start, end, codes, qualname)
    markers: dict = field(default_factory=dict)         # line -> set(marker words)
    funcs: list = field(default_factory=list)           # [FuncInfo]

    def func_by_qualname(self, qualname: str) -> Optional[FuncInfo]:
        for fi in self.funcs:
            if fi.qualname == qualname:
                return fi
        return None

    def has_marker(self, word: str) -> bool:
        return any(word in words for words in self.markers.values())

    def marker_lines(self, word: str) -> set:
        return {ln for ln, words in self.markers.items() if word in words}


class Project:
    """All parsed sources under the lint roots, plus the repo root for
    checkers (HL006) that look at non-Python files."""

    def __init__(self, root: Path, files: list, parse_findings: list):
        self.root = Path(root)
        self.files = files
        self.parse_findings = parse_findings
        self.by_path = {f.path: f for f in files}

    @classmethod
    def load(cls, root, paths: Iterable) -> "Project":
        root = Path(root).resolve()
        seen, files, parse_findings = set(), [], []
        for p in paths:
            p = Path(p)
            if not p.is_absolute():
                p = root / p
            if p.is_dir():
                candidates = sorted(p.rglob("*.py"))
            else:
                candidates = [p]
            for f in candidates:
                if "__pycache__" in f.parts or ".git" in f.parts:
                    continue
                f = f.resolve()
                if f in seen or not f.exists():
                    continue
                seen.add(f)
                try:
                    rel = f.relative_to(root).as_posix()
                except ValueError:
                    rel = f.as_posix()
                source = f.read_text()
                try:
                    tree = ast.parse(source, filename=rel)
                except SyntaxError as e:
                    parse_findings.append(Finding(
                        "HL000", rel, e.lineno or 1, (e.offset or 1) - 1,
                        f"syntax error: {e.msg}", f"syntax:{e.msg}"))
                    continue
                files.append(_build_source_file(rel, source, tree))
        return cls(root, files, parse_findings)

    def iter_funcs(self):
        for sf in self.files:
            for fi in sf.funcs:
                yield sf, fi

    def is_suppressed(self, f: Finding) -> bool:
        sf = self.by_path.get(f.path)
        if sf is None:
            return False
        if f.code in sf.line_disables.get(f.line, ()):
            return True
        for start, end, codes, _qn in sf.scope_disables:
            if start <= f.line <= end and f.code in codes:
                return True
        return False

    def scope_suppressed_qualnames(self, code: str) -> set:
        """(path, qualname) pairs whose whole body suppresses ``code``."""
        out = set()
        for sf in self.files:
            for _s, _e, codes, qn in sf.scope_disables:
                if qn and code in codes:
                    out.add((sf.path, qn))
        return out


def _build_source_file(rel: str, source: str, tree: ast.Module) -> SourceFile:
    lines = source.splitlines()
    sf = SourceFile(rel, source, tree, lines)
    for i, text in enumerate(lines, start=1):
        m = DISABLE_RE.search(text)
        if m:
            codes = {c.strip() for c in m.group(1).split(",") if c.strip()}
            sf.line_disables.setdefault(i, set()).update(codes)
        for mm in MARKER_RE.finditer(text):
            if mm.group(1) != "disable":
                sf.markers.setdefault(i, set()).add(mm.group(1))

    # A disable on a comment-only line covers the next code line, so the
    # justification can be written above the statement it annotates.
    for i in sorted(sf.line_disables):
        if not lines[i - 1].lstrip().startswith("#"):
            continue
        j = i + 1
        while j <= len(lines) and (not lines[j - 1].strip()
                                   or lines[j - 1].lstrip().startswith("#")):
            j += 1
        if j <= len(lines):
            sf.line_disables.setdefault(j, set()).update(sf.line_disables[i])

    # Function index with qualnames, and scope-level suppressions: a
    # disable comment anywhere in a def/class signature covers the body.
    def visit(node, prefix, cls):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qn = prefix + child.name
                sf.funcs.append(FuncInfo(qn, child, cls))
                _scope_disables(sf, child, qn)
                visit(child, qn + ".", cls)
            elif isinstance(child, ast.ClassDef):
                qn = prefix + child.name
                _scope_disables(sf, child, qn)
                visit(child, qn + ".", child)
    visit(tree, "", None)
    return sf


def _scope_disables(sf: SourceFile, node, qualname: str) -> None:
    body_start = node.body[0].lineno if node.body else node.lineno
    sig_lines = range(node.lineno, max(node.lineno, body_start - 1) + 1)
    codes = set()
    for ln in sig_lines:
        codes |= sf.line_disables.get(ln, set())
    if codes:
        sf.scope_disables.append(
            (node.lineno, node.end_lineno or node.lineno, codes, qualname))


# ---------------------------------------------------------------------------
# checker registry

def all_checkers():
    from tools.hydralint import (adapters, clihygiene, determinism, docsref,
                                 exsafety, lifecycle, lockcheck, parity,
                                 purity, spans, vocab)
    return [
        ("HL001", lockcheck.check),
        ("HL002", purity.check),
        ("HL003", determinism.check),
        ("HL004", vocab.check),
        ("HL005", adapters.check),
        ("HL006", docsref.check),
        ("HL007", clihygiene.check),
        ("HL008", spans.check),
        ("HL009", lifecycle.check),
        ("HL010", exsafety.check),
        ("HL011", parity.check),
    ]


@dataclass
class LintResult:
    findings: list                     # unsuppressed findings
    suppressed: list                   # findings silenced by inline disables

    def new_against(self, baseline: dict) -> list:
        return [f for f in self.findings if f.key not in baseline]

    def stale_baseline_keys(self, baseline: dict) -> list:
        live = {f.key for f in self.findings}
        return sorted(k for k in baseline if k not in live)


def run_lint(paths: Iterable, root, select: Optional[set] = None) -> LintResult:
    project = Project.load(root, paths)
    findings = list(project.parse_findings)
    for code, check in all_checkers():
        if select and code not in select:
            continue
        findings.extend(check(project))
    findings.sort(key=lambda f: (f.path, f.line, f.code))
    kept = [f for f in findings if not project.is_suppressed(f)]
    supp = [f for f in findings if project.is_suppressed(f)]
    return LintResult(kept, supp)


# ---------------------------------------------------------------------------
# baseline

def load_baseline(path) -> dict:
    path = Path(path)
    if not path.exists():
        return {}
    data = json.loads(path.read_text())
    return dict(data.get("findings", {}))


def write_baseline(path, findings: Iterable) -> None:
    payload = {
        "version": 1,
        "note": "hydralint debt ledger: may only shrink. Prefer fixing or "
                "an annotated inline disable over adding entries.",
        "findings": {f.key: f.message for f in findings},
    }
    Path(path).write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")


# AST helpers shared by checkers -------------------------------------------

def dotted_name(node) -> Optional[str]:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def str_const(node) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None
