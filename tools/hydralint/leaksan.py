"""Runtime resource-leak sanitizer (the dynamic half of HL009).

Static HL009 proves acquire/release pairing along a function's own
paths; it cannot follow a claim handed between threads — a gateway
worker claims an arena, the janitor evicts, a trace finishes on a
different thread than it started on.  This module wraps the same
paired APIs the static checker knows (``ArenaPool.acquire``/
``release``, ``HydraPlatform._claim_runtime``/``_return_runtime``,
``Tracer.start_request``/``RequestTrace.finish``) and keeps a ledger of
outstanding claims; a test that finishes a gateway replay or a cluster
rebalance with unreturned resources fails with the acquiring thread
and call site of every leaked claim.

Usage (armed next to locksan in the tier-1 concurrency tests)::

    from tools.hydralint import leaksan

    with leaksan.sanitized():      # patches the paired APIs,
        run_replay()               # ledgers claims, checks at exit
        platform.shutdown()        # quiesce INSIDE the block

Notes on fidelity:

  * A claimed runtime that goes on active duty is settled either by
    ``_return_runtime`` or by its ``shutdown()`` — platform/cluster
    shutdown is the legitimate end of an active runtime's life, so the
    workload must shut down inside the ``with`` block.
  * Only head-sampled traces are ledgered (``NULL_TRACE`` is a no-op
    singleton and never finishes).
  * The ledger is keyed by object identity; double release is tolerated
    (idempotent ``finish`` / pooled re-claim hand the object around).
  * The meta-lock is a raw ``_thread`` lock so locksan never wraps it
    when both sanitizers are armed together.
"""
from __future__ import annotations

import _thread
import contextlib
import sys
import threading

__all__ = ["LeakSanitizer", "sanitized", "ResourceLeakError"]


class ResourceLeakError(AssertionError):
    pass


def _call_site() -> str:
    """file:line of the nearest frame outside this module."""
    f = sys._getframe(2)
    while f is not None and f.f_code.co_filename == __file__:
        f = f.f_back
    if f is None:
        return "?"
    return f"{f.f_code.co_filename.rsplit('/', 1)[-1]}:{f.f_lineno}"


class LeakSanitizer:
    """Ledger of outstanding claims across every paired API."""

    def __init__(self):
        self._meta = _thread.allocate_lock()   # raw: never locksan-wrapped
        self._outstanding: dict = {}   # (kind, id) -> (label, thread, site)
        self.claims = 0
        self.releases = 0

    # -- ledger ------------------------------------------------------------
    def _on_claim(self, kind: str, obj, label: str = "") -> None:
        site = _call_site()
        with self._meta:
            self._outstanding[(kind, id(obj))] = (
                label, threading.current_thread().name, site)
            self.claims += 1

    def _on_release(self, kind: str, obj) -> None:
        with self._meta:
            if self._outstanding.pop((kind, id(obj)), None) is not None:
                self.releases += 1

    def outstanding(self) -> list:
        with self._meta:
            return [(kind, label, thread, site)
                    for (kind, _oid), (label, thread, site)
                    in sorted(self._outstanding.items(),
                              key=lambda kv: kv[0])]

    # -- patching ----------------------------------------------------------
    @contextlib.contextmanager
    def patched(self):
        """Wrap the paired APIs on their classes.  Imports are lazy so
        the sanitizer (like the rest of hydralint) adds no import-time
        dependency on the runtime package."""
        from repro.core.arena import ArenaPool
        from repro.core.platform import HydraPlatform
        from repro.core.runtime import HydraRuntime
        from repro.core.tracing import RequestTrace, Tracer

        san = self
        saved = [
            (ArenaPool, "acquire", ArenaPool.acquire),
            (ArenaPool, "release", ArenaPool.release),
            (HydraPlatform, "_claim_runtime", HydraPlatform._claim_runtime),
            (HydraPlatform, "_return_runtime", HydraPlatform._return_runtime),
            (HydraRuntime, "shutdown", HydraRuntime.shutdown),
            (Tracer, "start_request", Tracer.start_request),
            (RequestTrace, "finish", RequestTrace.finish),
        ]
        orig = {(cls.__name__, name): fn for cls, name, fn in saved}

        def arena_acquire(pool, *a, **kw):
            arena = orig[("ArenaPool", "acquire")](pool, *a, **kw)
            san._on_claim("arena", arena,
                          str(a[0] if a else kw.get("signature", "")))
            return arena

        def arena_release(pool, arena):
            san._on_release("arena", arena)
            return orig[("ArenaPool", "release")](pool, arena)

        def claim_runtime(plat, *a, **kw):
            rt = orig[("HydraPlatform", "_claim_runtime")](plat, *a, **kw)
            san._on_claim("runtime", rt, getattr(rt, "name", ""))
            return rt

        def return_runtime(plat, rt):
            san._on_release("runtime", rt)
            return orig[("HydraPlatform", "_return_runtime")](plat, rt)

        def runtime_shutdown(rt, *a, **kw):
            # shutdown is the legitimate end of an active claim's life
            san._on_release("runtime", rt)
            return orig[("HydraRuntime", "shutdown")](rt, *a, **kw)

        def start_request(tracer, fid, tenant=None):
            ctx = orig[("Tracer", "start_request")](tracer, fid, tenant)
            if isinstance(ctx, RequestTrace):
                san._on_claim("trace", ctx, fid)
            return ctx

        def trace_finish(ctx, *a, **kw):
            san._on_release("trace", ctx)
            return orig[("RequestTrace", "finish")](ctx, *a, **kw)

        ArenaPool.acquire = arena_acquire
        ArenaPool.release = arena_release
        HydraPlatform._claim_runtime = claim_runtime
        HydraPlatform._return_runtime = return_runtime
        HydraRuntime.shutdown = runtime_shutdown
        Tracer.start_request = start_request
        RequestTrace.finish = trace_finish
        try:
            yield self
        finally:
            for cls, name, fn in saved:
                setattr(cls, name, fn)

    # -- analysis ----------------------------------------------------------
    def check(self) -> list:
        """Human-readable leak reports (empty = clean)."""
        return [
            f"leaked {kind} claim {label!r}: acquired by thread "
            f"{thread} at {site}, never returned"
            for kind, label, thread, site in self.outstanding()]

    def assert_clean(self) -> None:
        leaks = self.check()
        if leaks:
            raise ResourceLeakError(
                f"{len(leaks)} unreturned resource claim(s) at sanitizer "
                "exit:\n" + "\n".join(leaks))


@contextlib.contextmanager
def sanitized():
    """Patch the paired APIs, run the body, fail on outstanding claims.
    Shut the workload down INSIDE the block so active runtimes settle."""
    san = LeakSanitizer()
    with san.patched():
        yield san
    san.assert_clean()
