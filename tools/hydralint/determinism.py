"""HL003 — sim determinism.

The simulator's value rests on the golden-parity pin in
``tests/test_sim.py``: identical trace + params => identical
``SimResult``, bit for bit, across machines and runs.  Anything that
couples the event loop to wall-clock time, unseeded randomness, or hash
iteration order silently breaks that pin.

Scope: files under ``core/sim/``, plus ``core/tracesim.py``,
``core/traces.py``, and ``core/streaming.py`` (path-matched), plus any
file carrying a ``# hydralint: sim-module`` marker (used by fixtures
and future sim modules that live elsewhere).

Flags:
  * ``time.time`` / ``time.monotonic`` / ``time.perf_counter`` /
    ``time.sleep`` calls;
  * module-level ``random.*`` calls (unseeded global RNG);
  * legacy ``np.random.<fn>`` calls (global RNG) and
    ``np.random.default_rng()`` with no seed argument;
  * ``for`` loops iterating directly over a set literal, set
    comprehension, ``set(...)``, or ``frozenset(...)`` — set order is
    hash-order and must not feed event scheduling.
"""
from __future__ import annotations

import ast

from tools.hydralint import Finding, Project, dotted_name
from tools.hydralint.purity import _import_aliases

CODE = "HL003"

SIM_PATH_PARTS = ("core/sim/", "core/tracesim.py", "core/traces.py",
                  "core/streaming.py")
TIME_FNS = {"time", "monotonic", "perf_counter", "sleep", "monotonic_ns",
            "time_ns", "perf_counter_ns"}


def _is_sim_file(sf) -> bool:
    if any(part in sf.path for part in SIM_PATH_PARTS):
        return True
    return sf.has_marker("sim-module")


def check(project: Project) -> list:
    findings = []
    for sf in project.files:
        if not _is_sim_file(sf):
            continue
        aliases = _import_aliases(sf.tree)
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                findings.extend(_check_call(sf.path, node, aliases))
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                findings.extend(_check_for(sf.path, node))
    return findings


def _full_name(name: str, aliases: dict) -> str:
    parts = name.split(".")
    return ".".join([aliases.get(parts[0], parts[0])] + parts[1:])


def _check_call(path: str, node: ast.Call, aliases: dict) -> list:
    name = dotted_name(node.func)
    if name is None:
        return []
    full = _full_name(name, aliases)
    parts = full.split(".")
    if parts[0] == "time" and len(parts) == 2 and parts[1] in TIME_FNS:
        return [Finding(CODE, path, node.lineno, node.col_offset,
                        f"wall-clock call {name}() in sim code — sim time "
                        f"must come from the event queue",
                        f"wallclock:{full}")]
    if parts[0] == "random" and len(parts) == 2:
        return [Finding(CODE, path, node.lineno, node.col_offset,
                        f"global random.{parts[1]}() in sim code — use a "
                        f"seeded np.random.default_rng(seed)",
                        f"unseeded:{full}")]
    if full.startswith("numpy.random.") or full.startswith("np.random."):
        leaf = parts[-1]
        if leaf in ("default_rng", "Generator", "SeedSequence"):
            if leaf == "default_rng" and not node.args and not node.keywords:
                return [Finding(CODE, path, node.lineno, node.col_offset,
                                "np.random.default_rng() without a seed in "
                                "sim code",
                                "unseeded:default_rng")]
            return []
        return [Finding(CODE, path, node.lineno, node.col_offset,
                        f"legacy global np.random.{leaf}() in sim code — "
                        f"use a seeded np.random.default_rng(seed)",
                        f"unseeded:np.random.{leaf}")]
    return []


def _check_for(path: str, node) -> list:
    it = node.iter
    is_set = isinstance(it, (ast.Set, ast.SetComp))
    if isinstance(it, ast.Call):
        name = dotted_name(it.func)
        if name in ("set", "frozenset"):
            is_set = True
    if not is_set:
        return []
    return [Finding(CODE, path, node.lineno, node.col_offset,
                    "iteration over a set in sim code — set order is "
                    "hash-order; sort it before it can feed event scheduling",
                    f"set-iter:L{node.lineno}")]
