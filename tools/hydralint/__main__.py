"""CLI: ``python -m tools.hydralint src/ tests/ [--baseline FILE]``.

Exit codes: 0 clean (or fully baselined), 1 findings / baseline
violations, 2 usage error.  Run from the repo root.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from tools.hydralint import (all_checkers, load_baseline, run_lint,
                             write_baseline)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.hydralint",
        description="Repo-specific static analysis for the Hydra "
                    "reproduction (see docs/development.md).")
    parser.add_argument("paths", nargs="+",
                        help="files or directories to lint (e.g. src/ tests/)")
    parser.add_argument("--root", default=".",
                        help="project root for relative paths and docs "
                             "(default: current directory)")
    parser.add_argument("--baseline", default=None,
                        help="baseline JSON of known findings; lint fails on "
                             "findings not in it AND on stale entries "
                             "(the baseline may only shrink)")
    parser.add_argument("--write-baseline", default=None, metavar="FILE",
                        help="write the current findings to FILE as the new "
                             "baseline and exit 0")
    parser.add_argument("--select", default=None,
                        help="comma-separated checker codes to run "
                             "(default: all)")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="emit findings as JSON instead of text")
    args = parser.parse_args(argv)

    root = Path(args.root).resolve()
    select = None
    if args.select:
        select = {c.strip() for c in args.select.split(",") if c.strip()}
        known = {code for code, _ in all_checkers()}
        bad = select - known - {"HL000"}
        if bad:
            parser.error(f"unknown checker code(s): {', '.join(sorted(bad))}")

    result = run_lint(args.paths, root, select=select)

    if args.write_baseline:
        write_baseline(args.write_baseline, result.findings)
        print(f"[hydralint] wrote {len(result.findings)} finding(s) to "
              f"{args.write_baseline}")
        return 0

    baseline = load_baseline(args.baseline) if args.baseline else {}
    new = result.new_against(baseline)
    stale = result.stale_baseline_keys(baseline)

    if args.as_json:
        print(json.dumps({
            "findings": [vars(f) | {"key": f.key} for f in new],
            "baselined": len(result.findings) - len(new),
            "stale_baseline": stale,
            "suppressed": len(result.suppressed),
        }, indent=2))
    else:
        for f in new:
            print(f.render())
        for k in stale:
            print(f"baseline: stale entry {k!r} no longer matches any "
                  f"finding — remove it (the baseline may only shrink)")
        n_base = len(result.findings) - len(new)
        if not new and not stale:
            print(f"[hydralint] OK: {len(result.suppressed)} suppressed, "
                  f"{n_base} baselined, 0 new")
        else:
            print(f"[hydralint] {len(new)} new finding(s), {len(stale)} "
                  f"stale baseline entr(y/ies)", file=sys.stderr)
    return 1 if (new or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
