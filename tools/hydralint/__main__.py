"""CLI: ``python -m tools.hydralint src/ tests/ [--baseline FILE]``.

Exit codes: 0 clean (or fully baselined), 1 findings / baseline
violations / budget overrun, 2 usage error.  Run from the repo root.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
import time
from pathlib import Path

from tools.hydralint import (all_checkers, load_baseline, run_lint,
                             write_baseline)


def explain(code: str, root: Path) -> str:
    """The invariant-table entry for ``code``: the ``### HL00X — ...``
    section of docs/development.md (rationale, historical bug, how to
    suppress), falling back to the checker module's docstring."""
    doc = root / "docs" / "development.md"
    if doc.exists():
        text = doc.read_text(encoding="utf-8")
        m = re.search(rf"^### {code}[^\n]*\n(.*?)(?=^#{{2,3}} |\Z)",
                      text, re.M | re.S)
        if m:
            return (m.group(0).rstrip() + "\n")
    import importlib

    for ck_code, fn in all_checkers():
        if ck_code == code:
            mod = importlib.import_module(fn.__module__)
            return (mod.__doc__ or f"{code}: no documentation").strip() + "\n"
    return f"{code}: unknown checker code\n"


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.hydralint",
        description="Repo-specific static analysis for the Hydra "
                    "reproduction (see docs/development.md).")
    parser.add_argument("paths", nargs="*",
                        help="files or directories to lint (e.g. src/ tests/)")
    parser.add_argument("--root", default=".",
                        help="project root for relative paths and docs "
                             "(default: current directory)")
    parser.add_argument("--baseline", default=None,
                        help="baseline JSON of known findings; lint fails on "
                             "findings not in it AND on stale entries "
                             "(the baseline may only shrink)")
    parser.add_argument("--write-baseline", default=None, metavar="FILE",
                        help="write the current findings to FILE as the new "
                             "baseline and exit 0")
    parser.add_argument("--select", default=None,
                        help="comma-separated checker codes to run "
                             "(default: all)")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="emit findings as JSON instead of text")
    parser.add_argument("--format", choices=("text", "github"),
                        default="text", dest="fmt",
                        help="finding output format: 'github' emits "
                             "::error workflow annotations that surface "
                             "inline on the PR diff (default: text)")
    parser.add_argument("--explain", metavar="HL00X", default=None,
                        help="print the invariant-table entry for a checker "
                             "code (rationale, historical bug, suppression) "
                             "and exit")
    parser.add_argument("--budget", metavar="FILE", default=None,
                        help="lint-speed gate: fail if the full sweep's wall "
                             "time exceeds the committed 'lint' budget in "
                             "FILE (benchmarks/data/overhead_budget.json)")
    args = parser.parse_args(argv)

    root = Path(args.root).resolve()
    if args.explain:
        sys.stdout.write(explain(args.explain.strip(), root))
        return 0
    if not args.paths:
        parser.error("no paths to lint (or use --explain HL00X)")
    select = None
    if args.select:
        select = {c.strip() for c in args.select.split(",") if c.strip()}
        known = {code for code, _ in all_checkers()}
        bad = select - known - {"HL000"}
        if bad:
            parser.error(f"unknown checker code(s): {', '.join(sorted(bad))}")

    t0 = time.perf_counter()
    result = run_lint(args.paths, root, select=select)
    sweep_s = time.perf_counter() - t0

    if args.write_baseline:
        write_baseline(args.write_baseline, result.findings)
        print(f"[hydralint] wrote {len(result.findings)} finding(s) to "
              f"{args.write_baseline}")
        return 0

    baseline = load_baseline(args.baseline) if args.baseline else {}
    new = result.new_against(baseline)
    stale = result.stale_baseline_keys(baseline)

    if args.as_json:
        print(json.dumps({
            "findings": [vars(f) | {"key": f.key} for f in new],
            "baselined": len(result.findings) - len(new),
            "stale_baseline": stale,
            "suppressed": len(result.suppressed),
        }, indent=2))
    else:
        for f in new:
            if args.fmt == "github":
                # one workflow annotation per finding; the annotation body
                # must stay single-line, so detail rides in the title
                print(f"::error file={f.path},line={f.line},col={f.col},"
                      f"title={f.code} {f.detail}::{f.message}")
            else:
                print(f.render())
        for k in stale:
            print(f"baseline: stale entry {k!r} no longer matches any "
                  f"finding — remove it (the baseline may only shrink)")
        n_base = len(result.findings) - len(new)
        if not new and not stale:
            print(f"[hydralint] OK: {len(result.suppressed)} suppressed, "
                  f"{n_base} baselined, 0 new")
        else:
            print(f"[hydralint] {len(new)} new finding(s), {len(stale)} "
                  f"stale baseline entr(y/ies)", file=sys.stderr)

    over_budget = False
    if args.budget:
        doc = json.loads(Path(args.budget).read_text(encoding="utf-8"))
        limit = float(doc.get("lint", {}).get("hydralint_sweep_s", 0) or 0)
        if limit <= 0:
            parser.error(f"{args.budget} has no lint.hydralint_sweep_s "
                         "budget")
        over_budget = sweep_s > limit
        status = "OVER" if over_budget else "ok"
        line = (f"[hydralint] sweep took {sweep_s:.2f}s against a "
                f"{limit:.2f}s budget — {status}")
        if args.fmt == "github" and over_budget:
            print(f"::error title=hydralint budget::{line}")
        print(line, file=sys.stderr if over_budget else sys.stdout)
    return 1 if (new or stale or over_budget) else 0


if __name__ == "__main__":
    sys.exit(main())
