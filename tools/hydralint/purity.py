"""HL002 — hot-path purity.

History: PR 4 shipped ``jnp.zeros`` arena factories that compiled a fill
kernel per size, and PR 5 found ``workload.args_for`` building payloads
with eager ``jnp.full`` — throttling the open-loop replay ~2.3x until it
was moved to host ``np`` arrays.  The slab-allocator PR moved per-claim
``jax.device_put`` host→device copies off the warm path entirely (slabs
are minted once and scrubbed on-device), so ``device_put`` is banned on
the hot path alongside the jnp constructors, and the claim/return pair
(``ArenaPool.acquire``/``release``) are both roots.  The request path
must not create device arrays, copy host memory to device, trigger XLA
compilation, sleep, or touch the filesystem.

The checker builds a name-resolved call graph from the request-path
roots (gateway admission + worker loop, ``HydraRuntime.invoke`` /
``_do_invoke``, ``TraceWorkload.args_for``, the arena claim path, the
platform/cluster invoke entries) and flags banned calls in any function
reachable from them.  Resolution is deliberately over-approximate
(attribute calls match every project method of that name) but skips
attributes rooted at imported modules (``np.full`` never resolves into
the project) and very common container-method names.

Extra roots can be declared with a ``# hydralint: hot-path-root`` marker
on the ``def`` line.  A scoped ``# hydralint: disable=HL002`` on a def
both silences the body and stops traversal through it — used where the
"impurity" is a modeled cost (registration, lazy restore).
"""
from __future__ import annotations

import ast
from typing import Optional

from tools.hydralint import Finding, Project, dotted_name

CODE = "HL002"

ROOTS = {
    "Gateway.submit",
    "Gateway._worker_loop",
    "Gateway._serve",
    "HydraRuntime.invoke",
    "HydraRuntime._do_invoke",
    "TraceWorkload.args_for",
    "ArenaPool.acquire",
    "ArenaPool.release",
    "HydraPlatform.invoke",
    "HydraCluster.invoke",
}

JNP_CONSTRUCTORS = {
    "zeros", "ones", "full", "empty", "array", "asarray", "arange",
    "linspace", "eye", "zeros_like", "ones_like", "full_like", "identity",
}
COMPILE_TRIGGERS = {"jit", "pjit", "pmap", "xla_computation"}
FILE_IO_METHODS = {"read_text", "write_text", "read_bytes", "write_bytes",
                   "unlink", "mkdir", "glob", "rglob"}
# Container/str methods too generic to resolve through the project.
RESOLVE_STOPLIST = {
    "get", "put", "pop", "append", "extend", "items", "keys", "values",
    "join", "split", "read", "write", "close", "update", "add", "copy",
    "sort", "setdefault", "format", "strip", "startswith", "endswith",
    "encode", "decode", "discard", "remove", "clear", "count", "index",
    "wait", "notify", "notify_all", "set", "is_set", "start",
    # finish: RequestTrace/_NullTrace (hot-path span close, pure),
    # Recorder, CalibrationProbe, LoadResult... — too many unrelated
    # implementations to resolve an attr call by name alone
    "finish",
}


def _import_aliases(tree: ast.Module) -> dict:
    """alias -> full dotted module path, for every import in the file."""
    aliases = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                aliases.setdefault(a.asname or a.name,
                                   f"{node.module}.{a.name}")
    return aliases


def _banned(call: ast.Call, aliases: dict) -> Optional[str]:
    """Return a human label if this call is banned on the hot path."""
    name = dotted_name(call.func)
    if name is None:
        return None
    parts = name.split(".")
    full = ".".join([aliases.get(parts[0], parts[0])] + parts[1:])
    if full == "time.sleep":
        return "time.sleep"
    if full == "builtins.open" or name == "open":
        return "open() file I/O"
    if full.startswith("jax.numpy.") and parts[-1] in JNP_CONSTRUCTORS:
        return f"eager jnp.{parts[-1]} device-array construction"
    if full == "jax.device_put":
        return "device_put host->device copy"
    if full.startswith("jax.") and parts[-1] in COMPILE_TRIGGERS:
        return f"jax.{parts[-1]} compile trigger"
    if len(parts) > 1 and parts[-1] in FILE_IO_METHODS \
            and aliases.get(parts[0], "").startswith(("pathlib", "os")):
        return f"blocking file I/O ({name})"
    return None


class _Graph:
    def __init__(self, project: Project):
        self.project = project
        self.by_qualname = {}     # (path, qualname) -> (SourceFile, FuncInfo)
        self.by_method = {}       # method name -> [(path, qualname)]
        self.classes = {}         # class name -> [(path, "Cls.__init__")]
        self.aliases = {}         # path -> import aliases
        for sf, fi in project.iter_funcs():
            self.by_qualname[(sf.path, fi.qualname)] = (sf, fi)
            leaf = fi.qualname.rsplit(".", 1)[-1]
            self.by_method.setdefault(leaf, []).append((sf.path, fi.qualname))
        for sf in project.files:
            self.aliases[sf.path] = _import_aliases(sf.tree)
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.ClassDef):
                    for stmt in node.body:
                        if isinstance(stmt, (ast.FunctionDef,
                                             ast.AsyncFunctionDef)) \
                                and stmt.name == "__init__":
                            self.classes.setdefault(node.name, []).append(
                                (sf.path, f"{node.name}.__init__"))

    def edges(self, path: str, fi) -> set:
        """Resolve every call in ``fi`` to project (path, qualname) targets."""
        out = set()
        aliases = self.aliases[path]
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name is None:
                continue
            parts = name.split(".")
            if len(parts) == 1:
                leaf = parts[0]
                if leaf in aliases and "." in aliases[leaf]:
                    leaf = aliases[leaf].rsplit(".", 1)[-1]
                key = (path, leaf)
                if key in self.by_qualname:
                    out.add(key)
                out.update(self.classes.get(leaf, ()))
                # module-level func of same name elsewhere (from-imports)
                for tgt in self.by_method.get(leaf, ()):
                    if "." not in tgt[1]:
                        out.add(tgt)
            else:
                if parts[0] in aliases and parts[0] not in ("self", "cls"):
                    continue      # rooted at an imported module: not ours
                leaf = parts[-1]
                if leaf in RESOLVE_STOPLIST:
                    continue
                for tgt in self.by_method.get(leaf, ()):
                    if "." in tgt[1]:       # methods only for attr calls
                        out.add(tgt)
        return out


def check(project: Project) -> list:
    graph = _Graph(project)
    cut = project.scope_suppressed_qualnames(CODE)

    roots = []
    for sf, fi in project.iter_funcs():
        if fi.qualname in ROOTS:
            roots.append((sf.path, fi.qualname))
            continue
        node = fi.node
        body_start = node.body[0].lineno if node.body else node.lineno
        sig = set(range(node.lineno, max(node.lineno, body_start - 1) + 1))
        if sig & sf.marker_lines("hot-path-root"):
            roots.append((sf.path, fi.qualname))

    findings, visited, order = [], set(), list()
    came_from = {}
    queue = [r for r in roots if r not in cut]
    visited.update(queue)
    while queue:
        key = queue.pop(0)
        order.append(key)
        sf, fi = graph.by_qualname[key]
        aliases = graph.aliases[key[0]]
        counts = {}
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Call):
                label = _banned(node, aliases)
                if label:
                    i = counts.get(label, 0)
                    counts[label] = i + 1
                    root = key
                    while root in came_from:
                        root = came_from[root]
                    findings.append(Finding(
                        CODE, key[0], node.lineno, node.col_offset,
                        f"{label} in {fi.qualname}() on the request hot path "
                        f"(reachable from {root[1]})",
                        f"{fi.qualname}:{label}:{i}"))
        for tgt in graph.edges(key[0], fi):
            if tgt in visited or tgt in cut:
                continue
            visited.add(tgt)
            came_from[tgt] = key
            queue.append(tgt)
    return findings
