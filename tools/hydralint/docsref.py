"""HL006 — docs references (the old ``tools/check_docs.py``, as a
hydralint checker).

Every file-path-looking reference in ``README.md`` / ``docs/*.md`` must
point at a real file (exact path or unique basename suffix), and every
``python <script>`` / ``python -m <module>`` command in a fenced code
block must resolve to a shipped script/module that byte-compiles.

``tools/check_docs.py`` remains as a thin shim over this module so the
CI docs job and the documented command keep working.
"""
from __future__ import annotations

import py_compile
import re
from pathlib import Path

from tools.hydralint import Finding, Project

CODE = "HL006"

CMD_RE = re.compile(
    r"(?:PYTHONPATH=\S+\s+)?python3?\s+(-m\s+[A-Za-z0-9_.]+|[A-Za-z0-9_./-]+\.py)")
REF_RE = re.compile(r"[A-Za-z0-9_][A-Za-z0-9_./*-]*\.(?:py|md|yml|yaml|txt)\b")
FENCE_RE = re.compile(r"```[a-z]*\n(.*?)```", re.S)


def doc_files(root: Path) -> list:
    docs = [root / "README.md"]
    docs += sorted((root / "docs").glob("*.md"))
    return [d for d in docs if d.exists()]


def resolve(root: Path, ref: str):
    """A reference resolves if it exists relative to the repo root or is
    a unique basename/suffix of a tracked file."""
    if (root / ref).exists():
        return root / ref
    matches = [p for p in root.rglob(Path(ref).name)
               if p.is_file() and str(p).endswith("/" + ref)
               and ".git" not in p.parts]
    return matches[0] if len(matches) == 1 else None


def _module_exists(root: Path, mod: str) -> bool:
    for base in (root / "src", root):
        path = base / Path(*mod.split("."))
        if path.with_suffix(".py").exists() or (path / "__init__.py").exists():
            return True
    return False


def check_docs(root: Path) -> list:
    """All HL006 findings for the docs under ``root``."""
    root = Path(root)
    findings = []
    for doc in doc_files(root):
        text = doc.read_text()
        rel = doc.relative_to(root).as_posix()
        for i, line in enumerate(text.splitlines(), start=1):
            for m in REF_RE.finditer(line):
                ref = m.group(0)
                if "*" in ref:
                    continue
                if resolve(root, ref) is None:
                    findings.append(Finding(
                        CODE, rel, i, m.start(),
                        f"dangling file reference: {ref}", f"ref:{ref}"))
        for block in FENCE_RE.findall(text):
            # attribute command findings to the first line of the block
            line_no = text[:text.index(block)].count("\n") + 1
            for cmd in CMD_RE.finditer(block):
                target = cmd.group(1)
                if target.startswith("-m"):
                    mod = target.split()[-1]
                    if mod in ("pytest", "pyflakes"):
                        continue
                    if not _module_exists(root, mod):
                        findings.append(Finding(
                            CODE, rel, line_no, 0,
                            f"command references missing module: {mod}",
                            f"module:{mod}"))
                else:
                    script = resolve(root, target)
                    if script is None:
                        findings.append(Finding(
                            CODE, rel, line_no, 0,
                            f"command references missing script: {target}",
                            f"script:{target}"))
                        continue
                    try:
                        py_compile.compile(str(script), doraise=True)
                    except py_compile.PyCompileError as e:
                        findings.append(Finding(
                            CODE, rel, line_no, 0,
                            f"{target} does not compile: {e}",
                            f"compile:{target}"))
    return findings


def check(project: Project) -> list:
    return check_docs(project.root)
