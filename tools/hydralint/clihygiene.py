"""HL007 — argparse hygiene.

Every CLI flag must carry a non-empty ``help=`` string: the launchers
(``repro.launch.serve``, ``benchmarks/bench_trace.py``) are the
documented entry points and ``--help`` is their reference manual.
Mutually-exclusive flag *combos* can't be checked statically in general
— those are enforced by explicit ``parser.error`` calls and exercised
in tests — but the missing-help case is purely syntactic and cheap.
"""
from __future__ import annotations

import ast

from tools.hydralint import Finding, Project, str_const

CODE = "HL007"


def check(project: Project) -> list:
    findings = []
    for sf in project.files:
        for node in ast.walk(sf.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "add_argument"
                    and node.args):
                continue
            flag = str_const(node.args[0])
            if flag is None:
                continue
            help_val = None
            has_help = False
            for kw in node.keywords:
                if kw.arg == "help":
                    has_help = True
                    help_val = str_const(kw.value)
            if not has_help:
                findings.append(Finding(
                    CODE, sf.path, node.lineno, node.col_offset,
                    f"CLI flag {flag} has no help= string",
                    f"no-help:{flag}"))
            elif help_val is not None and not help_val.strip():
                findings.append(Finding(
                    CODE, sf.path, node.lineno, node.col_offset,
                    f"CLI flag {flag} has an empty help= string",
                    f"empty-help:{flag}"))
    return findings
