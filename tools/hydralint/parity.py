"""HL011 — sim/live accounting parity (conservation, not just names).

History: the calibration round trip (PR 5) and the sim-vs-real CI gate
only mean something because the live recorder emits a *complete*
``SimResult`` — HL004 checks that metric *names* stay inside the shared
vocabulary, but nothing checked that the accounting itself is
conserved.  The failure mode is silent: add a ``SimResult`` field (the
sim starts reporting it), forget the recorder/targets mapping, and
every live replay reports the dataclass default — the validation gate
then "passes" by comparing a measured number against a constant.

Three conservation checks over the mapping layer:

* **unfed field** — every field of ``class SimResult`` must be passed
  explicitly where the live recorder (``*recorder*.py``) constructs
  its ``SimResult``; a field the recorder cannot feed is sim-only
  accounting and fails the gate's premise.
* **dead counter** — every key a ``counters()`` provider (in
  ``*targets*.py``) returns must be read by the recorder; an
  accumulated-but-never-folded counter is accounting that leaks out of
  the live ledger.
* **phantom counter** — every ``c["key"]`` / ``c.get("key")`` the
  recorder reads from ``adapter.counters()`` must be returned by every
  provider; a missing key is a ``KeyError`` (or silent zero) at replay
  end.

Suppress with ``# hydralint: disable=HL011`` plus a justification for
a deliberately sim-only or live-only quantity.
"""
from __future__ import annotations

import ast

from tools.hydralint import Finding, Project, dotted_name, str_const

CODE = "HL011"


def _simresult_fields(project: Project):
    """(path, ClassDef, [field names]) for ``class SimResult``."""
    hits = []
    for sf in project.files:
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ClassDef) and node.name == "SimResult":
                fields = []
                for stmt in node.body:
                    if isinstance(stmt, ast.AnnAssign) \
                            and isinstance(stmt.target, ast.Name):
                        fields.append(stmt.target.id)
                    elif isinstance(stmt, ast.Assign):
                        for t in stmt.targets:
                            if isinstance(t, ast.Name):
                                fields.append(t.id)
                if fields:
                    hits.append((sf.path, node, fields))
    hits.sort(key=lambda h: ("engine" not in h[0], h[0]))
    return hits[0] if hits else None


def _constructions(sf):
    """SimResult(...) calls in one file: (call, {keywords}, has_star)."""
    out = []
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        if name is None or name.split(".")[-1] != "SimResult":
            continue
        kws = {kw.arg for kw in node.keywords if kw.arg is not None}
        has_star = any(kw.arg is None for kw in node.keywords)
        out.append((node, kws, has_star))
    return out


def _counter_reads(sf):
    """Keys read off variables assigned from ``*.counters()`` calls:
    (key, read node)."""
    counters_vars: set = set()
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            name = dotted_name(node.value.func)
            if name and name.split(".")[-1] == "counters":
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        counters_vars.add(t.id)
    reads = []
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Subscript) \
                and isinstance(node.value, ast.Name) \
                and node.value.id in counters_vars:
            key = str_const(node.slice)
            if key is not None:
                reads.append((key, node))
        elif isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "get" \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id in counters_vars and node.args:
            key = str_const(node.args[0])
            if key is not None:
                reads.append((key, node))
    return reads


def _providers(sf):
    """counters() implementations returning dict literals:
    (qualname, dict node, {keys})."""
    out = []

    def visit(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                visit(child, prefix + child.name + ".")
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if child.name == "counters":
                    for ret in ast.walk(child):
                        if isinstance(ret, ast.Return) \
                                and isinstance(ret.value, ast.Dict):
                            keys = {str_const(k) for k in ret.value.keys}
                            keys.discard(None)
                            out.append((prefix + child.name,
                                        ret.value, keys))
                visit(child, prefix + child.name + ".")
    visit(sf.tree, "")
    return out


def _map_files(project: Project, token: str):
    return [sf for sf in project.files
            if token in sf.path.rsplit("/", 1)[-1]]


def check(project: Project) -> list:
    findings = []
    sim = _simresult_fields(project)
    recorders = _map_files(project, "recorder")
    targets = _map_files(project, "targets")

    # 1. unfed fields: the recorder's SimResult(...) must feed everything
    if sim is not None and recorders:
        _path, _cls, fields = sim
        for sf in recorders:
            for call, kws, has_star in _constructions(sf):
                if has_star:
                    continue        # **kwargs: not statically checkable
                for f in fields:
                    if f not in kws:
                        findings.append(Finding(
                            CODE, sf.path, call.lineno, call.col_offset,
                            f"SimResult field {f!r} is not fed by this "
                            f"live-recorder construction — the sim "
                            f"reports it, the live replay would report "
                            f"the dataclass default",
                            f"unfed:{f}"))

    # 2/3. counter conservation between providers and recorder reads
    reads: dict = {}
    for sf in recorders:
        for key, node in _counter_reads(sf):
            reads.setdefault(key, (sf, node))
    for sf in targets:
        for qualname, dnode, keys in _providers(sf):
            for key in sorted(keys - set(reads)):
                findings.append(Finding(
                    CODE, sf.path, dnode.lineno, dnode.col_offset,
                    f"counter {key!r} returned by {qualname}() is never "
                    f"read by the recorder — accumulated accounting "
                    f"leaks out of the live SimResult",
                    f"dead-counter:{key}:{qualname}"))
            for key in sorted(set(reads) - keys):
                rsf, rnode = reads[key]
                findings.append(Finding(
                    CODE, rsf.path, rnode.lineno, rnode.col_offset,
                    f"recorder reads counter {key!r} that {qualname}() "
                    f"does not return — KeyError (or silent zero) at "
                    f"replay end",
                    f"phantom-counter:{key}:{qualname}"))
    return findings
