"""Docs consistency checker (run by the CI docs job and locally):

  python tools/check_docs.py

1. Every file-path-looking reference in README.md and docs/*.md must
   point at a real file in the repo (exact path, or unique suffix for
   bare names like ``serve.py``).
2. Every ``python <script>`` / ``python -m <module>`` command shown in a
   fenced code block must resolve to a shipped script/module, and the
   script must at least byte-compile.

Exits non-zero with a report when anything dangles.
"""
from __future__ import annotations

import py_compile
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

CMD_RE = re.compile(
    r"(?:PYTHONPATH=\S+\s+)?python3?\s+(-m\s+[A-Za-z0-9_.]+|[A-Za-z0-9_./-]+\.py)")


def doc_files() -> list:
    docs = [ROOT / "README.md"]
    docs += sorted((ROOT / "docs").glob("*.md"))
    return [d for d in docs if d.exists()]


def resolve(ref: str):
    """A reference resolves if it exists relative to the repo root or is
    a unique basename/suffix of a tracked file."""
    if (ROOT / ref).exists():
        return ROOT / ref
    matches = [p for p in ROOT.rglob(Path(ref).name)
               if p.is_file() and str(p).endswith("/" + ref)
               and ".git" not in p.parts]
    return matches[0] if len(matches) == 1 else None


def main() -> int:
    errors = []
    fence = re.compile(r"```[a-z]*\n(.*?)```", re.S)
    for doc in doc_files():
        text = doc.read_text()
        rel = doc.relative_to(ROOT)

        # 1) file references (skip globs)
        for m in re.finditer(
                r"[A-Za-z0-9_][A-Za-z0-9_./*-]*\.(?:py|md|yml|yaml|txt)\b",
                text):
            ref = m.group(0)
            if "*" in ref:
                continue
            if resolve(ref) is None:
                errors.append(f"{rel}: dangling file reference: {ref}")

        # 2) commands in fenced code blocks
        for block in fence.findall(text):
            for cmd in CMD_RE.finditer(block):
                target = cmd.group(1)
                if target.startswith("-m"):
                    mod = target.split()[-1]
                    if mod == "pytest":
                        continue
                    path = ROOT / "src" / Path(*mod.split("."))
                    if not (path.with_suffix(".py").exists()
                            or (path / "__init__.py").exists()):
                        errors.append(f"{rel}: command references missing "
                                      f"module: {mod}")
                else:
                    script = resolve(target)
                    if script is None:
                        errors.append(f"{rel}: command references missing "
                                      f"script: {target}")
                        continue
                    try:
                        py_compile.compile(str(script), doraise=True)
                    except py_compile.PyCompileError as e:
                        errors.append(f"{rel}: {target} does not compile: "
                                      f"{e}")

    if not doc_files():
        errors.append("no docs found (README.md / docs/*.md)")
    for e in errors:
        print(f"[check_docs] {e}", file=sys.stderr)
    if not errors:
        print(f"[check_docs] OK: {len(doc_files())} docs, all file "
              "references and commands resolve")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
