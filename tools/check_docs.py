"""Docs consistency checker (run by the CI docs job and locally):

  python tools/check_docs.py

Thin shim over the hydralint HL006 checker (``tools.hydralint.docsref``)
so the documented command and the CI docs job keep working; the same
check also runs inside ``python -m tools.hydralint``.
"""
from __future__ import annotations

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))

from tools.hydralint import docsref  # noqa: E402


def main() -> int:
    findings = docsref.check_docs(ROOT)
    docs = docsref.doc_files(ROOT)
    if not docs:
        findings = list(findings)
        print("[check_docs] no docs found (README.md / docs/*.md)",
              file=sys.stderr)
        return 1
    for f in findings:
        print(f"[check_docs] {f.path}: {f.message}", file=sys.stderr)
    if not findings:
        print(f"[check_docs] OK: {len(docs)} docs, all file "
              "references and commands resolve")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
