"""Trace layer: the Trace sequence interface, synthetic parity, the
Azure Functions 2019 loader (determinism, thinning, schema errors), and
the streaming loader (parity, windowing, selection, sharding, bounded
memory)."""
import os

import pytest

from repro.core.streaming import StreamingTrace
from repro.core.traces import Invocation, Trace, gen_trace, load_azure_trace

MB = 1 << 20
DATA = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "data")
SAMPLE = os.path.join(DATA, "azure_sample.csv")
SAMPLE_DUR = os.path.join(DATA, "azure_sample_durations.csv")
SAMPLE_MEM = os.path.join(DATA, "azure_sample_memory.csv")


# ---------------------------------------------------------------------------
def test_trace_is_a_sequence_over_invocations():
    tr = Trace.synthetic(n_functions=10, n_tenants=2, duration_s=20.0,
                         mean_rps=4.0, seed=3)
    assert len(tr) > 0
    assert isinstance(tr[0], Invocation)
    assert isinstance(tr[:5], Trace) and len(tr[:5]) == 5
    assert list(tr) == list(tr.invocations)
    assert tr.duration_s == tr[-1].t
    d = tr.describe()
    assert d["source"] == "synthetic" and d["invocations"] == len(tr)


def test_synthetic_trace_matches_gen_trace():
    kw = dict(n_functions=10, n_tenants=2, duration_s=20.0, mean_rps=4.0,
              seed=3)
    assert list(Trace.synthetic(**kw)) == gen_trace(**kw)


# ---------------------------------------------------------------------------
# Azure loader on the bundled sample
# ---------------------------------------------------------------------------
def test_azure_sample_loads_with_tables():
    tr = Trace.from_azure(SAMPLE, durations_csv=SAMPLE_DUR,
                          memory_csv=SAMPLE_MEM)
    assert tr.source == "azure"
    assert len(tr) > 1000
    ts = [i.t for i in tr]
    assert ts == sorted(ts)
    assert all(i.duration_s > 0 for i in tr)
    assert all(i.mem_bytes >= 16 * MB for i in tr)
    d = tr.describe()
    assert d["functions"] == 36 and d["tenants"] == 18


def test_azure_loader_is_deterministic():
    a = Trace.from_azure(SAMPLE, durations_csv=SAMPLE_DUR,
                         memory_csv=SAMPLE_MEM, seed=1)
    b = Trace.from_azure(SAMPLE, durations_csv=SAMPLE_DUR,
                         memory_csv=SAMPLE_MEM, seed=1)
    assert list(a) == list(b)
    c = Trace.from_azure(SAMPLE, durations_csv=SAMPLE_DUR,
                         memory_csv=SAMPLE_MEM, seed=2)
    assert list(a) != list(c)          # seed actually drives expansion


def test_azure_thinning_hits_target_rps_deterministically():
    full = Trace.from_azure(SAMPLE)
    thin = Trace.from_azure(SAMPLE, target_rps=1.0, seed=5)
    again = Trace.from_azure(SAMPLE, target_rps=1.0, seed=5)
    assert list(thin) == list(again)
    assert len(thin) < len(full)
    # binomial thinning lands near the target (the sample runs ~3 rps)
    assert thin.mean_rps == pytest.approx(1.0, rel=0.25)
    assert thin.meta["thinning_keep"] < 1.0
    # thinning preserves the invocation universe, not just a prefix
    assert {i.fid for i in thin} <= {i.fid for i in full}


def test_azure_loader_works_without_tables():
    tr = Trace.from_azure(SAMPLE)      # falls back to seeded lognormals
    assert len(tr) > 1000
    assert all(0.1 <= i.duration_s <= 3.0 for i in tr)


def test_azure_sparse_minute_columns_keep_real_timeline(tmp_path):
    """A trimmed export whose zero-count minute columns were dropped must
    keep its idle gaps: timestamps follow the numeric minute labels, not
    the column positions."""
    p = tmp_path / "gap.csv"
    p.write_text("HashOwner,HashApp,HashFunction,Trigger,1,5,20\n"
                 "o1,a1,f1,http,2,2,2\n")
    tr = load_azure_trace(str(p))
    ts = [i.t for i in tr]
    assert len(ts) == 6
    assert min(ts) < 60.0                 # minute '1' -> [0, 60)
    assert max(ts) >= 19 * 60.0           # minute '20' -> [1140, 1200)
    # the realized rate uses the real 20-minute horizon
    assert tr.meta["raw_invocations"] == 6
    # max_minutes truncates by minute label too, not column position
    first2 = load_azure_trace(str(p), max_minutes=2)
    assert len(first2) == 2 and max(i.t for i in first2) < 60.0


def test_azure_max_minutes_truncates():
    tr = Trace.from_azure(SAMPLE, max_minutes=5)
    assert tr.duration_s <= 5 * 60.0
    assert len(tr) > 0


# ---------------------------------------------------------------------------
# Schema errors
# ---------------------------------------------------------------------------
def test_azure_missing_required_columns(tmp_path):
    p = tmp_path / "bad.csv"
    p.write_text("HashOwner,Trigger,1,2\no1,http,1,0\n")
    with pytest.raises(ValueError, match="HashFunction"):
        load_azure_trace(str(p))


def test_azure_missing_minute_columns(tmp_path):
    p = tmp_path / "bad.csv"
    p.write_text("HashOwner,HashApp,HashFunction,Trigger\no1,a1,f1,http\n")
    with pytest.raises(ValueError, match="per-minute"):
        load_azure_trace(str(p))


def test_azure_empty_file(tmp_path):
    p = tmp_path / "empty.csv"
    p.write_text("")
    with pytest.raises(ValueError, match="empty"):
        load_azure_trace(str(p))


def test_azure_no_data_rows(tmp_path):
    p = tmp_path / "hdr.csv"
    p.write_text("HashOwner,HashApp,HashFunction,Trigger,1\n")
    with pytest.raises(ValueError, match="no data rows"):
        load_azure_trace(str(p))


def test_azure_bad_durations_schema(tmp_path):
    p = tmp_path / "ok.csv"
    p.write_text("HashOwner,HashApp,HashFunction,Trigger,1\no1,a1,f1,http,2\n")
    d = tmp_path / "dur.csv"
    d.write_text("Function,Average\nf1,100\n")
    with pytest.raises(ValueError, match="HashFunction"):
        load_azure_trace(str(p), durations_csv=str(d))


# ---------------------------------------------------------------------------
# The loaded trace drives the simulator
# ---------------------------------------------------------------------------
def test_azure_trace_simulates():
    from repro.core.sim import SimParams, simulate
    tr = Trace.from_azure(SAMPLE, durations_csv=SAMPLE_DUR,
                          memory_csv=SAMPLE_MEM, target_rps=0.5, seed=0)
    r = simulate(tr, "hydra-pool", SimParams())
    assert len(r.latencies) + r.dropped == len(tr)
    assert r.ops_per_gb_s() > 0


def test_azure_sample_density_ordering():
    """Acceptance: on the bundled sample at fleet pressure (single-node
    fixed pool sized for the fleet's peak warm capacity, cluster pools
    EWMA-adaptive), density orders hydra-cluster >= hydra-pool >= hydra
    — the ordering bench_trace's azure section reports."""
    from repro.core.sim import SimParams, simulate
    GB = 1 << 30
    tr = Trace.from_azure(SAMPLE, durations_csv=SAMPLE_DUR,
                          memory_csv=SAMPLE_MEM)
    p = SimParams(runtime_cap=192 * MB, machine_cap=3 * GB, n_nodes=4,
                  pool_size=8, pool_min=1, pool_max=2)
    ops = {m: simulate(tr, m, p).ops_per_gb_s()
           for m in ("hydra", "hydra-pool", "hydra-cluster")}
    assert ops["hydra-cluster"] >= ops["hydra-pool"] >= ops["hydra"]


# ---------------------------------------------------------------------------
# Streaming loader (repro.core.streaming)
# ---------------------------------------------------------------------------
def test_stream_matches_from_azure_byte_for_byte():
    """Acceptance: the streaming loader and the in-memory loader agree
    invocation-for-invocation — with tables, without tables, thinned."""
    mem = Trace.from_azure(SAMPLE, durations_csv=SAMPLE_DUR,
                           memory_csv=SAMPLE_MEM)
    st = Trace.stream_azure(SAMPLE, durations_csv=SAMPLE_DUR,
                            memory_csv=SAMPLE_MEM)
    assert list(st) == list(mem)
    assert list(Trace.stream_azure(SAMPLE)) == list(Trace.from_azure(SAMPLE))
    thin_m = Trace.from_azure(SAMPLE, target_rps=1.0, seed=5)
    thin_s = Trace.stream_azure(SAMPLE, target_rps=1.0, seed=5)
    assert list(thin_s) == list(thin_m)
    assert thin_s.keep == thin_m.meta["thinning_keep"]


def test_stream_is_reiterable_and_reports_counts():
    st = Trace.stream_azure(SAMPLE)
    a = list(st)
    assert list(st) == a                  # a second pass is identical
    assert st.last_count == len(a)
    d = st.describe()
    assert d["invocations"] == len(a)
    assert d["functions"] == 36 and d["tenants"] == 18
    assert d["source"] == "azure-stream"


def test_stream_chunk_size_invariant():
    base = list(Trace.stream_azure(SAMPLE))
    for chunk in (1, 7, 10_000):
        assert list(Trace.stream_azure(SAMPLE, chunk_rows=chunk)) == base


def test_stream_minute_window_is_a_subslice():
    """Per-cell seeded RNG: a minute window expands byte-identically to
    the same minutes of the full stream."""
    full = list(Trace.stream_azure(SAMPLE))
    win = Trace.stream_azure(SAMPLE, minute_range=(5, 10))
    want = [i for i in full if 4 * 60.0 <= i.t < 10 * 60.0]
    assert list(win) == want
    sub = Trace.stream_azure(SAMPLE, minute_range=(1, 30)) \
        .window(5, 10)
    assert list(sub) == want


def test_stream_top_k_keeps_busiest_rows():
    full = Trace.stream_azure(SAMPLE)
    totals = {f.fid: f.total_invocations for f in full.functions()}
    top = Trace.stream_azure(SAMPLE, top_k=5)
    fids = {f.fid for f in top.functions()}
    assert fids == set(sorted(totals, key=lambda f: (-totals[f], f))[:5])
    # kept rows expand byte-identically to their slice of the full stream
    assert list(top) == [i for i in list(full) if i.fid in fids]


def test_stream_stratified_selection_spans_popularity():
    import numpy as np
    k = 4
    full = Trace.stream_azure(SAMPLE)
    totals = {f.fid: f.total_invocations for f in full.functions()}
    ranked = sorted(totals, key=lambda f: (-totals[f], f))
    strata = np.array_split(np.arange(len(ranked)), k)
    strat = Trace.stream_azure(SAMPLE, top_k=k, select="stratified")
    picked = sorted(f.fid for f in strat.functions())
    assert len(picked) == k
    # one pick per popularity stratum: head, torso, and tail represented
    ranks = sorted(ranked.index(fid) for fid in picked)
    for rank, stratum in zip(ranks, strata):
        assert stratum[0] <= rank <= stratum[-1]
    # deterministic per seed
    again = Trace.stream_azure(SAMPLE, top_k=k, select="stratified")
    assert sorted(f.fid for f in again.functions()) == picked


def test_stream_shard_partition_and_union():
    full = Trace.stream_azure(SAMPLE)
    all_inv = list(full)
    shards = [full.shard(3, i) for i in range(3)]
    parts = [list(s) for s in shards]
    for i, part in enumerate(parts):
        assert part and all(inv.tenant % 3 == i for inv in part)
    merged = sorted((inv for p in parts for inv in p),
                    key=lambda i: (i.t, i.fid))
    assert merged == all_inv
    # thinning keep is fixed BEFORE the shard filter: thinned shards
    # union to exactly the thinned unsharded trace
    thin = Trace.stream_azure(SAMPLE, target_rps=1.0, seed=5)
    tparts = [list(thin.shard(2, i)) for i in range(2)]
    assert sorted(tparts[0] + tparts[1], key=lambda i: (i.t, i.fid)) \
        == list(thin)


def test_stream_functions_metadata_matches_expansion():
    st = Trace.stream_azure(SAMPLE, durations_csv=SAMPLE_DUR,
                            memory_csv=SAMPLE_MEM)
    by_fid = {}
    for inv in st:
        by_fid.setdefault(inv.fid, []).append(inv)
    fns = {f.fid: f for f in st.functions()}
    assert set(fns) == set(by_fid)
    for fid, group in by_fid.items():
        f = fns[fid]
        assert f.total_invocations == len(group)
        assert all(i.tenant == f.tenant for i in group)
        assert all(i.mem_bytes == f.mem_bytes for i in group)


def test_stream_peak_buffered_bounded_by_busiest_minute(tmp_path):
    """Acceptance: peak resident invocations are set by the busiest
    minute, NOT the trace length — 40x more minutes, same peak."""
    def write(minutes):
        cols = ",".join(str(m) for m in range(1, minutes + 1))
        counts = ",".join("40" for _ in range(minutes))
        p = tmp_path / f"t{minutes}.csv"
        p.write_text("HashOwner,HashApp,HashFunction,"
                     f"{cols}\no1,a1,f1,{counts}\n")
        return str(p)

    peaks = {}
    for minutes in (10, 100, 400):
        st = Trace.stream_azure(write(minutes))
        assert sum(1 for _ in st) == 40 * minutes
        peaks[minutes] = st.peak_buffered
    assert peaks[10] == peaks[100] == peaks[400] == 40


def test_stream_malformed_counts_raise(tmp_path):
    base = "HashOwner,HashApp,HashFunction,1,2\n"
    for bad in ("abc", "-3", "1.5", "inf"):
        p = tmp_path / "bad.csv"
        p.write_text(base + f"o1,a1,f1,{bad},2\n")
        with pytest.raises(ValueError, match="invocation count"):
            StreamingTrace(str(p))


def test_stream_empty_expansion_raises(tmp_path):
    p = tmp_path / "zero.csv"
    p.write_text("HashOwner,HashApp,HashFunction,1,2\no1,a1,f1,0,0\n")
    with pytest.raises(ValueError, match="zero invocations"):
        Trace.stream_azure(str(p))
    with pytest.raises(ValueError, match="minute_range"):
        Trace.stream_azure(SAMPLE, minute_range=(100, 200))
    with pytest.raises(ValueError, match="chunk_rows"):
        Trace.stream_azure(SAMPLE, chunk_rows=0)
    with pytest.raises(ValueError, match="select"):
        Trace.stream_azure(SAMPLE, top_k=3, select="bogus")
    with pytest.raises(ValueError, match="shard_index"):
        Trace.stream_azure(SAMPLE, n_shards=2, shard_index=5)
