"""Trace layer: the Trace sequence interface, synthetic parity, and the
Azure Functions 2019 loader (determinism, thinning, schema errors)."""
import os

import pytest

from repro.core.traces import Invocation, Trace, gen_trace, load_azure_trace

MB = 1 << 20
DATA = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "data")
SAMPLE = os.path.join(DATA, "azure_sample.csv")
SAMPLE_DUR = os.path.join(DATA, "azure_sample_durations.csv")
SAMPLE_MEM = os.path.join(DATA, "azure_sample_memory.csv")


# ---------------------------------------------------------------------------
def test_trace_is_a_sequence_over_invocations():
    tr = Trace.synthetic(n_functions=10, n_tenants=2, duration_s=20.0,
                         mean_rps=4.0, seed=3)
    assert len(tr) > 0
    assert isinstance(tr[0], Invocation)
    assert isinstance(tr[:5], Trace) and len(tr[:5]) == 5
    assert list(tr) == list(tr.invocations)
    assert tr.duration_s == tr[-1].t
    d = tr.describe()
    assert d["source"] == "synthetic" and d["invocations"] == len(tr)


def test_synthetic_trace_matches_gen_trace():
    kw = dict(n_functions=10, n_tenants=2, duration_s=20.0, mean_rps=4.0,
              seed=3)
    assert list(Trace.synthetic(**kw)) == gen_trace(**kw)


# ---------------------------------------------------------------------------
# Azure loader on the bundled sample
# ---------------------------------------------------------------------------
def test_azure_sample_loads_with_tables():
    tr = Trace.from_azure(SAMPLE, durations_csv=SAMPLE_DUR,
                          memory_csv=SAMPLE_MEM)
    assert tr.source == "azure"
    assert len(tr) > 1000
    ts = [i.t for i in tr]
    assert ts == sorted(ts)
    assert all(i.duration_s > 0 for i in tr)
    assert all(i.mem_bytes >= 16 * MB for i in tr)
    d = tr.describe()
    assert d["functions"] == 36 and d["tenants"] == 18


def test_azure_loader_is_deterministic():
    a = Trace.from_azure(SAMPLE, durations_csv=SAMPLE_DUR,
                         memory_csv=SAMPLE_MEM, seed=1)
    b = Trace.from_azure(SAMPLE, durations_csv=SAMPLE_DUR,
                         memory_csv=SAMPLE_MEM, seed=1)
    assert list(a) == list(b)
    c = Trace.from_azure(SAMPLE, durations_csv=SAMPLE_DUR,
                         memory_csv=SAMPLE_MEM, seed=2)
    assert list(a) != list(c)          # seed actually drives expansion


def test_azure_thinning_hits_target_rps_deterministically():
    full = Trace.from_azure(SAMPLE)
    thin = Trace.from_azure(SAMPLE, target_rps=1.0, seed=5)
    again = Trace.from_azure(SAMPLE, target_rps=1.0, seed=5)
    assert list(thin) == list(again)
    assert len(thin) < len(full)
    # binomial thinning lands near the target (the sample runs ~3 rps)
    assert thin.mean_rps == pytest.approx(1.0, rel=0.25)
    assert thin.meta["thinning_keep"] < 1.0
    # thinning preserves the invocation universe, not just a prefix
    assert {i.fid for i in thin} <= {i.fid for i in full}


def test_azure_loader_works_without_tables():
    tr = Trace.from_azure(SAMPLE)      # falls back to seeded lognormals
    assert len(tr) > 1000
    assert all(0.1 <= i.duration_s <= 3.0 for i in tr)


def test_azure_sparse_minute_columns_keep_real_timeline(tmp_path):
    """A trimmed export whose zero-count minute columns were dropped must
    keep its idle gaps: timestamps follow the numeric minute labels, not
    the column positions."""
    p = tmp_path / "gap.csv"
    p.write_text("HashOwner,HashApp,HashFunction,Trigger,1,5,20\n"
                 "o1,a1,f1,http,2,2,2\n")
    tr = load_azure_trace(str(p))
    ts = [i.t for i in tr]
    assert len(ts) == 6
    assert min(ts) < 60.0                 # minute '1' -> [0, 60)
    assert max(ts) >= 19 * 60.0           # minute '20' -> [1140, 1200)
    # the realized rate uses the real 20-minute horizon
    assert tr.meta["raw_invocations"] == 6
    # max_minutes truncates by minute label too, not column position
    first2 = load_azure_trace(str(p), max_minutes=2)
    assert len(first2) == 2 and max(i.t for i in first2) < 60.0


def test_azure_max_minutes_truncates():
    tr = Trace.from_azure(SAMPLE, max_minutes=5)
    assert tr.duration_s <= 5 * 60.0
    assert len(tr) > 0


# ---------------------------------------------------------------------------
# Schema errors
# ---------------------------------------------------------------------------
def test_azure_missing_required_columns(tmp_path):
    p = tmp_path / "bad.csv"
    p.write_text("HashOwner,Trigger,1,2\no1,http,1,0\n")
    with pytest.raises(ValueError, match="HashFunction"):
        load_azure_trace(str(p))


def test_azure_missing_minute_columns(tmp_path):
    p = tmp_path / "bad.csv"
    p.write_text("HashOwner,HashApp,HashFunction,Trigger\no1,a1,f1,http\n")
    with pytest.raises(ValueError, match="per-minute"):
        load_azure_trace(str(p))


def test_azure_empty_file(tmp_path):
    p = tmp_path / "empty.csv"
    p.write_text("")
    with pytest.raises(ValueError, match="empty"):
        load_azure_trace(str(p))


def test_azure_no_data_rows(tmp_path):
    p = tmp_path / "hdr.csv"
    p.write_text("HashOwner,HashApp,HashFunction,Trigger,1\n")
    with pytest.raises(ValueError, match="no data rows"):
        load_azure_trace(str(p))


def test_azure_bad_durations_schema(tmp_path):
    p = tmp_path / "ok.csv"
    p.write_text("HashOwner,HashApp,HashFunction,Trigger,1\no1,a1,f1,http,2\n")
    d = tmp_path / "dur.csv"
    d.write_text("Function,Average\nf1,100\n")
    with pytest.raises(ValueError, match="HashFunction"):
        load_azure_trace(str(p), durations_csv=str(d))


# ---------------------------------------------------------------------------
# The loaded trace drives the simulator
# ---------------------------------------------------------------------------
def test_azure_trace_simulates():
    from repro.core.sim import SimParams, simulate
    tr = Trace.from_azure(SAMPLE, durations_csv=SAMPLE_DUR,
                          memory_csv=SAMPLE_MEM, target_rps=0.5, seed=0)
    r = simulate(tr, "hydra-pool", SimParams())
    assert len(r.latencies) + r.dropped == len(tr)
    assert r.ops_per_gb_s() > 0


def test_azure_sample_density_ordering():
    """Acceptance: on the bundled sample at fleet pressure (single-node
    fixed pool sized for the fleet's peak warm capacity, cluster pools
    EWMA-adaptive), density orders hydra-cluster >= hydra-pool >= hydra
    — the ordering bench_trace's azure section reports."""
    from repro.core.sim import SimParams, simulate
    GB = 1 << 30
    tr = Trace.from_azure(SAMPLE, durations_csv=SAMPLE_DUR,
                          memory_csv=SAMPLE_MEM)
    p = SimParams(runtime_cap=192 * MB, machine_cap=3 * GB, n_nodes=4,
                  pool_size=8, pool_min=1, pool_max=2)
    ops = {m: simulate(tr, m, p).ops_per_gb_s()
           for m in ("hydra", "hydra-pool", "hydra-cluster")}
    assert ops["hydra-cluster"] >= ops["hydra-pool"] >= ops["hydra"]
