"""Direct coverage for core/scheduler.py: TokenBucket refill/burst
semantics under thread contention, and ContinuousBatcher admission
ordering (FIFO pending queue, slot reuse)."""
import threading
import time

import pytest

from repro.core.scheduler import ContinuousBatcher, TokenBucket


# ---------------------------------------------------------------------------
# TokenBucket
# ---------------------------------------------------------------------------
def test_burst_then_empty():
    tb = TokenBucket(rate=0.0, burst=5.0)
    grants = [tb.try_take() for _ in range(8)]
    assert grants == [True] * 5 + [False] * 3


def test_refill_caps_at_burst():
    tb = TokenBucket(rate=1000.0, burst=3.0)
    for _ in range(3):
        assert tb.try_take()
    time.sleep(0.05)                         # >> burst/rate: fully refilled
    grants = sum(tb.try_take() for _ in range(10))
    # refill is capped at burst: after ANY idle period at most `burst`
    # tokens are available immediately (a trickle may add 1 during the
    # take loop itself)
    assert 3 <= grants <= 4


def test_fractional_take_and_refill_rate():
    tb = TokenBucket(rate=10.0, burst=1.0)
    assert tb.try_take(1.0)
    assert not tb.try_take(1.0)
    time.sleep(0.25)                  # ~2.5 tokens accrued, capped at 1
    assert tb.try_take(1.0)
    assert not tb.try_take(1.0)


def test_contention_grants_exactly_burst_with_no_refill():
    # rate=0: the bucket can never refill, so across ANY interleaving of
    # 8 hammering threads exactly `burst` takes may succeed — lost
    # updates would grant more, lock starvation fewer
    tb = TokenBucket(rate=0.0, burst=100.0)
    granted = []
    barrier = threading.Barrier(8)

    def work():
        barrier.wait(timeout=5.0)
        mine = 0
        for _ in range(200):
            if tb.try_take():
                mine += 1
        granted.append(mine)

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10.0)
    assert sum(granted) == 100


def test_contention_with_refill_never_exceeds_budget():
    # with refill, total grants over a window are bounded by
    # burst + rate * elapsed (plus one token of measurement slack)
    tb = TokenBucket(rate=200.0, burst=10.0)
    granted = []
    stop = time.monotonic() + 0.25

    def work():
        mine = 0
        while time.monotonic() < stop:
            if tb.try_take():
                mine += 1
        granted.append(mine)

    t0 = time.monotonic()
    threads = [threading.Thread(target=work) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10.0)
    elapsed = time.monotonic() - t0
    assert sum(granted) <= 10 + 200.0 * elapsed + 1


# ---------------------------------------------------------------------------
# ContinuousBatcher
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def lm_runtime():
    from conftest import bf16_params

    from repro.configs import get_config
    from repro.core import HydraRuntime, LMSpec
    from repro.models.programs import ModelProgram

    cfg = get_config("qwen2.5-3b").reduced()
    params = bf16_params(ModelProgram(cfg))
    rt = HydraRuntime(memory_budget_bytes=2 << 30)
    rt.register_function("t0/lm", LMSpec(cfg=cfg, params=params,
                                         max_seq=64, slots=2),
                         tenant="t0")
    yield rt
    rt.shutdown()


def test_admission_is_fifo_and_bounded_by_slots(lm_runtime):
    b = ContinuousBatcher(lm_runtime, "t0/lm")
    try:
        futs = [b.submit(list(range(4)), max_new=3) for _ in range(3)]
        b.step()
        # 2 slots: the first two pending requests were admitted in
        # submission order; the third stays pending
        assert len(b.active) == 2
        assert len(b.pending) == 1
        admitted = {req.future for req in b.active.values()}
        assert admitted == {futs[0], futs[1]}
        assert b.pending[0].future is futs[2]
        # requests 0/1 finish first (equal max_new), freeing slots for 2
        b.run_until_done(max_steps=50)
        assert all(f.done() for f in futs)
        assert futs[2].result()  # admitted after a slot freed
        done_order = sorted(range(3), key=lambda i: len(futs[i].result()))
        assert all(len(f.result()) == 3 for f in futs), done_order
        assert not b.pending and not b.active
        assert sorted(b.free) == [0, 1]
    finally:
        b.close()


def test_slot_reuse_keeps_serving_after_drain(lm_runtime):
    b = ContinuousBatcher(lm_runtime, "t0/lm")
    try:
        first = [b.submit([1, 2, 3], max_new=2) for _ in range(2)]
        b.run_until_done(max_steps=50)
        assert all(len(f.result()) == 2 for f in first)
        # slots were returned: a second wave admits (and with max_new=2
        # completes — prefill + one decode) within a single step
        second = b.submit([4, 5], max_new=2)
        b.step()
        assert second.done()
        assert len(second.result()) == 2
        assert not b.pending and not b.active
    finally:
        b.close()
