"""BENCH_trace.json contract: clean empty-window CLI exits, artifact
schema validation, the CI drift/regression gate, --emit-bench, and the
warm-path overhead budget gate."""
import copy
import json
import os

import pytest

from benchmarks import bench_artifact, bench_hotpath, bench_trace

DATA = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "data")
SAMPLE = os.path.join(DATA, "azure_sample.csv")


@pytest.fixture(scope="module")
def artifact():
    # one short full-model sweep shared by the schema/gate tests
    return bench_artifact.build_artifact(SAMPLE, max_minutes=5)


def _zero_csv(tmp_path):
    p = tmp_path / "zero.csv"
    p.write_text("HashOwner,HashApp,HashFunction,1,2\no1,a1,f1,0,0\n")
    return str(p)


# ---------------------------------------------------------------------------
# Clean CLI exits (no tracebacks) on unusable windows
# ---------------------------------------------------------------------------
def test_bench_trace_empty_window_exits_cleanly(tmp_path, capsys):
    rc = bench_trace.main(["--trace-file", _zero_csv(tmp_path)])
    assert rc == 2
    err = capsys.readouterr().err
    assert err.startswith("bench_trace:")
    assert "zero invocations" in err
    assert "Traceback" not in err


def test_bench_trace_select_requires_top_k(capsys):
    rc = bench_trace.main(["--select", "stratified"])
    assert rc == 2
    assert "--top-k" in capsys.readouterr().err


def test_bench_artifact_cli_flag_combos(tmp_path, capsys):
    # no output or check target: nothing to do
    assert bench_artifact.main([]) == 2
    assert bench_artifact.main(
        ["--gateway-compress", "60", "--out", "x.json"]) == 2
    assert bench_artifact.main(
        ["--out", "x.json", "--trace-file", "/no/such.csv"]) == 2
    rc = bench_artifact.main(
        ["--out", str(tmp_path / "b.json"),
         "--trace-file", _zero_csv(tmp_path)])
    assert rc == 2
    err = capsys.readouterr().err
    assert "bench_artifact:" in err and "Traceback" not in err
    assert not (tmp_path / "b.json").exists()


# ---------------------------------------------------------------------------
# Artifact schema validation
# ---------------------------------------------------------------------------
def test_built_artifact_is_valid(artifact):
    assert bench_artifact.validate_artifact(artifact) == []
    assert artifact["schema"] == bench_artifact.SCHEMA
    assert artifact["trace"]["path"] == "azure_sample.csv"
    assert len(artifact["trace"]["sha256"]) == 64
    assert artifact["streaming"]["peak_buffered"] > 0
    assert artifact["density_ordering"]["holds"] is True


def test_validate_artifact_rejects_bad_docs(artifact):
    bad = copy.deepcopy(artifact)
    bad["schema"] = "hydra-bench/v0"
    assert any("schema" in e
               for e in bench_artifact.validate_artifact(bad))
    bad = copy.deepcopy(artifact)
    bad["models"]["hydra"]["p99_s"] = float("nan")
    assert any("non-finite" in e
               for e in bench_artifact.validate_artifact(bad))
    bad = copy.deepcopy(artifact)
    bad["models"]["hydra"]["ops_per_gb_s"] = -1.0
    assert any("> 0" in e for e in bench_artifact.validate_artifact(bad))
    bad = copy.deepcopy(artifact)
    del bad["models"]["hydra-pool"]
    assert any("missing from sweep" in e
               for e in bench_artifact.validate_artifact(bad))
    bad = copy.deepcopy(artifact)
    bad["density_ordering"]["holds"] = False
    assert any("ordering" in e
               for e in bench_artifact.validate_artifact(bad))


# ---------------------------------------------------------------------------
# The CI gate: schema drift and ordering regressions
# ---------------------------------------------------------------------------
def test_check_against_passes_value_drift(artifact):
    moved = copy.deepcopy(artifact)
    for m in moved["models"].values():
        m["p99_s"] *= 1.7            # values may move PR over PR
    assert bench_artifact.check_against(moved, artifact) == []


def test_check_against_flags_schema_drift(artifact):
    dropped = copy.deepcopy(artifact)
    del dropped["models"]["hydra"]["cold_runtime"]
    errs = bench_artifact.check_against(dropped, artifact)
    assert any("disappeared" in e and "cold_runtime" in e for e in errs)
    grown = copy.deepcopy(artifact)
    grown["models"]["hydra"]["new_metric"] = 1.0
    errs = bench_artifact.check_against(grown, artifact)
    assert any("appeared" in e and "new_metric" in e for e in errs)


def test_check_against_flags_ordering_regression(artifact):
    broken = copy.deepcopy(artifact)
    broken["density_ordering"]["holds"] = False
    errs = bench_artifact.check_against(broken, artifact)
    assert any("regression" in e for e in errs)
    # held in neither document: not a regression
    never = copy.deepcopy(artifact)
    never["density_ordering"]["holds"] = False
    assert bench_artifact.check_against(broken, never) == []


# ---------------------------------------------------------------------------
# The overhead budget gate (benchmarks/bench_hotpath.py)
# ---------------------------------------------------------------------------
FAKE_RESULT = {"arena_us": {"zeroed_reuse": {"mean": 120.0},
                            "donated_reuse": {"mean": 3.0}},
               "invoke_ms": {"mean": 0.5, "p99": 1.2},
               "invoke_traced_ms": {"off_delta_mean": 0.001,
                                    "on": {"mean": 0.6}}}


def test_check_budget_logic():
    ok = {"budgets": {"warm_invoke_ms_mean": 2.0,
                      "warm_invoke_ms_p99": 10.0,
                      "arena_zeroed_reuse_us_mean": 3000.0,
                      "arena_donated_reuse_us_mean": 500.0,
                      "tracing_off_delta_ms_mean": 0.25,
                      "traced_invoke_ms_mean": 4.0}}
    assert bench_hotpath.check_budget(FAKE_RESULT, ok) == []
    tight = {"budgets": {"warm_invoke_ms_mean": 0.1}}
    errs = bench_hotpath.check_budget(FAKE_RESULT, tight)
    assert len(errs) == 1 and "warm_invoke_ms_mean" in errs[0]
    unknown = {"budgets": {"no_such_metric": 1.0}}
    errs = bench_hotpath.check_budget(FAKE_RESULT, unknown)
    assert errs and "unknown budget key" in errs[0]


def test_committed_budget_keys_all_gateable():
    with open(os.path.join(DATA, "overhead_budget.json")) as f:
        doc = json.load(f)
    assert doc["schema"] == "hydra-overhead-budget/v1"
    # every committed key names a metric the gate measures (an ideal
    # zero-overhead result passes all of them)
    zero = {"arena_us": {"zeroed_reuse": {"mean": 0.0},
                         "donated_reuse": {"mean": 0.0}},
            "invoke_ms": {"mean": 0.0, "p99": 0.0},
            "invoke_traced_ms": {"off_delta_mean": 0.0,
                                 "on": {"mean": 0.0}}}
    assert bench_hotpath.check_budget(zero, doc) == []


def test_hotpath_bench_runs_and_gates(tmp_path, capsys):
    out = tmp_path / "hot.json"
    generous = tmp_path / "budget.json"
    generous.write_text(json.dumps(
        {"schema": "hydra-overhead-budget/v1",
         "budgets": {"warm_invoke_ms_mean": 1e6, "warm_invoke_ms_p99": 1e6,
                     "arena_zeroed_reuse_us_mean": 1e9,
                     "arena_donated_reuse_us_mean": 1e9}}))
    rc = bench_hotpath.main(["--iters", "5", "--json", str(out),
                             "--budget", str(generous)])
    assert rc == 0
    assert "within budget" in capsys.readouterr().out
    res = json.loads(out.read_text())
    # a fully warm invoke never compiles or mints a slab
    assert res["invoke_ms"]["compiles_during"] == 0
    assert res["invoke_ms"]["cold_allocs"] == 0
    # the slab claim path beats the pre-slab per-claim device_put
    assert (res["arena_us"]["donated_reuse"]["mean"]
            < res["arena_us"]["legacy_devput"]["mean"])
    impossible = {"budgets": {"warm_invoke_ms_mean": 1e-9}}
    assert bench_hotpath.check_budget(res, impossible)


# ---------------------------------------------------------------------------
# --emit-bench writes a validated artifact
# ---------------------------------------------------------------------------
def test_emit_bench_writes_valid_artifact(tmp_path, capsys):
    out = tmp_path / "BENCH_trace.json"
    rc = bench_trace.main(["--max-minutes", "5",
                           "--emit-bench", str(out)])
    assert rc == 0, capsys.readouterr().err
    doc = json.loads(out.read_text())
    assert bench_artifact.validate_artifact(doc) == []
    assert doc["trace"]["minutes"] == 5
