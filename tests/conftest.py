import os
import sys

# Tests must see the real single-device CPU backend (the 512-device override
# is reserved for the dry-run); make sure nothing leaks in.
os.environ.pop("XLA_FLAGS", None)

# Repo root on sys.path so `from tools.hydralint import locksan` resolves
# regardless of how pytest was launched (PYTHONPATH=src only adds src/).
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO_ROOT not in sys.path:
    sys.path.insert(0, _REPO_ROOT)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def bf16_params(prog, seed: int = 0):
    params = prog.init(jax.random.PRNGKey(seed))
    return jax.tree.map(
        lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x,
        params)
