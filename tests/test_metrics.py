"""Metrics thread-safety: counters, histograms, and snapshots hammered
from many threads must lose nothing and never raise (gateway workers,
refill threads, and the janitor all write the same Metrics object)."""
import math
import threading

from repro.core.metrics import Histogram, Metrics
from tools.hydralint import locksan

N_THREADS = 8
N_OPS = 500


def _run_threads(fn):
    errors = []
    start = threading.Barrier(N_THREADS)

    def wrap(i):
        try:
            start.wait(timeout=10.0)   # all threads hammer at once
            fn(i)
        except Exception as e:      # pragma: no cover - failure reporting
            errors.append(e)

    threads = [threading.Thread(target=wrap, args=(i,))
               for i in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30.0)
    assert not errors, errors


def test_counter_hammer_loses_no_increments():
    # locksan: Metrics constructed INSIDE the patch so its lock is wrapped
    with locksan.sanitized():
        m = Metrics()

        def work(i):
            for _ in range(N_OPS):
                m.inc("shared")
                m.inc(f"per.{i % 3}", 2)

        _run_threads(work)
    assert m.counters["shared"] == N_THREADS * N_OPS
    total = sum(m.counters[f"per.{k}"] for k in range(3))
    assert total == N_THREADS * N_OPS * 2


def test_histogram_hammer_loses_no_observations():
    with locksan.sanitized():
        m = Metrics()

        def work(i):
            for j in range(N_OPS):
                # fresh names force the creation race the old defaultdict
                # pattern lost observations on
                m.observe(f"h{(i * N_OPS + j) % 7}", float(j))
                m.observe("shared_hist", 1.0)

        _run_threads(work)
    assert m.hists["shared_hist"].count == N_THREADS * N_OPS
    spread = sum(m.hists[f"h{k}"].count for k in range(7))
    assert spread == N_THREADS * N_OPS


def test_snapshot_under_concurrent_writes_is_consistent():
    with locksan.sanitized():
        m = Metrics()
        stop = threading.Event()
        snaps = []

        def writer(i):
            k = 0
            while not stop.is_set() and k < N_OPS * 4:
                m.inc("c")
                m.observe(f"dyn.{k % 11}", k)
                with m.timeit("timed"):
                    pass
                k += 1

        def reader():
            while not stop.is_set():
                snaps.append(m.snapshot())

        threads = [threading.Thread(target=writer, args=(i,))
                   for i in range(4)]
        r = threading.Thread(target=reader)
        for t in threads:
            t.start()
        r.start()
        for t in threads:
            t.join(timeout=30.0)
        stop.set()
        r.join(timeout=10.0)
    assert snaps, "reader never snapshotted"
    final = m.snapshot()
    assert final["counters"]["c"] == 4 * N_OPS * 4
    assert final["hists"]["timed"]["count"] == 4 * N_OPS * 4
    # every interim snapshot was internally sane (no partial histograms)
    for s in snaps:
        for h in s["hists"].values():
            assert h["count"] >= 0
            if h["count"] > 0:
                assert math.isfinite(h["mean"])


def test_empty_histogram_snapshot_is_nan_not_crash():
    h = Histogram()
    s = h.snapshot()
    assert s["count"] == 0
    assert math.isnan(s["mean"]) and math.isnan(s["p99"])
    assert math.isnan(h.percentile(50)) and math.isnan(h.mean)


# ---------------------------------------------------------------------------
# bounded reservoir mode (max_samples)
# ---------------------------------------------------------------------------
def test_reservoir_exact_below_threshold():
    h = Histogram(max_samples=100)
    for i in range(100):
        h.observe(float(i))
    # under the bound the histogram is exact: every value retained
    assert sorted(h._vals) == [float(i) for i in range(100)]
    assert h.count == 100 and h.sum == sum(range(100))
    assert abs(h.percentile(50) - 49.5) < 1e-9


def test_reservoir_bounds_memory_but_keeps_exact_count_sum():
    h = Histogram(max_samples=64, seed=1)
    n = 10_000
    for i in range(n):
        h.observe(float(i))
    assert len(h._vals) == 64                 # bounded, not unbounded
    assert h.count == n                       # running totals stay exact
    assert h.sum == float(sum(range(n)))
    assert abs(h.mean - (n - 1) / 2) < 1e-9
    c, s = h.count_sum()                      # the probe's atomic pair
    assert (c, s) == (n, float(sum(range(n))))
    snap = h.snapshot()
    assert snap["count"] == n                 # snapshot count exact too
    assert abs(snap["mean"] - (n - 1) / 2) < 1e-9
    # quantiles are estimates from a uniform sample of the stream: for
    # 10k uniform values and k=64 they land well inside the bulk
    assert 0.0 <= snap["p50"] <= n
    q = sorted(h._vals)[len(h._vals) // 2]
    assert 0.1 * n < q < 0.9 * n


def test_reservoir_is_seed_deterministic():
    def fill(seed):
        h = Histogram(max_samples=32, seed=seed)
        for i in range(1000):
            h.observe(float(i))
        return list(h._vals)

    assert fill(7) == fill(7)
    assert fill(7) != fill(8)


def test_reservoir_rejects_nonpositive_bound():
    import pytest
    with pytest.raises(ValueError):
        Histogram(max_samples=0)
    with pytest.raises(ValueError):
        Histogram(max_samples=-1)


def test_metrics_propagates_reservoir_bound_to_new_hists():
    m = Metrics(hist_max_samples=16)
    for i in range(500):
        m.observe("lat", float(i))
    assert len(m.hists["lat"]._vals) == 16
    assert m.hists["lat"].count == 500
    # default Metrics stays exact/unbounded (sim + calibration paths)
    m2 = Metrics()
    for i in range(500):
        m2.observe("lat", float(i))
    assert len(m2.hists["lat"]._vals) == 500


def test_reservoir_hammer_exact_totals_under_threads():
    with locksan.sanitized():
        h = Histogram(max_samples=32)

        def work(i):
            for _ in range(N_OPS):
                h.observe(1.0)

        _run_threads(work)
    assert h.count == N_THREADS * N_OPS
    assert h.sum == float(N_THREADS * N_OPS)
    assert len(h._vals) == 32
