"""Metrics thread-safety: counters, histograms, and snapshots hammered
from many threads must lose nothing and never raise (gateway workers,
refill threads, and the janitor all write the same Metrics object)."""
import math
import threading

from repro.core.metrics import Histogram, Metrics
from tools.hydralint import locksan

N_THREADS = 8
N_OPS = 500


def _run_threads(fn):
    errors = []
    start = threading.Barrier(N_THREADS)

    def wrap(i):
        try:
            start.wait(timeout=10.0)   # all threads hammer at once
            fn(i)
        except Exception as e:      # pragma: no cover - failure reporting
            errors.append(e)

    threads = [threading.Thread(target=wrap, args=(i,))
               for i in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30.0)
    assert not errors, errors


def test_counter_hammer_loses_no_increments():
    # locksan: Metrics constructed INSIDE the patch so its lock is wrapped
    with locksan.sanitized():
        m = Metrics()

        def work(i):
            for _ in range(N_OPS):
                m.inc("shared")
                m.inc(f"per.{i % 3}", 2)

        _run_threads(work)
    assert m.counters["shared"] == N_THREADS * N_OPS
    total = sum(m.counters[f"per.{k}"] for k in range(3))
    assert total == N_THREADS * N_OPS * 2


def test_histogram_hammer_loses_no_observations():
    with locksan.sanitized():
        m = Metrics()

        def work(i):
            for j in range(N_OPS):
                # fresh names force the creation race the old defaultdict
                # pattern lost observations on
                m.observe(f"h{(i * N_OPS + j) % 7}", float(j))
                m.observe("shared_hist", 1.0)

        _run_threads(work)
    assert m.hists["shared_hist"].count == N_THREADS * N_OPS
    spread = sum(m.hists[f"h{k}"].count for k in range(7))
    assert spread == N_THREADS * N_OPS


def test_snapshot_under_concurrent_writes_is_consistent():
    with locksan.sanitized():
        m = Metrics()
        stop = threading.Event()
        snaps = []

        def writer(i):
            k = 0
            while not stop.is_set() and k < N_OPS * 4:
                m.inc("c")
                m.observe(f"dyn.{k % 11}", k)
                with m.timeit("timed"):
                    pass
                k += 1

        def reader():
            while not stop.is_set():
                snaps.append(m.snapshot())

        threads = [threading.Thread(target=writer, args=(i,))
                   for i in range(4)]
        r = threading.Thread(target=reader)
        for t in threads:
            t.start()
        r.start()
        for t in threads:
            t.join(timeout=30.0)
        stop.set()
        r.join(timeout=10.0)
    assert snaps, "reader never snapshotted"
    final = m.snapshot()
    assert final["counters"]["c"] == 4 * N_OPS * 4
    assert final["hists"]["timed"]["count"] == 4 * N_OPS * 4
    # every interim snapshot was internally sane (no partial histograms)
    for s in snaps:
        for h in s["hists"].values():
            assert h["count"] >= 0
            if h["count"] > 0:
                assert math.isfinite(h["mean"])


def test_empty_histogram_snapshot_is_nan_not_crash():
    h = Histogram()
    s = h.snapshot()
    assert s["count"] == 0
    assert math.isnan(s["mean"]) and math.isnan(s["p99"])
    assert math.isnan(h.percentile(50)) and math.isnan(h.mean)
