"""HydraPlatform behaviour: pre-warmed pool claim/return, colocation-aware
placement vs budget saturation, sandbox snapshot -> evict -> restore, and
the hydra-pool tracesim model beating plain hydra."""
import time

import jax.numpy as jnp
import pytest

from repro.core import (CallableSpec, FunctionNotRegisteredError, HydraError,
                        HydraPlatform)
from repro.core.tracesim import gen_trace, simulate

MB = 1 << 20


def spec(name="affine", arena_bytes=1 * MB):
    def fn(params, args):
        return {"y": args["x"] * params["w"] + 1.0}
    return CallableSpec(name=name, fn=fn,
                        example_args={"x": jnp.ones((64,), jnp.float32)},
                        params={"w": jnp.full((64,), 2.0)},
                        arena_bytes=arena_bytes)


ARGS = {"x": jnp.full((64,), 3.0)}


def wait_for(pred, timeout_s=5.0):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(0.01)
    return pred()


# ---------------------------------------------------------------------------
def test_pool_claim_refill_and_return(tmp_path):
    plat = HydraPlatform(pool_size=2, runtime_budget_bytes=64 * MB,
                         snapshot_dir=str(tmp_path))
    try:
        assert plat.pool_available == 2
        plat.register_function("t0/f", spec(), tenant="t0")
        # registration is lazy: nothing placed, pool untouched
        assert plat.stats()["functions_placed"] == 0
        out = plat.invoke("t0/f", ARGS)      # first invocation claims a
        assert float(out["y"][0]) == 7.0     # pre-warmed instance
        c = plat.metrics.counters
        assert c["pool.claim"] == 1 and c.get("pool.miss", 0) == 0
        # refill happens on a background thread, off the request path
        assert wait_for(lambda: plat.pool_available == 2)
        # evicting the only function drains the runtime back toward the
        # pool (full pool -> the spare shuts down; count stays at target)
        plat.evict("t0/f")
        assert plat.stats()["runtimes_active"] == 0
        assert plat.pool_available == 2
    finally:
        plat.shutdown()


def test_pool_return_without_refill(tmp_path):
    plat = HydraPlatform(pool_size=1, runtime_budget_bytes=64 * MB,
                         snapshot_dir=str(tmp_path), refill=False)
    try:
        plat.register_function("t0/f", spec(), tenant="t0")
        plat.invoke("t0/f", ARGS)
        assert plat.pool_available == 0      # claimed, no refill
        plat.evict("t0/f")
        assert plat.pool_available == 1      # emptied runtime returned
        assert plat.metrics.counters["pool.return"] == 1
    finally:
        plat.shutdown()


def test_refill_thread_bookkeeping_pruned_on_claim():
    """Regression: finished refill threads are dropped from the tracking
    list on EVERY claim, so repeated claim/evict cycles cannot accumulate
    dead thread objects without bound."""
    # a 4 MB runtime holds ONE ~3 MB function: every placement spills to
    # a fresh pool claim instead of colocating
    plat = HydraPlatform(pool_size=1, runtime_budget_bytes=4 * MB)
    try:
        for i in range(4):
            plat.register_function(f"t{i}/f",
                                   spec(arena_bytes=int(1.5 * MB)),
                                   tenant=f"t{i}")
            plat.invoke(f"t{i}/f", ARGS)     # placement claims a runtime
            assert wait_for(lambda: plat.pool_available == 1)
        # 4 claims spawned 4 refill threads; without pruning the backlog
        # would be 4 — with it, at most the latest (+ one straggler) remain
        assert plat.refill_backlog <= 2
    finally:
        plat.shutdown()


def test_colocation_packs_until_budget_saturates():
    # conservative placement estimate per function: ~3 MB (1.5 MB
    # registration reservation + one 1.5 MB arena). Colocated same-shape
    # functions share pooled arenas, so actual growth per extra function
    # is 1.5 MB: a 7 MB runtime admits two (3.0 + 1.5 used, 2.5 free) but
    # the third's 3 MB estimate no longer fits -> spill to a pool instance
    plat = HydraPlatform(pool_size=1, runtime_budget_bytes=7 * MB)
    try:
        for i in range(3):
            plat.register_function(f"t{i}/f", spec(arena_bytes=int(1.5 * MB)),
                                   tenant=f"t{i}")
            plat.invoke(f"t{i}/f", ARGS)
        c = plat.metrics.counters
        assert c["place.spill"] == 2         # first claim + saturation spill
        assert c["place.colocated"] == 1     # second fn packed with first
        assert plat.stats()["runtimes_active"] == 2
        place = plat.placement()
        # functions from different owners share runtime 0 (cross-tenant
        # colocation); the third lands alone on the spill runtime
        assert place["t0/f"] == place["t1/f"] != place["t2/f"]
    finally:
        plat.shutdown()


def test_snapshot_evict_restore_roundtrip_no_recompile(tmp_path):
    plat = HydraPlatform(pool_size=1, runtime_budget_bytes=64 * MB,
                         snapshot_dir=str(tmp_path))
    try:
        plat.register_function("t0/f", spec(), tenant="t0")
        before = plat.invoke("t0/f", ARGS)
        plat.snapshot("t0/f")
        plat.evict("t0/f")
        with pytest.raises(FunctionNotRegisteredError):
            plat.runtime_for("t0/f").invoke("t0/f", ARGS)
        compiles = plat.exe_cache.stats()["compiles"]
        plat.restore("t0/f")
        after = plat.invoke("t0/f", ARGS)
        assert float(after["y"][0]) == float(before["y"][0])
        # the restored function serves with ZERO new compilations: its
        # re-registration hit the shared ExecutableCache
        assert plat.exe_cache.stats()["compiles"] == compiles
        assert plat.metrics.counters["restores"] == 1
    finally:
        plat.shutdown()


def test_evict_requires_snapshot_dir():
    plat = HydraPlatform(pool_size=1, runtime_budget_bytes=64 * MB)
    try:
        plat.register_function("t0/f", spec(), tenant="t0")
        plat.invoke("t0/f", ARGS)
        with pytest.raises(HydraError):
            plat.snapshot("t0/f")
        # evict without snapshotting still works
        plat.evict("t0/f", snapshot=False)
        assert plat.stats()["functions_placed"] == 0
    finally:
        plat.shutdown()


def test_lm_snapshot_restore_serves_without_recompiling(tmp_path):
    """LM path: weights checkpoint through ft/checkpoint (bf16 leaves) and
    the restored function generates identical tokens with zero request-path
    compilations — decode AND lazily-compiled prefill both hit the shared
    ExecutableCache."""
    from repro.configs import get_config
    from repro.core import LMSpec
    from repro.models.programs import ModelProgram

    from conftest import bf16_params

    cfg = get_config("qwen2.5-3b").reduced()
    params = bf16_params(ModelProgram(cfg))
    plat = HydraPlatform(pool_size=1, runtime_budget_bytes=2 << 30,
                         snapshot_dir=str(tmp_path))
    try:
        plat.register_function("t0/lm", LMSpec(cfg=cfg, params=params,
                                               max_seq=64, slots=1),
                               tenant="t0")
        before = plat.generate("t0/lm", list(range(8)), max_new_tokens=5)
        plat.evict("t0/lm")                   # snapshots, then deregisters
        compiles = plat.exe_cache.stats()["compiles"]
        plat.restore("t0/lm")
        after = plat.generate("t0/lm", list(range(8)), max_new_tokens=5)
        assert after == before
        assert plat.exe_cache.stats()["compiles"] == compiles
    finally:
        plat.shutdown()


# ---------------------------------------------------------------------------
def test_persist_executables_defaults_on_with_snapshot_dir(tmp_path):
    """ROADMAP "snapshot warm-path": a snapshot-enabled platform persists
    compiled executables by default; no snapshot_dir (or explicit False)
    keeps the cache in-memory only."""
    plat = HydraPlatform(pool_size=1, runtime_budget_bytes=64 * MB,
                         snapshot_dir=str(tmp_path))
    assert plat.exe_cache.persist_dir is not None
    plat.shutdown()
    plat = HydraPlatform(pool_size=1, runtime_budget_bytes=64 * MB)
    assert plat.exe_cache.persist_dir is None
    plat.shutdown()
    plat = HydraPlatform(pool_size=1, runtime_budget_bytes=64 * MB,
                         snapshot_dir=str(tmp_path),
                         persist_executables=False)
    assert plat.exe_cache.persist_dir is None
    plat.shutdown()


def test_snapshot_restore_zero_recompile_across_platform_boots(tmp_path):
    """Regression for the cross-process warm path: a function exported
    from one platform restores into a FRESHLY CONSTRUCTED platform (same
    snapshot_dir) with zero new compilations — its executable
    deserializes from the persisted cache instead of recompiling."""
    plat = HydraPlatform(pool_size=1, runtime_budget_bytes=64 * MB,
                         snapshot_dir=str(tmp_path))
    try:
        plat.register_function("t0/f", spec(), tenant="t0")
        before = plat.invoke("t0/f", ARGS)
        exported = plat.export_function("t0/f")
    finally:
        plat.shutdown()
    # program + its arena-signature zeroer: both compiled at registration
    assert plat.exe_cache.stats()["compiles"] == 2

    fresh = HydraPlatform(pool_size=1, runtime_budget_bytes=64 * MB,
                          snapshot_dir=str(tmp_path))
    try:
        fresh.import_function(exported)
        fresh.restore("t0/f")
        after = fresh.invoke("t0/f", ARGS)
        assert float(after["y"][0]) == float(before["y"][0])
        stats = fresh.exe_cache.stats()
        assert stats["compiles"] == 0          # zero-recompile restore
        assert stats["disk_hits"] >= 1         # served from persisted exe
    finally:
        fresh.shutdown()


# ---------------------------------------------------------------------------
def test_tracesim_pool_beats_hydra_on_default_trace():
    """Acceptance: the platform layer strictly reduces cold starts AND p99
    latency vs per-tenant hydra on the default Azure-calibrated trace."""
    trace = gen_trace()
    hydra = simulate(trace, "hydra")
    pool = simulate(trace, "hydra-pool")
    assert pool.cold_runtime_starts < hydra.cold_runtime_starts
    assert pool.p(99) < hydra.p(99)
    # density: colocation across owners uses fewer runtimes and less memory
    assert pool.mean_runtimes() < hydra.mean_runtimes()
    assert pool.mean_mem() < hydra.mean_mem()


def test_tracesim_pool_summary_fields():
    trace = gen_trace(n_functions=20, n_tenants=4, duration_s=60.0,
                      mean_rps=4.0)
    s = simulate(trace, "hydra-pool").summary()
    assert s["pool_claims"] >= 1
    served = s["requests"] + s["dropped"]
    assert served == len(trace)
