"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.arena import ArenaPool
from repro.core.budget import MemoryBudget
from repro.core.errors import HydraOOMError
from repro.core.tracesim import SimParams, gen_trace, simulate
from repro.data.pipeline import DataConfig, make_batch
from repro.ft.compression import dequantize, quantize
from repro.launch.roofline import _shape_bytes, collective_bytes

SETTINGS = settings(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
@SETTINGS
@given(st.lists(st.integers(1, 1 << 20), min_size=1, max_size=40),
       st.integers(1 << 20, 1 << 24))
def test_budget_conservation(sizes, cap):
    """used == sum(reserved) - sum(released); never exceeds capacity."""
    b = MemoryBudget(cap)
    live = []
    for s in sizes:
        try:
            b.reserve(s)
            live.append(s)
        except HydraOOMError:
            assert b.used + s > cap
        if len(live) > 3:
            b.release(live.pop(0))
    assert b.used == sum(live)
    assert 0 <= b.used <= cap
    assert b.peak <= cap


@SETTINGS
@given(st.lists(st.sampled_from(["acq_a", "acq_b", "rel"]),
                min_size=1, max_size=60))
def test_arena_pool_conservation(ops):
    """live arenas == acquired - evicted; idle never exceeds releases."""
    pool = ArenaPool(ttl_s=1e9)
    factory = lambda: {"x": jnp.zeros((16,), jnp.float32)}
    held = []
    for op in ops:
        if op == "rel" and held:
            pool.release(held.pop())
        elif op.startswith("acq"):
            held.append(pool.acquire((op[-1],), factory))
    c = pool.metrics.counters
    assert pool.live == c["arena.cold"]
    assert pool.idle_count == pool.live - len(held)
    assert c["arena.warm"] + c["arena.cold"] == len(
        [o for o in ops if o.startswith("acq")])


# ---------------------------------------------------------------------------
@SETTINGS
@given(st.integers(0, 10_000), st.integers(1, 8), st.integers(8, 64))
def test_packing_label_shift_invariant(step, batch, seq):
    cfg = DataConfig(vocab_size=97, seq_len=seq, batch_size=batch, seed=1)
    b = make_batch(cfg, step)
    assert b["tokens"].shape == (batch, seq)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert (b["tokens"] >= 0).all() and (b["tokens"] < 97).all()


# ---------------------------------------------------------------------------
@SETTINGS
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1,
                max_size=200))
def test_quantization_error_bound(xs):
    x = jnp.asarray(xs, jnp.float32)
    q, s = quantize(x)
    err = float(jnp.max(jnp.abs(dequantize(q, s) - x)))
    assert err <= float(s) * 0.5 + 1e-5      # round-to-nearest bound


# ---------------------------------------------------------------------------
@SETTINGS
@given(st.integers(1, 64), st.integers(1, 64), st.integers(1, 16))
def test_online_softmax_merge_associative(n1, n2, d):
    """Two-block online-softmax merge == monolithic softmax (the invariant
    the flash kernels rely on)."""
    rng = np.random.default_rng(n1 * 1000 + n2 * 16 + d)
    s1 = jnp.asarray(rng.normal(size=(n1,)) * 5)
    s2 = jnp.asarray(rng.normal(size=(n2,)) * 5)
    v1 = jnp.asarray(rng.normal(size=(n1, d)))
    v2 = jnp.asarray(rng.normal(size=(n2, d)))

    def block(s, v):
        m = jnp.max(s)
        p = jnp.exp(s - m)
        return m, jnp.sum(p), p @ v

    m1, l1, a1 = block(s1, v1)
    m2, l2, a2 = block(s2, v2)
    m = jnp.maximum(m1, m2)
    l = l1 * jnp.exp(m1 - m) + l2 * jnp.exp(m2 - m)
    acc = a1 * jnp.exp(m1 - m) + a2 * jnp.exp(m2 - m)
    got = acc / l

    s = jnp.concatenate([s1, s2])
    v = jnp.concatenate([v1, v2])
    want = jax.nn.softmax(s) @ v
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
def test_hlo_shape_bytes_parser():
    assert _shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(f32[8], bf16[8])") == 32 + 16
    assert _shape_bytes("pred[]") == 1


def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups=[16,2]<=[32]
  %ar = f32[512]{0} all-reduce(%y), replica_groups={{0,1,2,3}}
  %rs = f32[64]{0} reduce-scatter(%z), replica_groups=[4,8]<=[32]
"""
    out = collective_bytes(hlo)
    assert out["count"] == 3
    assert out["all-gather"] == 16 * 1024 * 2 * (1 / 2)
    assert out["all-reduce"] == 2 * 512 * 4 * (3 / 4)
    assert out["reduce-scatter"] == 64 * 4 * 7


# ---------------------------------------------------------------------------
@SETTINGS
@given(st.integers(0, 3))
def test_tracesim_invariants(seed):
    trace = gen_trace(n_functions=20, n_tenants=4, duration_s=60,
                      mean_rps=4.0, seed=seed)
    assert all(t.duration_s >= 0.1 for t in trace)
    from repro.core.tracesim import MODELS
    for model in MODELS:
        res = simulate(trace, model, SimParams())
        served = len(res.latencies) + res.dropped
        assert served == len(trace)
        # latency >= pure duration for every request
        assert all(o >= -1e-9 for o in res.overheads)
        # memory never exceeds the machine cap
        assert all(m <= SimParams().machine_cap
                   for _, m in res.mem_samples)


def test_hydra_dominates_on_sparse_multi_tenant_trace():
    """The paper's headline: hydra uses less memory than photons than
    openwhisk under sparse multi-function traffic."""
    trace = gen_trace(n_functions=100, n_tenants=10, duration_s=300,
                      mean_rps=8.0, seed=1)
    p = SimParams(keepalive_s=600.0)
    mem = {m: simulate(trace, m, p).mean_mem()
           for m in ("openwhisk", "photons", "hydra")}
    assert mem["hydra"] < mem["photons"] < mem["openwhisk"]


# ---------------------------------------------------------------------------
# Streaming Azure loader invariants
# ---------------------------------------------------------------------------
@st.composite
def azure_csv(draw, max_rows=6, max_minutes=8):
    """A small synthetic Azure-format invocation grid: per-row per-minute
    counts, written through a temp CSV by the test body."""
    n_rows = draw(st.integers(1, max_rows))
    n_minutes = draw(st.integers(1, max_minutes))
    grid = draw(st.lists(
        st.lists(st.integers(0, 9), min_size=n_minutes,
                 max_size=n_minutes),
        min_size=n_rows, max_size=n_rows))
    return grid


def _write_azure_csv(grid, path):
    n_minutes = len(grid[0])
    cols = ",".join(str(m) for m in range(1, n_minutes + 1))
    lines = [f"HashOwner,HashApp,HashFunction,{cols}"]
    for r, counts in enumerate(grid):
        row = ",".join(str(c) for c in counts)
        lines.append(f"o{r % 3},a{r},f{r},{row}")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


@SETTINGS
@given(azure_csv(), st.integers(1, 30), st.integers(0, 99))
def test_stream_chunk_invariance_and_roundtrip(grid, chunk_rows, seed):
    """Chunked ingest is invisible: any chunk_rows yields the same
    expansion, same seed => same stream, and the expanded stream
    round-trips the written per-minute counts exactly."""
    import tempfile
    from collections import Counter

    from repro.core.streaming import StreamingTrace

    total = sum(sum(r) for r in grid)
    with tempfile.TemporaryDirectory() as td:
        path = f"{td}/t.csv"
        _write_azure_csv(grid, path)
        if total == 0:
            with pytest.raises(ValueError, match="zero invocations"):
                StreamingTrace(path, seed=seed)
            return
        a = list(StreamingTrace(path, seed=seed, chunk_rows=chunk_rows))
        b = list(StreamingTrace(path, seed=seed))
        assert a == b                      # chunk-size invariance
        again = list(StreamingTrace(path, seed=seed,
                                    chunk_rows=chunk_rows))
        assert a == again                  # seed determinism
        # round-trip: per-(row, minute) counts match what was written;
        # fid r is the r-th data row in file order
        got = Counter((inv.fid, int(inv.t // 60)) for inv in a)
        want = Counter()
        for r, counts in enumerate(grid):
            for m, c in enumerate(counts):
                if c:
                    want[(r, m)] = c
        assert got == want
        assert len(a) == total


@SETTINGS
@given(st.sampled_from(["abc", "-1", "2.5", "nan", "1e999"]))
def test_stream_malformed_count_cells_raise(bad):
    import tempfile

    from repro.core.streaming import StreamingTrace

    with tempfile.TemporaryDirectory() as td:
        path = f"{td}/bad.csv"
        with open(path, "w") as f:
            f.write("HashOwner,HashApp,HashFunction,1,2\n"
                    f"o1,a1,f1,1,{bad}\n")
        with pytest.raises(ValueError, match="invocation count"):
            StreamingTrace(path)
