"""HydraCluster behaviour: cross-node colocation + spill, snapshot
migration with explicit transfer cost, rebalancing, EWMA-adaptive pool
sizing, and the hydra-cluster tracesim model beating a statically
partitioned hydra-pool fleet."""
import jax.numpy as jnp
import pytest

from repro.core import (AdaptivePoolPolicy, ArrivalRateEstimator,
                        CallableSpec, ClusterParams, HydraCluster,
                        HydraOOMError, PlatformParams)
from repro.core.platform import estimate_bytes
from repro.core.tracesim import (SimParams, gen_trace, simulate,
                                 simulate_partitioned)
from tools.hydralint import leaksan, locksan

MB = 1 << 20
GB = 1 << 30


def spec(name="affine", arena_bytes=1 * MB):
    def fn(params, args):
        return {"y": args["x"] * params["w"] + 1.0}
    return CallableSpec(name=name, fn=fn,
                        example_args={"x": jnp.ones((64,), jnp.float32)},
                        params={"w": jnp.full((64,), 2.0)},
                        arena_bytes=arena_bytes)


ARGS = {"x": jnp.full((64,), 3.0)}


def make_cluster(tmp_path=None, **kw):
    defaults = dict(
        n_nodes=2,
        node_memory_bytes=64 * MB,
        snapshot_dir=str(tmp_path) if tmp_path is not None else None,
        platform=PlatformParams(pool_size=1,
                                runtime_budget_bytes=32 * MB))
    defaults.update(kw)
    return HydraCluster(ClusterParams(**defaults))


# ---------------------------------------------------------------------------
# Placement: colocation + spill
# ---------------------------------------------------------------------------
def test_colocation_then_spill_across_nodes():
    need = estimate_bytes(spec())
    # each node fits exactly two functions' placement estimates
    cl = make_cluster(node_memory_bytes=2 * need + need // 2)
    try:
        # same tenant colocates on one node while it fits
        cl.register_function("t0/a", spec("a"), tenant="t0")
        cl.register_function("t0/b", spec("b"), tenant="t0")
        place = cl.placement()
        assert place["t0/a"] == place["t0/b"]
        # the tenant's node is full: the third function spills to the other
        cl.register_function("t0/c", spec("c"), tenant="t0")
        assert cl.placement()["t0/c"] != place["t0/a"]
        assert cl.metrics.counters["place.colocated"] == 1
        assert cl.metrics.counters["place.spill"] == 1
        # a different tenant lands on the least-committed node
        cl.register_function("t1/a", spec("a"), tenant="t1")
        assert cl.placement()["t1/a"] == cl.placement()["t0/c"]
        # fleet full: admission fails rather than OOMing a node
        with pytest.raises(HydraOOMError):
            cl.register_function("t2/a", spec("a"), tenant="t2")
    finally:
        cl.shutdown()


def test_invoke_routes_to_owning_node():
    cl = make_cluster()
    try:
        cl.register_function("t0/f", spec(), tenant="t0")
        cl.register_function("t1/f", spec(), tenant="t1")
        out0 = cl.invoke("t0/f", ARGS)
        out1 = cl.invoke("t1/f", ARGS)
        assert float(out0["y"][0]) == float(out1["y"][0]) == 7.0
        # different tenants started on different (least-committed) nodes
        assert cl.placement()["t0/f"] != cl.placement()["t1/f"]
    finally:
        cl.shutdown()


# ---------------------------------------------------------------------------
# Migration + rebalance
# ---------------------------------------------------------------------------
def test_migrate_roundtrip_zero_recompile(tmp_path):
    cl = make_cluster(tmp_path)
    try:
        cl.register_function("t0/f", spec(), tenant="t0")
        before = cl.invoke("t0/f", ARGS)
        src = cl.placement()["t0/f"]
        dst = 1 - src
        compiles = cl.exe_cache.stats()["compiles"]
        nbytes = cl.migrate("t0/f", dst)
        assert nbytes > 0
        assert cl.placement()["t0/f"] == dst
        after = cl.invoke("t0/f", ARGS)
        assert float(after["y"][0]) == float(before["y"][0])
        # fleet-shared ExecutableCache: the migrated function re-registers
        # on its new node with ZERO new compilations
        assert cl.exe_cache.stats()["compiles"] == compiles
        c = cl.metrics.counters
        assert c["migrations"] == 1
        assert c["transfer_bytes"] == nbytes
        # the explicit cross-node transfer cost was charged
        assert cl.metrics.hists["transfer_s"].count == 1
        assert cl.metrics.hists["transfer_s"].mean > 0
    finally:
        cl.shutdown()


def test_failed_migrate_does_not_orphan_function():
    cl = make_cluster()                   # no snapshot_dir: migration fails
    try:
        cl.register_function("t0/f", spec(), tenant="t0")
        cl.invoke("t0/f", ARGS)
        src = cl.placement()["t0/f"]
        with pytest.raises(Exception):
            cl.migrate("t0/f", 1 - src)
        # the function survives the failed migration on its source node
        assert cl.placement()["t0/f"] == src
        out = cl.invoke("t0/f", ARGS)
        assert float(out["y"][0]) == 7.0
    finally:
        cl.shutdown()


def test_rebalance_drains_overloaded_node(tmp_path):
    need = estimate_bytes(spec())
    # locksan: rebalance nests the cluster lock over per-node platform,
    # budget, and metrics locks — the order graph must stay acyclic.
    # leaksan: snapshot-evict-restore moves must not strand runtime claims.
    with locksan.sanitized(), leaksan.sanitized():
        cl = make_cluster(tmp_path, node_memory_bytes=8 * need)
        try:
            # all one tenant: colocation piles everything onto one node
            for i in range(4):
                cl.register_function(f"t0/f{i}", spec(f"f{i}"), tenant="t0")
            nodes = set(cl.placement().values())
            assert len(nodes) == 1
            moves = cl.rebalance()
            assert len(moves) == 2            # 4|0 -> 2|2
            committed = [n.committed for n in cl.nodes]
            assert max(committed) - min(committed) <= need
            # a rebalanced (evicted) function restores lazily on next invoke
            moved_fid = moves[0][0]
            out = cl.invoke(moved_fid, ARGS)
            assert float(out["y"][0]) == 7.0
        finally:
            cl.shutdown()


# ---------------------------------------------------------------------------
# Adaptive pool sizing
# ---------------------------------------------------------------------------
def test_arrival_rate_estimator_tracks_burst_and_idle():
    est = ArrivalRateEstimator(alpha=0.5)
    assert est.rate() == 0.0
    for i in range(20):                    # 100 arrivals/s burst
        est.observe(i * 0.01)
    burst_rate = est.rate()
    assert burst_rate > 50
    # idle: the estimate decays with the time since the last arrival
    assert est.rate(now=0.2 + 10.0) < 1.0


def test_adaptive_policy_grows_shrinks_and_respects_memory():
    pol = AdaptivePoolPolicy(pool_min=1, pool_max=8, cover_s=1.0,
                             runtime_bytes=2 * GB)
    assert pol.target(0.0) == 1            # idle floor
    assert pol.target(3.5) == 4            # ceil(rate * cover)
    assert pol.target(100.0) == 8          # burst ceiling
    # the memory budget caps the target below pool_min if it must
    assert pol.target(100.0, free_bytes=5 * GB) == 2
    assert pol.target(100.0, free_bytes=0) == 0


def test_cluster_adaptive_pool_grows_on_burst_shrinks_idle():
    cl = make_cluster(
        n_nodes=1, node_memory_bytes=256 * MB,
        pool_min=1, pool_max=3, resize_every=1, ewma_alpha=0.5,
        pool_cover_s=1.0,
        platform=PlatformParams(pool_size=1, runtime_budget_bytes=8 * MB,
                                refill=False))
    try:
        cl.register_function("t0/f", spec(), tenant="t0")
        # burst: 100 arrivals/s -> EWMA rate >> pool_max -> pool grows
        t = 0.0
        for _ in range(8):
            cl.invoke("t0/f", ARGS, now=t)
            t += 0.01
        node = cl.nodes[0]
        assert node.platform.params.pool_size == 3
        # the pooled commitment never exceeds the node's free memory
        free = cl.params.node_memory_bytes - node.committed
        assert (node.platform.params.pool_size
                * cl.params.platform.runtime_budget_bytes) <= free
        # idle: next arrival is 100 s later -> rate collapses -> floor
        cl.invoke("t0/f", ARGS, now=t + 100.0)
        assert node.platform.params.pool_size == cl.params.pool_min
    finally:
        cl.shutdown()


def test_cluster_adaptive_pool_capped_by_node_memory():
    need = estimate_bytes(spec())
    # tiny node: after committing one function there is room for only one
    # 8 MB pooled runtime no matter how hot the arrival rate gets
    cl = make_cluster(
        n_nodes=1, node_memory_bytes=need + 12 * MB,
        pool_min=1, pool_max=8, resize_every=1, ewma_alpha=0.5,
        pool_cover_s=10.0,
        platform=PlatformParams(pool_size=1, runtime_budget_bytes=8 * MB,
                                refill=False))
    try:
        cl.register_function("t0/f", spec(), tenant="t0")
        t = 0.0
        for _ in range(8):
            cl.invoke("t0/f", ARGS, now=t)
            t += 0.001
        node = cl.nodes[0]
        assert node.platform.params.pool_size == 1   # memory-capped, not 8
    finally:
        cl.shutdown()


# ---------------------------------------------------------------------------
# Tracesim: the hydra-cluster model
# ---------------------------------------------------------------------------
def fleet_params(**kw):
    """Fleet-pressure regime: trace and budgets scaled together (see
    bench_trace) so pool churn matches the paper's ratios."""
    base = dict(n_nodes=4, runtime_cap=192 * MB, machine_cap=3 * GB)
    base.update(kw)
    return SimParams(**base)


def test_tracesim_cluster_beats_static_partition():
    """Acceptance: at 4 nodes on the default Azure-sparse trace, the
    cluster layer strictly reduces total cold starts AND fleet p99 vs 4
    independent hydra-pool nodes with statically partitioned traffic and
    the same aggregate memory."""
    trace = gen_trace()
    p = fleet_params()
    cluster = simulate(trace, "hydra-cluster", p)
    static = simulate_partitioned(trace, 4, p)
    assert cluster.cold_runtime_starts < static.cold_runtime_starts
    assert cluster.p(99) < static.p(99)
    # cross-machine placement also lifts density at equal fleet memory
    assert cluster.ops_per_gb_s() > static.ops_per_gb_s()
    assert cluster.transfers > 0          # snapshots moved between nodes


def test_tracesim_adaptive_pool_peak_within_fixed_baseline():
    """Acceptance: adaptive sizing never holds more pooled memory at peak
    than the fixed-pool_size policy, and holds strictly less on average."""
    trace = gen_trace()
    adaptive = simulate(trace, "hydra-cluster", fleet_params())
    fixed = simulate(trace, "hydra-cluster",
                     fleet_params(adaptive_pool=False))
    assert adaptive.peak_pool_mem <= fixed.peak_pool_mem
    assert adaptive.mean_pool_mem() < fixed.mean_pool_mem()


def test_tracesim_cluster_conservation_and_summary():
    trace = gen_trace(n_functions=20, n_tenants=4, duration_s=60.0,
                      mean_rps=4.0)
    s = simulate(trace, "hydra-cluster", SimParams(n_nodes=2)).summary()
    assert s["requests"] + s["dropped"] == len(trace)
    assert s["n_nodes"] == 2
    assert s["peak_pool_mem_mb"] >= 0
    # node_cap defaults to an even split: fleet total stays machine_cap
    assert s["peak_mem_mb"] <= SimParams().machine_cap / MB


def test_tracesim_node_cap_defaults_to_even_split():
    trace = gen_trace(n_functions=20, n_tenants=4, duration_s=60.0,
                      mean_rps=4.0)
    implicit = simulate(trace, "hydra-cluster",
                        SimParams(n_nodes=4, machine_cap=2 * GB))
    explicit = simulate(trace, "hydra-cluster",
                        SimParams(n_nodes=4, machine_cap=2 * GB,
                                  node_cap=2 * GB // 4))
    assert implicit.summary() == explicit.summary()
