"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_scan

KEY = jax.random.PRNGKey(7)


def tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,Hq,Hkv,hd,causal,window", [
    (2, 128, 4, 2, 64, True, None),
    (1, 256, 4, 1, 32, True, 64),
    (2, 100, 8, 8, 16, True, None),      # ragged S (padding path)
    (1, 64, 4, 4, 128, False, None),     # non-causal
    (1, 64, 16, 2, 8, True, 16),         # deep GQA + window
])
def test_flash_attention(B, S, Hq, Hkv, hd, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True, block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,Hq,Hkv,hd,window", [
    (2, 256, 4, 2, 64, None),
    (3, 100, 8, 1, 32, None),            # ragged S
    (2, 512, 4, 4, 128, 128),            # MHA + window
    (1, 64, 16, 2, 16, None),
])
def test_decode_attention(B, S, Hq, Hkv, hd, window, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Hq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32).astype(dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = decode_attention(q, k, v, lengths, window=window, interpret=True,
                           block_k=64)
    want = ref.decode_attention_ref(q, k, v, lengths, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,P,N,chunk,init", [
    (2, 64, 4, 16, 16, 16, False),
    (1, 100, 2, 32, 64, 32, True),       # ragged + init state
    (2, 33, 4, 64, 32, 8, False),
])
def test_ssd_scan(B, S, H, P, N, chunk, init, dtype):
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, S, H, P)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (B, S, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, S, N)) * 0.5).astype(dtype)
    s0 = jax.random.normal(ks[5], (B, H, P, N)) if init else None
    y, sf = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, init_state=s0,
                     return_state=True, interpret=True)
    yr, sr = ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk, init_state=s0,
                              return_state=True)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               **tol(dtype))
    np.testing.assert_allclose(sf, sr, atol=1e-3, rtol=1e-3)


def test_ssd_matches_sequential_recurrence():
    """Chunked SSD == the literal state-space recurrence definition."""
    ks = jax.random.split(KEY, 6)
    B, S, H, P, N = 2, 48, 3, 8, 16
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    st = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        decay = jnp.exp(dt[:, t] * A[None, :])
        st = st * decay[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dt[:, t], x[:, t], Bm[:, t])
        ys.append(jnp.einsum("bhpn,bn->bhp", st, Cm[:, t]))
    want = jnp.stack(ys, 1)
    got, sf = ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk=16, return_state=True)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(sf, st, atol=1e-4, rtol=1e-4)


def test_ssd_decode_matches_scan_tail():
    """One ssd_decode step == extending the scan by one token."""
    ks = jax.random.split(KEY, 6)
    B, S, H, P, N = 2, 17, 2, 8, 8
    x = jax.random.normal(ks[0], (B, S + 1, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S + 1, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S + 1, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S + 1, N)) * 0.5
    y_full = ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk=8)
    _, state = ref.ssd_scan_ref(x[:, :S], dt[:, :S], A, Bm[:, :S],
                                Cm[:, :S], chunk=8, return_state=True)
    y1, _ = ref.ssd_decode_ref(x[:, S], dt[:, S], A, Bm[:, S], Cm[:, S],
                               state)
    np.testing.assert_allclose(y1, y_full[:, S], atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("shape", [(8, 64), (2, 17, 128), (100, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x = jax.random.normal(KEY, shape, jnp.float32).astype(dtype)
    w = jax.random.normal(KEY, shape[-1:]) * 0.1
    out = rmsnorm(x, w, interpret=True, block_rows=16)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-2, rtol=2e-2)
