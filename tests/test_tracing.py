"""Request tracing (repro.core.tracing): the phase-conservation
invariant on a live replay, thread-safety of the span pipeline,
deterministic head sampling, Chrome trace-event schema validation, the
anomaly flight recorder's bounds, and the near-zero disabled path."""
import json
import threading
import time

from repro.core.platform import HydraPlatform, PlatformParams
from repro.core.tracing import (ARENA_KINDS, NULL_TRACE, PHASES,
                                SUMMARY_KEYS, FlightRecorder, PhaseBreakdown,
                                RequestTrace, Tracer, chrome_trace,
                                trace_now, validate_chrome)
from repro.core.traces import Invocation, Trace
from repro.gateway import ReplayConfig, replay_trace

MB = 1 << 20


def make_trace(n=24, gap_s=0.5, duration_s=0.2, n_fns=4, n_tenants=2,
               mem_mb=80):
    invs = tuple(
        Invocation(t=i * gap_s, fid=i % n_fns, tenant=(i % n_fns) % n_tenants,
                   duration_s=duration_s, mem_bytes=mem_mb * MB)
        for i in range(n))
    return Trace(invocations=invs, source="synthetic")


def traced_replay(trace, tracer, compress=30.0, **cfg_kw):
    plat = HydraPlatform(PlatformParams(
        pool_size=1, runtime_budget_bytes=64 * MB,
        arena_ttl_s=10.0 / compress, n_workers=2))
    try:
        return replay_trace(trace, plat,
                            ReplayConfig(compress=compress, n_workers=4,
                                         **cfg_kw),
                            tracer=tracer)
    finally:
        plat.shutdown()


# ---------------------------------------------------------------------------
# conservation on a live replay
# ---------------------------------------------------------------------------
def test_live_replay_phases_conserve_and_export_validates():
    tracer = Tracer(1.0, seed=0)
    res, extras = traced_replay(make_trace(n=16, gap_s=0.4), tracer)
    traces = tracer.traces()
    assert len(traces) == tracer.summary()["finished"] >= 1
    for t in traces:
        # per-request conservation: spans + unattributed == total + overlap
        phase_sum = sum(t["phases"].values())
        assert abs(phase_sum - t["total_s"] - t["overlap_s"]) < 1e-6
        # every span inside the request window, every name in the registry
        t_end = t["t0"] + t["total_s"] + 1e-4
        for sp in t["spans"]:
            assert sp["name"] in PHASES
            assert t["t0"] - 1e-4 <= sp["t0"] <= sp["t1"] <= t_end
    # the exported Chrome doc passes its own checker (schema + epsilon)
    doc = chrome_trace(traces, meta={"test": True})
    assert validate_chrome(doc) == []
    assert doc["otherData"]["schema"] == "hydra-trace/v1"
    # served requests all carry the core invoke phases
    ok = [t for t in traces if t["status"] == "ok"]
    assert ok
    for t in ok:
        names = {sp["name"] for sp in t["spans"]}
        assert {"admission", "queue_wait", "arena_acquire",
                "compute", "body"} <= names
    # the replay extras surface the aggregate with the full vocabulary
    assert set(extras["tracing"]["phases"]) == set(SUMMARY_KEYS)


def test_phase_breakdown_counts_overlap_once():
    spans = [("compute", 1.0, 2.0, None), ("dispatch", 1.5, 2.5, None)]
    bd = PhaseBreakdown.compute(spans, total_s=3.0)
    assert abs(bd.overlap_s - 0.5) < 1e-12          # 0.5s double-counted
    assert abs(bd.phases["unattributed"] - 1.5) < 1e-12   # 3.0 - covered 1.5
    assert bd.conservation_error_s() < 1e-12


# ---------------------------------------------------------------------------
# thread safety: concurrent requests never interleave spans
# ---------------------------------------------------------------------------
def test_multithread_hammer_no_cross_request_interleave():
    tracer = Tracer(1.0, seed=0, max_traces=10_000)
    n_threads, n_reqs = 8, 50
    start = threading.Barrier(n_threads)
    errors = []

    def worker(wid):
        try:
            start.wait(timeout=10.0)
            for i in range(n_reqs):
                ctx = tracer.start_request(f"fn-{wid}", tenant=f"t{wid}")
                with ctx.span("compute") as sp:
                    sp.set(worker=wid, i=i)
                ctx.add_span("queue_wait", ctx.t0, trace_now())
                ctx.finish("ok")
        except Exception as e:      # pragma: no cover - failure reporting
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30.0)
    assert not errors, errors

    traces = tracer.traces()
    assert len(traces) == n_threads * n_reqs
    assert len({t["trace_id"] for t in traces}) == len(traces)  # unique ids
    for t in traces:
        # exactly this request's two spans — nothing leaked across
        assert [sp["name"] for sp in t["spans"]] == ["compute", "queue_wait"]
        wid = int(t["fid"].split("-")[1])
        assert t["spans"][0]["attrs"]["worker"] == wid
        assert abs(sum(t["phases"].values())
                   - t["total_s"] - t["overlap_s"]) < 1e-6
    s = tracer.summary()
    assert s["requests"] == s["sampled"] == s["finished"] == len(traces)


# ---------------------------------------------------------------------------
# deterministic head sampling
# ---------------------------------------------------------------------------
def test_sampling_is_deterministic_under_fixed_seed():
    a = Tracer(0.3, seed=42)
    b = Tracer(0.3, seed=42)
    decisions = [a.would_sample(i) for i in range(2000)]
    assert decisions == [b.would_sample(i) for i in range(2000)]
    # the live path takes exactly the precomputed decisions, in order
    live = [a.start_request("f").sampled for _ in range(2000)]
    assert live == decisions
    # rate is honoured statistically, and a different seed re-deals
    frac = sum(decisions) / len(decisions)
    assert 0.2 < frac < 0.4
    assert decisions != [Tracer(0.3, seed=43).would_sample(i)
                         for i in range(2000)]


def test_sampling_edge_rates():
    off = Tracer(0.0)
    assert off.start_request("f") is NULL_TRACE
    assert not off.would_sample(0)
    assert off.summary()["requests"] == 0     # rate 0 skips even counting
    on = Tracer(1.0)
    assert all(on.would_sample(i) for i in range(100))


def test_null_trace_is_inert():
    ctx = NULL_TRACE
    assert not ctx.sampled
    with ctx.span("compute") as sp:
        sp.set(kind="reuse")                  # all no-ops, no state
    ctx.add_span("queue_wait", 0.0, 1.0)
    ctx.finish("ok")
    # hydralint: disable=HL008 — deliberately bare: asserting the no-op
    # singleton, not timing a phase
    assert ctx.span("compute") is ctx.span("body")


# ---------------------------------------------------------------------------
# Chrome export schema validation
# ---------------------------------------------------------------------------
def _one_trace_doc():
    tracer = Tracer(1.0)
    ctx = tracer.start_request("f1", "t0")
    with ctx.span("compute"):
        time.sleep(0.002)
    ctx.finish("ok")
    return chrome_trace(tracer.traces())


def test_validate_chrome_accepts_good_and_rejects_corrupt():
    doc = _one_trace_doc()
    assert validate_chrome(doc) == []
    assert json.loads(json.dumps(doc)) == doc      # JSON-serializable

    assert validate_chrome({"foo": 1})             # traceEvents missing
    assert validate_chrome({"traceEvents": []})    # no request tracks

    bad_name = json.loads(json.dumps(doc))
    bad_name["traceEvents"][1]["name"] = "made_up_phase"
    assert any("unknown span name" in e for e in validate_chrome(bad_name))

    bad_sum = json.loads(json.dumps(doc))
    for ev in bad_sum["traceEvents"]:
        if ev["name"] == "compute":
            ev["dur"] += 50_000.0                  # +50ms breaks conservation
    assert any("conservation" in e for e in validate_chrome(bad_sum))

    two_reqs = json.loads(json.dumps(doc))
    two_reqs["traceEvents"].append(dict(two_reqs["traceEvents"][0]))
    assert any("request events" in e for e in validate_chrome(two_reqs))

    bad_ph = json.loads(json.dumps(doc))
    bad_ph["traceEvents"][0]["ph"] = "B"
    assert any("ph=" in e for e in validate_chrome(bad_ph))


def test_chrome_cli_checker(tmp_path, capsys):
    from repro.core.tracing import main
    good = tmp_path / "spans.json"
    good.write_text(json.dumps(_one_trace_doc()))
    assert main(["--check", str(good)]) == 0
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert main(["--check", str(bad)]) == 2
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"traceEvents": []}))
    assert main(["--check", str(empty)]) == 1


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------
def test_flight_recorder_ring_is_bounded_and_dumps_jsonl(tmp_path):
    fl = FlightRecorder(str(tmp_path), ring=8, max_dumps=2)
    tracer = Tracer(1.0, flight=fl)
    tracer.set_metrics_provider(lambda: {"runtimes": 3})
    for i in range(50):
        ctx = tracer.start_request(f"fn{i}")
        with ctx.span("compute"):
            pass
        ctx.finish("ok")
    assert len(fl) == 8                         # ring kept only the last 8

    trigger = tracer.start_request("victim")
    trigger.finish("slo_timeout")
    path = tracer.anomaly("slo_violation", fid="victim", ctx=trigger)
    assert path is not None
    lines = [json.loads(l) for l in open(path)]
    header, traces = lines[0], lines[1:]
    assert header["schema"] == "hydra-flight/v1"
    assert header["anomaly"] == "slo_violation"
    assert header["fid"] == "victim"
    assert header["metrics"] == {"runtimes": 3}
    assert header["trigger"]["fid"] == "victim"
    assert header["n_traces"] == len(traces) == 8
    assert traces[-1]["fid"] == "victim"        # newest ring entry

    # dump cap: the 3rd anomaly is counted but not written
    assert tracer.anomaly("oom_give_up") is not None
    assert tracer.anomaly("oom_give_up") is None
    assert fl.dumps == 2 and fl.dropped == 1
    s = tracer.summary()
    assert s["anomalies"] == {"slo_violation": 1, "oom_give_up": 2}
    assert s["flight"] == {"recorded": 8, "dumps": 2, "dump_cap_dropped": 1}


def test_gateway_slo_drop_fires_flight_dump(tmp_path):
    fl = FlightRecorder(str(tmp_path / "flight"))
    tracer = Tracer(1.0, seed=0, flight=fl)
    # 2x-compressed replay with an SLO far tighter than the service time:
    # most requests drop at pickup, each firing an slo_violation anomaly
    traced_replay(make_trace(n=12, gap_s=0.05, duration_s=1.0),
                  tracer, compress=60.0, slo_timeout_s=0.5)
    s = tracer.summary()
    assert s["anomalies"].get("slo_violation", 0) >= 1
    dumps = sorted((tmp_path / "flight").glob("flight-*.jsonl"))
    assert dumps
    header = json.loads(dumps[0].read_text().splitlines()[0])
    assert header["schema"] == "hydra-flight/v1"
    assert "metrics" in header                   # fleet snapshot embedded


# ---------------------------------------------------------------------------
# aggregation + attribution
# ---------------------------------------------------------------------------
def test_summary_vocabulary_is_fixed_and_arena_kinds_split():
    tracer = Tracer(1.0)
    for kind, secs in (("reuse", 0.001), ("zeroed", 0.002), ("cold", 0.01)):
        ctx = tracer.start_request("f")
        t0 = trace_now()
        ctx.add_span("arena_acquire", t0, t0 + secs, kind=kind)
        ctx.finish("ok")
    s = tracer.summary()
    assert set(s["phases"]) == set(SUMMARY_KEYS)
    for kind in ARENA_KINDS:
        assert s["phases"][f"arena_acquire.{kind}"]["count"] == 1
    assert s["phases"]["arena_acquire"]["count"] == 3
    assert s["phases"]["compute"]["count"] == 0          # fixed keys, None
    assert s["phases"]["compute"]["p99_ms"] is None


def test_attribution_names_dominant_phase():
    tracer = Tracer(1.0)
    for i in range(20):
        ctx = tracer.start_request(f"f{i}")
        t0 = trace_now()
        ctx.add_span("queue_wait", t0, t0 + 0.001)
        ctx.add_span("body", t0 + 0.001, t0 + 0.099)   # body must not win
        if i == 19:
            # one genuinely slow cold request: the p99 tail is selected
            # on wall total_s (t0 -> finish), so the dominating phase
            # must hold the request open for real time
            with ctx.span("restore"):
                time.sleep(0.05)
        ctx.finish("ok")
    att = tracer.attribution()
    assert att["requests"] == 20
    assert att["p99"]["dominant"] == "restore"
    assert att["cold"]["n"] == 1
    assert att["cold"]["dominant"] == "restore"


def test_export_window_is_bounded():
    tracer = Tracer(1.0, max_traces=16)
    for i in range(40):
        ctx = tracer.start_request(f"f{i}")
        ctx.finish("ok")
    assert len(tracer.traces()) == 16
    s = tracer.summary()
    assert s["export_window_dropped"] == 24
    assert s["finished"] == 40                 # aggregation saw everything
