"""The pluggable sim engine: golden parity with the pre-refactor
monolith, the PlatformModel registry, and SimResult edge-case guards."""
import inspect
import json
import os

import pytest

import repro.core.sim as sim_pkg
import repro.core.sim.engine as sim_engine
from repro.core.tracesim import (MODELS, Invocation, PlatformModel,
                                 SimParams, SimResult, compare, gen_trace,
                                 register_model, simulate)

MB = 1 << 20
GB = 1 << 30

GOLDEN = os.path.join(os.path.dirname(__file__), "data", "golden_sim.json")


# ---------------------------------------------------------------------------
# Golden parity: the refactored engine reproduces the monolith's summary()
# for all five models on a seeded trace (fixture captured pre-refactor).
# ---------------------------------------------------------------------------
def golden_params(model: str) -> SimParams:
    if model == "hydra-cluster":
        return SimParams(n_nodes=4, runtime_cap=192 * MB,
                         machine_cap=3 * GB)
    return SimParams()


@pytest.fixture(scope="module")
def golden_trace():
    return gen_trace(n_functions=60, n_tenants=16, duration_s=600.0,
                     mean_rps=3.0, seed=7)


@pytest.mark.parametrize("model", list(MODELS))
def test_golden_parity(model, golden_trace):
    with open(GOLDEN) as f:
        golden = json.load(f)
    got = simulate(golden_trace, model, golden_params(model)).summary()
    want = golden[model]
    assert set(got) == set(want)
    for key, expect in want.items():
        if isinstance(expect, float):
            assert got[key] == pytest.approx(expect, rel=1e-9), key
        else:
            assert got[key] == expect, key


def test_engine_has_no_model_name_branching():
    """Acceptance: every policy decision lives in a PlatformModel
    subclass — the engine and the simulate() entry point never compare
    model names."""
    for src in (inspect.getsource(sim_engine),
                inspect.getsource(sim_pkg.simulate)):
        assert "model ==" not in src
        assert '== "hydra' not in src and "== 'hydra" not in src
        assert '== "openwhisk"' not in src and '== "photons"' not in src


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
def test_models_registry_keeps_tuple_semantics():
    assert list(MODELS) == ["openwhisk", "photons", "hydra", "hydra-pool",
                            "hydra-cluster"]
    assert "hydra" in MODELS              # membership, like the old tuple
    for name, cls in MODELS.items():
        assert issubclass(cls, PlatformModel)
        assert cls.name == name


def test_register_model_plugs_into_simulate():
    class EagerHydra(MODELS["hydra"]):
        """A sixth model: per-tenant runtimes with free installs."""
        name = "eager-hydra"

        def install_cost(self, eng, nd, inv):
            return 0.0

    register_model(EagerHydra)
    try:
        trace = gen_trace(n_functions=10, n_tenants=2, duration_s=30.0,
                          mean_rps=4.0)
        base = simulate(trace, "hydra")
        eager = simulate(trace, "eager-hydra")
        assert len(eager.latencies) == len(base.latencies)
        # identical policy except installs are free -> overhead never worse
        assert sum(eager.overheads) < sum(base.overheads)
    finally:
        del MODELS["eager-hydra"]


def test_register_model_requires_name():
    class Anon(PlatformModel):
        pass

    with pytest.raises(ValueError):
        register_model(Anon)


# ---------------------------------------------------------------------------
# Satellite: guards on trivial/empty traces + compare(models=)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("model", list(MODELS))
def test_empty_trace_is_safe(model):
    s = simulate([], model).summary()
    assert s["requests"] == 0 and s["dropped"] == 0
    assert s["peak_mem_mb"] == 0
    # no metric raises or divides by zero; undefined ones are NaN
    assert s["p99_s"] != s["p99_s"]             # NaN
    assert s["ops_per_gb_s"] != s["ops_per_gb_s"]


def test_single_invocation_at_t0_is_safe():
    # one arrival at t=0: elapsed sample time is 0 -> density undefined,
    # everything else well-formed
    trace = [Invocation(t=0.0, fid=0, tenant=0, duration_s=0.2,
                        mem_bytes=64 * MB)]
    r = simulate(trace, "hydra")
    s = r.summary()
    assert s["requests"] == 1
    assert s["p99_s"] > 0
    assert r.mean_mem() >= 0


def test_empty_result_accessors():
    r = SimResult(model="x")
    assert r.p(99) != r.p(99)
    assert r.mean_mem() != r.mean_mem()
    assert r.mean_runtimes() != r.mean_runtimes()
    assert r.mean_pool_mem() == 0.0
    assert r.ops_per_gb_s() != r.ops_per_gb_s()


def test_compare_accepts_model_subset():
    trace = gen_trace(n_functions=10, n_tenants=2, duration_s=30.0,
                      mean_rps=4.0)
    out = compare(trace, models=["hydra", "hydra-pool"])
    assert list(out) == ["hydra", "hydra-pool"]
    with pytest.raises(ValueError):
        compare(trace, models=["hydra", "no-such-model"])


def test_tracesim_facade_reexports():
    # old private names and the module entry point survive the split
    from repro.core import tracesim
    assert tracesim._RuntimeInst is tracesim.RuntimeInst
    assert tracesim._Node is tracesim.Node
    assert tracesim.simulate is sim_pkg.simulate


# ---------------------------------------------------------------------------
# Streaming traces through the engine (lazy event feed)
# ---------------------------------------------------------------------------
def test_streamed_azure_sim_matches_in_memory_for_all_models():
    """Acceptance: a streamed sim of the full bundled sample is
    bit-identical to the in-memory loader's sim, for every model."""
    from repro.core.traces import Trace
    data = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "data")
    sample = os.path.join(data, "azure_sample.csv")
    dur = os.path.join(data, "azure_sample_durations.csv")
    mem_csv = os.path.join(data, "azure_sample_memory.csv")
    MB_ = 1 << 20
    GB_ = 1 << 30
    p = SimParams(runtime_cap=192 * MB_, machine_cap=3 * GB_, n_nodes=4,
                  pool_size=8, pool_min=1, pool_max=2)
    mem = Trace.from_azure(sample, durations_csv=dur, memory_csv=mem_csv)
    st = Trace.stream_azure(sample, durations_csv=dur, memory_csv=mem_csv)
    for model in MODELS:
        a = simulate(mem, model, p)
        b = simulate(st, model, p)
        assert a.latencies == b.latencies, model
        assert a.summary() == b.summary(), model


def test_engine_accepts_sorted_iterator():
    trace = gen_trace(n_functions=10, n_tenants=2, duration_s=60.0,
                      mean_rps=4.0, seed=11)
    a = simulate(list(trace), "hydra-pool", SimParams())
    b = simulate(iter(trace), "hydra-pool", SimParams())
    assert a.latencies == b.latencies
    assert a.summary() == b.summary()


def test_engine_rejects_unsorted_iterator():
    trace = gen_trace(n_functions=10, n_tenants=2, duration_s=60.0,
                      mean_rps=4.0, seed=11)
    shuffled = [trace[1], trace[0]] + trace[2:]
    with pytest.raises(ValueError, match="not time-sorted"):
        simulate(iter(shuffled), "hydra", SimParams())


def test_engine_sorts_unsorted_sequence_eagerly():
    # a Sequence (unlike a bare iterator) may arrive unsorted: the
    # engine falls back to pushing everything up front, and the result
    # matches the sorted run
    trace = gen_trace(n_functions=10, n_tenants=2, duration_s=60.0,
                      mean_rps=4.0, seed=11)
    shuffled = list(reversed(trace))
    a = simulate(trace, "hydra-pool", SimParams())
    b = simulate(shuffled, "hydra-pool", SimParams())
    assert a.summary() == b.summary()
