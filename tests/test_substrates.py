"""Data pipeline, checkpointing, fault tolerance, compression, optimizer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, Prefetcher, make_batch
from repro.ft import checkpoint as ckpt
from repro.ft.compression import ErrorFeedbackCompression, dequantize, quantize
from repro.ft.failures import (FailureInjector, HeartbeatMonitor,
                               InjectedFailure)
from repro.optim import AdamW, constant, warmup_cosine


# ---------------------------------------------------------------------------
def test_data_deterministic_and_host_sharded():
    cfg = DataConfig(vocab_size=100, seq_len=64, batch_size=4, seed=3)
    a = make_batch(cfg, step=7)
    b = make_batch(cfg, step=7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = make_batch(cfg, step=8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # different hosts get different data
    cfg2 = DataConfig(vocab_size=100, seq_len=64, batch_size=4, seed=3,
                      host_id=1, n_hosts=2)
    d = make_batch(cfg2, step=7)
    assert not np.array_equal(a["tokens"], d["tokens"])
    # labels are inputs shifted by one
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_prefetcher_order_and_resume():
    cfg = DataConfig(vocab_size=50, seq_len=32, batch_size=2)
    pf = Prefetcher(cfg, start_step=5)
    steps = [pf.next()[0] for _ in range(3)]
    pf.close()
    assert steps == [5, 6, 7]
    # resume mid-stream reproduces the same batch
    pf2 = Prefetcher(cfg, start_step=6)
    s, batch = pf2.next()
    pf2.close()
    np.testing.assert_array_equal(batch["tokens"],
                                  make_batch(cfg, 6)["tokens"])


# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                  "step": jnp.int32(7)}}
    ckpt.save(str(tmp_path), 3, tree)
    assert ckpt.latest_step(str(tmp_path)) == 3
    out = ckpt.restore(str(tmp_path), 3, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_atomicity(tmp_path):
    """A step dir without its .done marker is not visible."""
    tree = {"a": jnp.zeros(4)}
    path = ckpt.save(str(tmp_path), 1, tree)
    os.remove(path + ".done")
    assert ckpt.latest_step(str(tmp_path)) is None


def test_async_checkpointer_gc(tmp_path):
    w = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.zeros(128)}
    for s in range(5):
        w.save_async(s, tree)
        w.wait()
    assert ckpt.list_steps(str(tmp_path)) == [3, 4]


def test_elastic_restore_placement(tmp_path):
    """Restore re-places leaves via shardings (elastic mesh change)."""
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(16, dtype=jnp.float32)}
    ckpt.save(str(tmp_path), 0, tree)
    sh = {"w": NamedSharding(mesh, P("data"))}
    out = ckpt.restore(str(tmp_path), 0, tree, shardings=sh)
    assert out["w"].sharding == sh["w"]


# ---------------------------------------------------------------------------
def test_failure_injector_fires_once():
    inj = FailureInjector(fail_at_steps=(3,))
    inj.check(2)
    with pytest.raises(InjectedFailure):
        inj.check(3)
    inj.check(3)  # second attempt passes (recovery retried the step)


def test_heartbeat_straggler_detection():
    import time
    mon = HeartbeatMonitor()
    mon.beat("w0")
    mon.beat("w1")
    time.sleep(0.15)
    mon.beat("w0")
    assert mon.stragglers(0.1) == ["w1"]


def test_train_driver_failure_recovery(tmp_path):
    """End-to-end node-failure drill: fail at step 12, restore, resume,
    finish — final losses must be finite and training must progress."""
    from repro.launch.train import main
    losses = main(["--arch", "mamba2-780m", "--reduced", "--steps", "18",
                   "--batch", "2", "--seq", "32", "--fail-at", "12",
                   "--ckpt-dir", str(tmp_path), "--ckpt-every", "5"])
    assert len(losses) >= 18 - 11   # resumed from step 10/11
    assert all(np.isfinite(losses))


# ---------------------------------------------------------------------------
def test_quantize_dequantize_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 5
    q, s = quantize(x)
    err = jnp.abs(dequantize(q, s) - x)
    assert float(jnp.max(err)) <= float(s) * 0.5 + 1e-6


def test_error_feedback_compression_converges():
    """EF-compressed AdamW still optimizes a quadratic."""
    opt = ErrorFeedbackCompression(AdamW(lr=constant(0.2),
                                         weight_decay=0.0))
    params = {"w": jnp.full((8,), 5.0)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(80):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    assert float(loss(params)) < 0.5


def test_adamw_clip_and_schedule():
    opt = AdamW(lr=warmup_cosine(1e-2, 5, 50), clip_norm=1.0)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    huge = {"w": jnp.full((4,), 1e6)}
    p2, state, mets = opt.update(huge, state, params)
    assert float(mets["grad_norm"]) > 1e5
    # clipped: update magnitude bounded by lr * O(1)
    assert float(jnp.max(jnp.abs(p2["w"] - params["w"]))) < 1e-2
    assert float(mets["lr"]) == pytest.approx(1e-2 / 5, rel=1e-3)
