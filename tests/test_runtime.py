"""Hydra runtime behaviour: registration, invocation, isolation semantics,
code-cache sharing, arena pooling, budgets, continuous batching."""
import time

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import (CallableSpec, ContinuousBatcher, ExecutableCache,
                        FunctionNotRegisteredError, HydraOOMError,
                        HydraRuntime, LMSpec, MemoryBudget)
from repro.core.arena import ArenaPool
from repro.models.programs import ModelProgram

from conftest import bf16_params


def make_rt(**kw):
    kw.setdefault("memory_budget_bytes", 1 << 30)
    kw.setdefault("janitor", False)
    return HydraRuntime(**kw)


def simple_spec(name="affine"):
    def fn(params, args):
        return {"y": args["x"] * params["w"] + 1.0}
    return CallableSpec(name=name, fn=fn,
                        example_args={"x": jnp.ones((64,), jnp.float32)},
                        params={"w": jnp.full((64,), 2.0)})


# ---------------------------------------------------------------------------
def test_register_invoke_deregister():
    rt = make_rt()
    try:
        assert rt.register_function("f1", simple_spec())
        out = rt.invoke("f1", {"x": jnp.full((64,), 3.0)})
        assert float(out["y"][0]) == 7.0
        # duplicate registration rejected
        assert not rt.register_function("f1", simple_spec())
        assert rt.deregister_function("f1")
        with pytest.raises(FunctionNotRegisteredError):
            rt.invoke("f1", {"x": jnp.ones((64,))})
        assert not rt.deregister_function("f1")
    finally:
        rt.shutdown()


def test_executable_cache_shared_across_tenants():
    """Two tenants registering the same program compile ONCE (paper §3.3)."""
    rt = make_rt()
    try:
        rt.register_function("a/f", simple_spec(), tenant="a")
        rt.register_function("b/f", simple_spec(), tenant="b")
        stats = rt.exe_cache.stats()
        # one shared program entry + one shared arena-zeroer entry (the
        # slab scrubber compiles once per signature, at registration)
        assert stats["entries"] == 2
        assert stats["hits"] == 1
    finally:
        rt.shutdown()


def test_executable_cache_unshared_baseline():
    """shared=False = the per-context JIT baseline (compiles per fid)."""
    rt = make_rt(executable_cache=ExecutableCache(shared=False))
    try:
        rt.register_function("a/f", simple_spec(), tenant="a")
        rt.register_function("b/f", simple_spec(), tenant="b")
        # two per-fid program copies + the (always-shared) arena zeroer
        assert rt.exe_cache.stats()["entries"] == 3
    finally:
        rt.shutdown()


def test_slab_isolation_cross_owner_zeroed_same_owner_donated():
    """Slab allocator semantics: a slab handed across owners is scrubbed
    on-device (indistinguishable from a fresh zeroed arena); a slab
    claimed back by its own donor keeps its contents untouched."""
    pool = ArenaPool(ttl_s=1e9)
    sig = ("slab", 4096)
    factory = lambda: {"buf": jnp.zeros((1024,), jnp.float32)}
    pool.register_signature(
        sig, factory, {"buf": jax.ShapeDtypeStruct((1024,), jnp.float32)})

    a = pool.acquire(sig, owner="fn-a")
    a.buffers = {"buf": a.buffers["buf"] + 7.0}     # fn-a dirties the slab
    pool.release(a)

    b = pool.acquire(sig, owner="fn-a")             # donor reclaims it
    assert b is a
    assert float(b.buffers["buf"][0]) == 7.0        # contents preserved
    pool.release(b)

    c = pool.acquire(sig, owner="fn-b")             # cross-owner handover
    assert c is a
    assert float(jnp.max(jnp.abs(c.buffers["buf"]))) == 0.0   # scrubbed
    pool.release(c)

    counters = pool.metrics.counters
    assert counters["arena.cold"] == 1              # one slab ever minted
    assert counters["arena.reuse"] == 1
    assert counters["arena.zeroed"] == 1


def test_prealloc_pretouches_slabs_off_the_clock():
    pool = ArenaPool(ttl_s=1e9)
    calls = []

    def factory():
        calls.append(1)
        return {"buf": jnp.zeros((256,), jnp.float32)}

    pool.prealloc(("sig",), factory, 3, owner="fn")
    assert len(calls) == 3                 # n slabs actually materialized
    assert pool.idle_count == 3
    cold = pool.metrics.counters["arena.cold"]
    reuse = pool.metrics.counters.get("arena.reuse", 0)
    arenas = [pool.acquire(("sig",), owner="fn") for _ in range(3)]
    assert len(calls) == 3                 # claims are pure pool pops...
    assert pool.metrics.counters["arena.cold"] == cold
    # ...and pre-assigned slabs skip even the scrub (donated reuse)
    assert pool.metrics.counters["arena.reuse"] == reuse + 3
    for a in arenas:
        pool.release(a)


def test_arena_pool_warm_and_ttl():
    pool = ArenaPool(ttl_s=0.2)
    factory = lambda: {"buf": jnp.zeros((1024,), jnp.float32)}
    a = pool.acquire(("sig",), factory)
    pool.release(a)
    b = pool.acquire(("sig",), factory)
    assert b is a                                  # warm hit
    pool.release(b)
    assert pool.metrics.counters["arena.warm"] == 1
    time.sleep(0.3)
    released = pool.evict_idle()
    assert released == a.nbytes
    assert pool.idle_count == 0


def test_budget_oom():
    b = MemoryBudget(1000)
    b.reserve(800)
    with pytest.raises(HydraOOMError):
        b.reserve(300)
    b.release(500)
    b.reserve(300)
    assert b.used == 600
    assert b.peak == 800


def test_runtime_budget_admission():
    rt = make_rt(memory_budget_bytes=4 << 20)   # 4 MB runtime
    try:
        with pytest.raises(HydraOOMError):
            rt.register_function(
                "big", simple_spec(), mem_budget=16 << 20)
    finally:
        rt.shutdown()


def test_lm_generate_deterministic_and_warm():
    rt = make_rt(memory_budget_bytes=2 << 30)
    try:
        cfg = get_config("qwen2.5-3b").reduced()
        params = bf16_params(ModelProgram(cfg))
        rt.register_function("lm", LMSpec(cfg=cfg, params=params,
                                          max_seq=64, slots=1))
        t1 = rt.generate("lm", list(range(8)), max_new_tokens=6)
        cold = rt.metrics.counters["arena.cold"]
        t2 = rt.generate("lm", list(range(8)), max_new_tokens=6)
        assert t1 == t2
        assert rt.metrics.counters["arena.cold"] == cold  # pool hit
        assert rt.metrics.counters["arena.warm"] >= 1
    finally:
        rt.shutdown()


def test_continuous_batcher_matches_single_path():
    rt = make_rt(memory_budget_bytes=2 << 30)
    try:
        cfg = get_config("granite-moe-1b-a400m").reduced()
        params = bf16_params(ModelProgram(cfg))
        rt.register_function("lm", LMSpec(cfg=cfg, params=params,
                                          max_seq=64, slots=3))
        single = rt.generate("lm", list(range(8)), max_new_tokens=5)
        b = ContinuousBatcher(rt, "lm")
        futs = [b.submit(list(range(8)), 5) for _ in range(5)]
        b.run_until_done()
        outs = [f.result() for f in futs]
        assert all(o == single for o in outs)
        # 5 requests over 3 slots share decode steps
        assert b.steps < 5 * 5
        b.close()
    finally:
        rt.shutdown()


def test_invoke_latency_metrics_populated():
    rt = make_rt()
    try:
        rt.register_function("f", simple_spec())
        for _ in range(5):
            rt.invoke("f", {"x": jnp.ones((64,))})
        snap = rt.metrics.snapshot()
        assert snap["hists"]["invoke_latency_s"]["count"] == 5
    finally:
        rt.shutdown()
