"""ROADMAP "cross-PROCESS restore": a snapshot taken by one Python
process restores in a FRESH process with zero recompiles.

The in-process variant (test_platform.py) already proves a freshly
*constructed* platform restores through the persisted ExecutableCache;
this harness proves it across a real process boundary — the restart
story the paper's Native-Image-binary-on-disk analog promises. The
parent registers + snapshots + exports a function and shuts down; a
subprocess with its own interpreter (fresh JAX, fresh caches) imports
the exported record, restores from the on-disk snapshot, serves the
function, and reports its executable-cache stats: ``compiles`` must be
0 and ``disk_hits`` >= 1."""
import json
import os
import subprocess
import sys

import jax.numpy as jnp

from repro.core import CallableSpec, HydraPlatform

MB = 1 << 20
REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")

# the child rebuilds the SAME spec (program name + shapes = the
# executable-cache key; weights come from the snapshot, not from here)
CHILD_SCRIPT = r"""
import json, sys
import jax
import jax.numpy as jnp
from repro.core import CallableSpec, HydraPlatform

meta = json.load(open(sys.argv[1]))

def fn(params, args):
    return {"y": args["x"] * params["w"] + 1.0}

spec = CallableSpec(name="xproc", fn=fn,
                    example_args={"x": jnp.ones((64,), jnp.float32)},
                    params=None, arena_bytes=1 << 20)
plat = HydraPlatform(pool_size=1, runtime_budget_bytes=64 << 20,
                     snapshot_dir=meta["snapshot_dir"])
try:
    plat.import_function({
        "fid": meta["fid"], "spec": spec, "tenant": meta["tenant"],
        "mem_budget": meta["mem_budget"], "need_bytes": meta["need_bytes"],
        "params_spec": {"w": jax.ShapeDtypeStruct((64,), jnp.float32)},
        "invocations": meta["invocations"],
        "snapshot_path": meta["snapshot_path"]})
    plat.restore(meta["fid"])
    out = plat.invoke(meta["fid"], {"x": jnp.full((64,), 3.0)})
    print(json.dumps({"y0": float(out["y"][0]),
                      **plat.exe_cache.stats()}))
finally:
    plat.shutdown()
"""


def test_restore_in_fresh_process_zero_recompiles(tmp_path):
    def fn(params, args):
        return {"y": args["x"] * params["w"] + 1.0}

    spec = CallableSpec(name="xproc", fn=fn,
                        example_args={"x": jnp.ones((64,), jnp.float32)},
                        params={"w": jnp.full((64,), 2.0)},
                        arena_bytes=1 * MB)
    plat = HydraPlatform(pool_size=1, runtime_budget_bytes=64 * MB,
                         snapshot_dir=str(tmp_path))
    try:
        plat.register_function("t0/f", spec, tenant="t0")
        before = plat.invoke("t0/f", {"x": jnp.full((64,), 3.0)})
        exported = plat.export_function("t0/f")
    finally:
        plat.shutdown()
    # program + its arena-signature zeroer: both compiled at registration
    assert plat.exe_cache.stats()["compiles"] == 2

    meta = {"snapshot_dir": str(tmp_path),
            "fid": exported["fid"], "tenant": exported["tenant"],
            "mem_budget": exported["mem_budget"],
            "need_bytes": exported["need_bytes"],
            "invocations": exported["invocations"],
            "snapshot_path": exported["snapshot_path"]}
    meta_path = tmp_path / "export.json"
    meta_path.write_text(json.dumps(meta))
    child = tmp_path / "child.py"
    child.write_text(CHILD_SCRIPT)

    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, str(child), str(meta_path)],
                          capture_output=True, text=True, timeout=300,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    stats = json.loads(proc.stdout.strip().splitlines()[-1])

    # the fresh process served the restored function correctly...
    assert stats["y0"] == float(before["y"][0]) == 7.0
    # ...with ZERO compilations: the executable deserialized from the
    # cache persisted by the PARENT process
    assert stats["compiles"] == 0
    assert stats["disk_hits"] >= 1
    # snapshot_dir also switched on jax's persistent compilation cache
    # (the layer under serialize_executable) in both processes
    assert stats["xla_cache_enabled"] is True


# ---------------------------------------------------------------------------
XLA_CACHE_CHILD = r"""
import sys
import jax
import jax.numpy as jnp
from repro.core.executable_cache import enable_persistent_compilation_cache

assert enable_persistent_compilation_cache(sys.argv[1])
out = jax.jit(lambda x: (x * 3.0 + 1.0).sum())(jnp.ones((257,), jnp.float32))
print(float(out))
"""


def test_xla_persistent_cache_reused_by_fresh_process(tmp_path):
    """The layer UNDER our serialize_executable payloads: jax's persistent
    compilation cache. The first process writes its XLA compilation to the
    shared directory; a second, fresh process compiling the same program
    replays it from disk instead of re-running XLA — no new cache entries
    appear. (Run in subprocesses because the cache dir is process-global.)"""
    cache_dir = tmp_path / "xla"
    script = tmp_path / "xla_child.py"
    script.write_text(XLA_CACHE_CHILD)
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)

    def run_once():
        proc = subprocess.run(
            [sys.executable, str(script), str(cache_dir)],
            capture_output=True, text=True, timeout=300, env=env)
        assert proc.returncode == 0, proc.stderr[-2000:]
        assert proc.stdout.strip().splitlines()[-1] == "1028.0"
        return sorted(os.listdir(cache_dir))

    first = run_once()
    assert first                     # the compile was written to disk
    second = run_once()
    assert second == first           # cache hit: nothing new written
