"""Sharding policy rules + a real multi-device lower/compile smoke (run in a
subprocess so the 8-device XLA flag doesn't contaminate this process)."""
import json
import subprocess
import sys
import textwrap

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.launch.sharding import (default_rules, logical_spec,
                                   param_specs, use_rules)
from repro.models import transformer as tf


def mesh1():
    return make_mesh((1, 1), ("data", "model"))


def test_param_rules_no_duplicate_axes():
    """No PartitionSpec may map one mesh axis to two dims (for every arch
    and both serve/train rule-sets)."""
    m = mesh1()
    for arch in ("qwen2.5-3b", "dbrx-132b", "granite-moe-1b-a400m",
                 "mamba2-780m", "zamba2-2.7b", "gemma3-1b"):
        cfg = get_config(arch).reduced()
        params = jax.eval_shape(
            lambda c=cfg: tf.init_params(jax.random.PRNGKey(0), c))
        for fsdp in (False, True):
            rules = default_rules(m, fsdp=fsdp)
            specs = param_specs(params, rules, cfg)
            for s in jax.tree.leaves(
                    specs, is_leaf=lambda x: isinstance(x, P)):
                flat = [a for dim in s for a in
                        (dim if isinstance(dim, tuple) else (dim,))
                        if a is not None]
                assert len(flat) == len(set(flat)), (arch, s)


def test_kv_replicated_when_heads_not_divisible():
    """gemma3 has 1 KV head: its wk/wv must be replicated under TP-16
    (production mesh geometry via AbstractMesh — no devices needed)."""
    from repro.launch.mesh import make_abstract_mesh
    m = make_abstract_mesh((16, 16), ("data", "model"))
    cfg = get_config("gemma3-1b")
    params = jax.eval_shape(
        lambda: tf.init_params(jax.random.PRNGKey(0), cfg))
    rules = default_rules(m)
    specs = param_specs(params, rules, cfg)
    wk = specs["layers"]["attn"]["wk"]
    assert all(a is None for a in wk), wk
    wq = specs["layers"]["attn"]["wq"]
    assert "model" in [a for a in wq if a]


def test_logical_spec_resolution():
    m = mesh1()
    rules = default_rules(m, fsdp=True, kv_seq=True)
    with use_rules(rules):
        assert logical_spec("batch", None, "ff") == P(None, None, "model")
        # kv_seq claims data; batch excludes it
        assert rules.kv_seq == "data"
        assert "data" not in rules.batch


def test_no_rules_is_noop(rng):
    from repro.launch.sharding import shard
    x = jax.numpy.ones((4, 4))
    assert shard(x, "batch", None) is x


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, dataclasses
    import jax, jax.numpy as jnp
    from repro.launch.mesh import make_mesh
    from repro.launch.sharding import default_rules, named_sharding_tree, use_rules
    from repro.launch.roofline import analyze
    from repro.models.programs import ModelProgram
    from repro.configs import get_config
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_mesh((4, 2), ("data", "model"))
    cfg = get_config("qwen2.5-3b").reduced()
    prog = ModelProgram(cfg, remat=False, unroll=True)
    rules = default_rules(mesh, fsdp=True)
    with use_rules(rules):
        params = jax.eval_shape(lambda: prog.init(jax.random.PRNGKey(0)))
        pspecs = named_sharding_tree(params, rules, cfg)
        batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        bspecs = {k: NamedSharding(mesh, P("data", None)) for k in batch}
        def loss(p, b):
            return prog.loss_fn(p, b)[0]
        comp = jax.jit(jax.grad(loss), in_shardings=(pspecs, bspecs)).lower(
            params, batch).compile()
        r = analyze(comp, mesh.size)
        print(json.dumps({"flops": r.flops_per_device,
                          "wire": r.wire_bytes_per_device,
                          "ncoll": r.collectives["count"]}))
""")


def test_multi_device_lower_compile_and_collectives():
    """Real SPMD compile on 8 host devices: collectives must appear and the
    roofline analyzer must parse them."""
    out = subprocess.run(
        [sys.executable, "-c", SUBPROC], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}, cwd="/root/repo",
        timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    stats = json.loads(out.stdout.strip().splitlines()[-1])
    assert stats["flops"] > 0
    assert stats["ncoll"] > 0          # FSDP+TP must emit collectives
    assert stats["wire"] > 0
