"""Per-architecture smoke tests (reduced configs) + cache-path consistency.

Every assigned arch: one forward + one train step on CPU, asserting output
shapes and no NaNs; prefill+decode must match the cacheless forward.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import applicable_shapes, get_config, list_archs
from repro.models import transformer as tf
from repro.models.programs import ModelProgram
from repro.optim import AdamW, constant

ARCHS = list_archs()


def make_batch(cfg, B, S, rng):
    batch = {}
    if cfg.family == "audio":
        batch["embeds"] = jax.random.normal(
            rng, (B, S, cfg.d_model), jnp.float32).astype(
            jnp.dtype(cfg.dtype))
    elif cfg.family == "vlm":
        ft = cfg.frontend_tokens
        batch["embeds"] = jax.random.normal(
            rng, (B, ft, cfg.d_model)).astype(jnp.dtype(cfg.dtype))
        batch["tokens"] = jax.random.randint(rng, (B, S - ft), 0,
                                             cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    prog = ModelProgram(cfg, remat=True)
    params = prog.init(rng)
    B, S = 2, 32
    batch = make_batch(cfg, B, S, rng)

    logits, aux = jax.jit(
        lambda p, b: tf.forward(p, cfg, tokens=b.get("tokens"),
                                embeds=b.get("embeds")))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size), logits.shape
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))

    opt = AdamW(lr=constant(1e-3))
    step = jax.jit(prog.make_train_step(opt, n_micro=2))
    params2, _, mets = step(params, opt.init(params), batch)
    assert np.isfinite(float(mets["loss"]))
    # params changed
    delta = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, params2))
    assert max(delta) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch, rng):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    prog = ModelProgram(cfg, remat=False)
    params = prog.init(rng)
    B, S = 2, 16
    full = make_batch(cfg, B, S + 1, rng)
    full.pop("labels")

    logits_full, _ = jax.jit(
        lambda p, b: tf.forward(p, cfg, tokens=b.get("tokens"),
                                embeds=b.get("embeds")))(params, full)

    pre = dict(full)
    if cfg.family == "audio":
        pre["embeds"] = full["embeds"][:, :S]
        dec_in = {"embeds": full["embeds"][:, S:S + 1]}
    elif cfg.family == "vlm":
        pre["tokens"] = full["tokens"][:, :-1]
        dec_in = {"tokens": full["tokens"][:, -1:]}
    else:
        pre["tokens"] = full["tokens"][:, :S]
        dec_in = {"tokens": full["tokens"][:, S:S + 1]}

    last_logits, cache = jax.jit(prog.prefill)(params, pre)
    np.testing.assert_allclose(last_logits, logits_full[:, S - 1],
                               atol=3e-5, rtol=3e-5)
    # grow kv slabs so decode has room
    for key in ("k", "v"):
        if key in cache:
            kv = cache[key]
            cache[key] = jnp.concatenate(
                [kv, jnp.zeros(kv.shape[:2] + (4,) + kv.shape[3:],
                               kv.dtype)], axis=2)
    dec_logits, new_cache = jax.jit(prog.decode_step)(params, cache, dec_in)
    np.testing.assert_allclose(dec_logits, logits_full[:, -1],
                               atol=3e-5, rtol=3e-5)
    assert int(new_cache["length"][0]) == S + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_all_shapes(arch):
    cfg = get_config(arch)
    prog = ModelProgram(cfg)
    for shape in applicable_shapes(cfg):
        specs = prog.input_specs(shape)
        assert specs, (arch, shape.name)
        for v in jax.tree.leaves(specs):
            assert isinstance(v, jax.ShapeDtypeStruct)
        if shape.kind == "decode":
            cache = prog.cache_specs(shape.global_batch, shape.seq_len)
            assert prog.cache_bytes(shape.global_batch, shape.seq_len) > 0
            assert "length" in cache


def test_gemma_window_pattern():
    cfg = get_config("gemma3-1b")
    w = np.asarray(tf.layer_windows(cfg))
    assert (w[:5] == cfg.sliding_window).all()
    assert w[5] > 1e8          # every 6th layer is global
    assert (w != cfg.sliding_window).sum() == cfg.n_layers // 6


def test_unroll_equals_scan(rng):
    for arch in ("gemma3-1b", "zamba2-2.7b", "mamba2-780m"):
        cfg = dataclasses.replace(get_config(arch).reduced(),
                                  dtype="float32")
        params = tf.init_params(rng, cfg)
        toks = jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)
        a, _ = jax.jit(lambda p, t: tf.forward(p, cfg, tokens=t))(params,
                                                                  toks)
        b, _ = jax.jit(lambda p, t: tf.forward(p, cfg, tokens=t,
                                               unroll=True))(params, toks)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)
