"""hydralint self-tests: every checker must flag the known-bad shape it
was built from (PR 4/5/9 bug classes) and pass the fixed shape; the
baseline may only shrink; inline/scoped suppressions work; the CFG
engine routes exception edges correctly; and the runtime lock/leak
sanitizers catch an A/B-B/A inversion and an unreturned claim.
Finally, the real tree must lint clean — the CI gate this PR extends."""
import ast
import json
import os
import subprocess
import sys
import textwrap
import threading

import pytest

from tools.hydralint import load_baseline, run_lint, write_baseline
from tools.hydralint import flow, leaksan, locksan

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint_fixture(tmp_path, files, select):
    """Write {relpath: source} under tmp_path and lint it."""
    paths = []
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
        paths.append(rel)
    return run_lint(paths, tmp_path, select={select})


# ---------------------------------------------------------------------------
# HL001 lock discipline
# ---------------------------------------------------------------------------
BAD_LOCK = """\
    import threading

    class Metrics:
        def __init__(self):
            self._lock = threading.Lock()
            self._c = {}

        def inc(self, name):
            with self._lock:
                self._c[name] = self._c.get(name, 0) + 1

        def read(self, name):
            return self._c.get(name, 0)
"""

GOOD_LOCK = BAD_LOCK.replace(
    "        def read(self, name):\n"
    "            return self._c.get(name, 0)\n",
    "        def read(self, name):\n"
    "            with self._lock:\n"
    "                return self._c.get(name, 0)\n")


def test_hl001_flags_unguarded_read_of_locked_attr(tmp_path):
    res = lint_fixture(tmp_path, {"src/m.py": BAD_LOCK}, "HL001")
    assert [f.detail for f in res.findings] == ["Metrics.read:_c"]
    assert "without it" in res.findings[0].message


def test_hl001_passes_when_all_access_is_locked(tmp_path):
    res = lint_fixture(tmp_path, {"src/m.py": GOOD_LOCK}, "HL001")
    assert res.findings == []


def test_hl001_condition_aliases_to_wrapped_lock(tmp_path):
    src = """\
        import threading

        class Q:
            def __init__(self):
                self._lock = threading.Lock()
                self._cv = threading.Condition(self._lock)
                self._items = []

            def put(self, x):
                with self._lock:
                    self._items.append(x)

            def drain(self):
                with self._cv:          # same lock: no finding
                    return list(self._items)
    """
    res = lint_fixture(tmp_path, {"src/q.py": src}, "HL001")
    assert res.findings == []


def test_hl001_rmw_in_thread_owning_class(tmp_path):
    src = """\
        import threading

        class Ticker:
            def __init__(self):
                self.ticks = 0
                self._t = threading.Thread(target=self.run)

            def run(self):
                self.ticks += 1
    """
    res = lint_fixture(tmp_path, {"src/t.py": src}, "HL001")
    assert [f.detail for f in res.findings] == ["Ticker.run:ticks:rmw"]


def test_hl001_caller_holds_lock_helper_pattern(tmp_path):
    src = """\
        import threading

        class G:
            def __init__(self):
                self._lock = threading.Lock()
                self._q = []

            def submit(self, x):
                with self._lock:
                    self._q.append(x)
                    self._next()

            def _next(self):
                # caller holds the lock (every call site does)
                return self._q.pop(0)
    """
    res = lint_fixture(tmp_path, {"src/g.py": src}, "HL001")
    assert res.findings == []


# ---------------------------------------------------------------------------
# HL002 hot-path purity
# ---------------------------------------------------------------------------
BAD_HOTPATH = """\
    import jax.numpy as jnp
    import numpy as np

    class Gateway:
        def _worker_loop(self):
            return self._payload()

        def _payload(self):
            # the PR 5 args_for bug shape: eager device-array per request
            return jnp.full((64,), 3.0)
"""

GOOD_HOTPATH = BAD_HOTPATH.replace("jnp.full((64,), 3.0)",
                                   "np.full((64,), 3.0)")


def test_hl002_flags_eager_jnp_reachable_from_root(tmp_path):
    res = lint_fixture(tmp_path, {"src/gw.py": BAD_HOTPATH}, "HL002")
    assert len(res.findings) == 1
    f = res.findings[0]
    assert "jnp.full" in f.message
    assert "Gateway._worker_loop" in f.message    # names the root
    assert f.detail.startswith("Gateway._payload:")


def test_hl002_host_numpy_is_fine(tmp_path):
    res = lint_fixture(tmp_path, {"src/gw.py": GOOD_HOTPATH}, "HL002")
    assert res.findings == []


def test_hl002_marker_declares_extra_root(tmp_path):
    src = """\
        import time

        def claim():  # hydralint: hot-path-root
            time.sleep(0.1)
    """
    res = lint_fixture(tmp_path, {"src/a.py": src}, "HL002")
    assert [f.detail for f in res.findings] == ["claim:time.sleep:0"]


def test_hl002_scoped_disable_cuts_traversal(tmp_path):
    src = """\
        import time

        class Gateway:
            def _worker_loop(self):
                return self._register()

            # registration cost is modeled, not hot-path
            def _register(self):  # hydralint: disable=HL002
                time.sleep(0.1)
    """
    res = lint_fixture(tmp_path, {"src/gw.py": src}, "HL002")
    assert res.findings == []


# ---------------------------------------------------------------------------
# HL003 sim determinism
# ---------------------------------------------------------------------------
def test_hl003_flags_wallclock_and_unseeded_rng(tmp_path):
    src = """\
        # hydralint: sim-module
        import random
        import time

        def step(pending):
            now = time.time()
            jitter = random.random()
            for node in {1, 2, 3}:
                pass
            return now + jitter
    """
    res = lint_fixture(tmp_path, {"src/core/sim2.py": src}, "HL003")
    details = sorted(f.detail for f in res.findings)
    assert details == ["set-iter:L8", "unseeded:random.random",
                       "wallclock:time.time"]


def test_hl003_seeded_rng_and_sorted_iter_pass(tmp_path):
    src = """\
        # hydralint: sim-module
        import numpy as np

        def step(nodes, seed):
            rng = np.random.default_rng(seed)
            for node in sorted(nodes):
                pass
            return rng.random()
    """
    res = lint_fixture(tmp_path, {"src/core/sim2.py": src}, "HL003")
    assert res.findings == []


def test_hl003_ignores_non_sim_files(tmp_path):
    src = "import time\n\ndef now():\n    return time.time()\n"
    res = lint_fixture(tmp_path, {"src/other.py": src}, "HL003")
    assert res.findings == []


# ---------------------------------------------------------------------------
# HL004 metric vocabulary
# ---------------------------------------------------------------------------
EMITTER = """\
    class Node:
        def __init__(self, metrics):
            self.metrics = metrics

        def boot(self):
            self.metrics.inc("pool.miss")
"""

MAPPING_WITH = 'WIRED = {"pool.miss": "cold_runtime"}\n'
MAPPING_WITHOUT = 'WIRED = {}\n'


def test_hl004_flags_unmapped_live_metric(tmp_path):
    res = lint_fixture(tmp_path, {"src/gateway/node.py": EMITTER,
                                  "src/gateway/replay.py": MAPPING_WITHOUT},
                       "HL004")
    assert [f.detail for f in res.findings] == ["unmapped:pool.miss"]


def test_hl004_mapped_metric_passes(tmp_path):
    res = lint_fixture(tmp_path, {"src/gateway/node.py": EMITTER,
                                  "src/gateway/replay.py": MAPPING_WITH},
                       "HL004")
    assert res.findings == []


def test_hl004_flags_phantom_read(tmp_path):
    mapping = 'def pull(cm):\n    return cm.counters.get("ghost.metric", 0)\n'
    res = lint_fixture(tmp_path, {"src/gateway/node.py": EMITTER,
                                  "src/gateway/replay.py": mapping},
                       "HL004")
    assert "phantom:ghost.metric" in [f.detail for f in res.findings]


def test_hl004_counters_key_parity_across_adapters(tmp_path):
    targets = """\
        class A:
            def counters(self):
                return {"cold": 1, "warm": 2}

        class B:
            def counters(self):
                return {"cold": 1}
    """
    res = lint_fixture(tmp_path, {"src/gateway/targets.py": targets},
                       "HL004")
    assert [f.detail for f in res.findings] == ["counters-parity:B"]


# ---------------------------------------------------------------------------
# HL005 adapter conformance
# ---------------------------------------------------------------------------
def test_hl005_flags_missing_base_attr_and_unimplemented(tmp_path):
    targets = """\
        class TargetAdapter:
            def invoke(self, fid, args):
                raise NotImplementedError

        class PlatformTarget(TargetAdapter):
            pass
    """
    user = """\
        def drive(adapter):
            adapter.invoke("f", {})
            adapter.sample()
    """
    res = lint_fixture(tmp_path, {"src/gateway/targets.py": targets,
                                  "src/gateway/replay.py": user}, "HL005")
    assert sorted(f.detail for f in res.findings) == [
        "base-missing:sample", "unimplemented:PlatformTarget.invoke"]


def test_hl005_full_surface_passes(tmp_path):
    targets = """\
        class TargetAdapter:
            n_nodes = 1

            def invoke(self, fid, args):
                raise NotImplementedError

        class PlatformTarget(TargetAdapter):
            def invoke(self, fid, args):
                return {}
    """
    user = """\
        def drive(adapter):
            adapter.invoke("f", {})
            return adapter.n_nodes
    """
    res = lint_fixture(tmp_path, {"src/gateway/targets.py": targets,
                                  "src/gateway/replay.py": user}, "HL005")
    assert res.findings == []


# ---------------------------------------------------------------------------
# HL006 docs references
# ---------------------------------------------------------------------------
def test_hl006_flags_dangling_ref_and_missing_module(tmp_path):
    (tmp_path / "README.md").write_text(
        "See `missing_file.py` for details.\n\n"
        "```bash\npython -m nope.mod\n```\n")
    res = run_lint([], tmp_path, select={"HL006"})
    assert sorted(f.detail for f in res.findings) == [
        "module:nope.mod", "ref:missing_file.py"]


def test_hl006_resolved_refs_pass(tmp_path):
    (tmp_path / "real.py").write_text("x = 1\n")
    (tmp_path / "README.md").write_text(
        "See `real.py`.\n\n```bash\npython real.py\n```\n")
    res = run_lint([], tmp_path, select={"HL006"})
    assert res.findings == []


# ---------------------------------------------------------------------------
# HL007 argparse hygiene
# ---------------------------------------------------------------------------
def test_hl007_flags_missing_and_empty_help(tmp_path):
    src = """\
        import argparse

        ap = argparse.ArgumentParser()
        ap.add_argument("--good", help="does a thing")
        ap.add_argument("--bare")
        ap.add_argument("--blank", help="")
    """
    res = lint_fixture(tmp_path, {"src/cli.py": src}, "HL007")
    assert sorted(f.detail for f in res.findings) == [
        "empty-help:--blank", "no-help:--bare"]


# ---------------------------------------------------------------------------
# HL008 span discipline
# ---------------------------------------------------------------------------
# minimal registry so the checker's AST loader finds the vocabulary
TRACING_STUB = """\
    PHASES = ("admission", "queue_wait", "compute")
"""


def test_hl008_flags_bare_span_and_unknown_phase(tmp_path):
    src = """\
        def handle(ctx):
            ctx.span("compute")                 # bare: times nothing
            with ctx.span("made_up_phase"):     # not in the registry
                pass
    """
    res = lint_fixture(tmp_path, {"src/repro/core/tracing.py": TRACING_STUB,
                                  "src/gw.py": src}, "HL008")
    assert sorted(f.detail for f in res.findings) == [
        "bare-span:compute:L2", "unknown-phase:made_up_phase"]
    assert "context manager" in res.findings[0].message


def test_hl008_with_usage_and_registry_names_pass(tmp_path):
    src = """\
        def handle(ctx, t0, t1):
            with ctx.span("queue_wait"):
                pass
            with ctx.span("compute") as sp:
                sp.attrs["n"] = 1
            ctx.add_span("admission", t0, t1)
    """
    res = lint_fixture(tmp_path, {"src/repro/core/tracing.py": TRACING_STUB,
                                  "src/gw.py": src}, "HL008")
    assert res.findings == []


def test_hl008_missing_registry_skips_name_check_not_shape_check(tmp_path):
    # no tracing.py anywhere: phase-name checks are skipped rather than
    # guessed, but the context-manager rule still applies
    src = """\
        def handle(ctx):
            ctx.span("whatever")
    """
    res = lint_fixture(tmp_path, {"src/gw.py": src}, "HL008")
    assert [f.detail for f in res.findings] == ["bare-span:whatever:L2"]


def test_hl008_sim_code_must_not_trace(tmp_path):
    src = """\
        # hydralint: sim-module
        from repro.core.tracing import Tracer

        def step(ctx):
            with ctx.span("compute"):
                pass
    """
    res = lint_fixture(tmp_path, {"src/repro/core/tracing.py": TRACING_STUB,
                                  "src/core/sim2.py": src}, "HL008")
    assert sorted(f.detail for f in res.findings) == [
        "sim-import:repro.core.tracing", "sim-tracing:span:L5"]


def test_hl008_tracing_module_itself_is_exempt(tmp_path):
    impl = """\
        PHASES = ("admission", "queue_wait", "compute")

        class RequestTrace:
            def span(self, name):
                return self.span(name)      # machinery, not a call site
    """
    res = lint_fixture(tmp_path,
                       {"src/repro/core/tracing.py": impl}, "HL008")
    assert res.findings == []


def test_hl008_disable_comment_suppresses(tmp_path):
    src = """\
        def probe(ctx):
            # hydralint: disable=HL008 — identity check, not a timing
            assert ctx.span("compute") is ctx.span("compute")
    """
    res = lint_fixture(tmp_path, {"src/repro/core/tracing.py": TRACING_STUB,
                                  "src/gw.py": src}, "HL008")
    assert res.findings == []
    assert len(res.suppressed) == 2


# ---------------------------------------------------------------------------
# suppression mechanics
# ---------------------------------------------------------------------------
def test_inline_disable_suppresses_and_is_counted(tmp_path):
    src = BAD_LOCK.replace(
        "return self._c.get(name, 0)",
        "return self._c.get(name, 0)  # hydralint: disable=HL001 — stale ok")
    res = lint_fixture(tmp_path, {"src/m.py": src}, "HL001")
    assert res.findings == []
    assert [f.detail for f in res.suppressed] == ["Metrics.read:_c"]


def test_disable_on_comment_line_covers_next_statement(tmp_path):
    src = BAD_LOCK.replace(
        "            return self._c.get(name, 0)",
        "            # hydralint: disable=HL001 — approximate read is fine\n"
        "            return self._c.get(name, 0)")
    res = lint_fixture(tmp_path, {"src/m.py": src}, "HL001")
    assert res.findings == []


def test_scoped_disable_on_def_covers_body(tmp_path):
    src = BAD_LOCK.replace(
        "def read(self, name):",
        "def read(self, name):  # hydralint: disable=HL001")
    res = lint_fixture(tmp_path, {"src/m.py": src}, "HL001")
    assert res.findings == []


# ---------------------------------------------------------------------------
# baseline: shrink-only
# ---------------------------------------------------------------------------
def test_baseline_masks_known_findings_and_flags_stale(tmp_path):
    res = lint_fixture(tmp_path, {"src/m.py": BAD_LOCK}, "HL001")
    bl_path = tmp_path / "baseline.json"
    write_baseline(bl_path, res.findings)
    baseline = load_baseline(bl_path)
    assert res.new_against(baseline) == []

    # fixing the bug leaves the baseline entry stale -> must be removed
    fixed = lint_fixture(tmp_path, {"src/m.py": GOOD_LOCK}, "HL001")
    assert fixed.new_against(baseline) == []
    stale = fixed.stale_baseline_keys(baseline)
    assert stale and stale[0].startswith("HL001::src/m.py::")


def test_baseline_key_is_line_number_stable(tmp_path):
    res1 = lint_fixture(tmp_path, {"src/m.py": BAD_LOCK}, "HL001")
    shifted = "# a new leading comment\n" + textwrap.dedent(BAD_LOCK)
    res2 = lint_fixture(tmp_path, {"src/m.py": shifted}, "HL001")
    assert [f.key for f in res1.findings] == [f.key for f in res2.findings]
    assert res1.findings[0].line != res2.findings[0].line


def test_missing_baseline_file_is_empty():
    assert load_baseline("/nonexistent/baseline.json") == {}


# ---------------------------------------------------------------------------
# CLI: the CI gate fails on a seeded regression and on stale baseline
# ---------------------------------------------------------------------------
def _run_cli(args, cwd):
    env = dict(os.environ, PYTHONPATH=REPO_ROOT)
    return subprocess.run([sys.executable, "-m", "tools.hydralint", *args],
                          cwd=cwd, env=env, capture_output=True, text=True)


def test_cli_exit_codes(tmp_path):
    bad = tmp_path / "src" / "m.py"
    bad.parent.mkdir(parents=True)
    bad.write_text(textwrap.dedent(BAD_LOCK))

    r = _run_cli(["src", "--root", str(tmp_path), "--select", "HL001"],
                 cwd=REPO_ROOT)
    assert r.returncode == 1
    assert "HL001" in r.stdout

    bad.write_text(textwrap.dedent(GOOD_LOCK))
    r = _run_cli(["src", "--root", str(tmp_path), "--select", "HL001"],
                 cwd=REPO_ROOT)
    assert r.returncode == 0, r.stdout + r.stderr

    # stale baseline entries fail even on a clean tree (shrink-only)
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps(
        {"version": 1, "findings": {"HL001::src/m.py::Gone.read:_x": "old"}}))
    r = _run_cli(["src", "--root", str(tmp_path), "--select", "HL001",
                  "--baseline", str(bl)], cwd=REPO_ROOT)
    assert r.returncode == 1
    assert "stale" in (r.stdout + r.stderr).lower()


# ---------------------------------------------------------------------------
# locksan: runtime lock-order sanitizer
# ---------------------------------------------------------------------------
def test_locksan_detects_ab_ba_inversion():
    san = locksan.LockOrderSanitizer()
    a = san.make_lock("A")
    b = san.make_lock("B")

    def t1():
        with a:
            with b:
                pass

    def t2():
        with b:
            with a:
                pass

    th1 = threading.Thread(target=t1)
    th1.start()
    th1.join()
    th2 = threading.Thread(target=t2)
    th2.start()
    th2.join()
    reports = san.check()
    assert len(reports) == 1
    assert "A" in reports[0] and "B" in reports[0]
    with pytest.raises(locksan.LockOrderViolation):
        san.assert_clean()


def test_locksan_consistent_order_is_clean():
    san = locksan.LockOrderSanitizer()
    a = san.make_lock("A")
    b = san.make_lock("B")
    for _ in range(3):
        with a:
            with b:
                pass
    assert san.check() == []
    san.assert_clean()


def test_locksan_condition_and_handoff_locks_not_false_positives():
    with locksan.sanitized() as san:
        cv = threading.Condition()
        done = []

        def waiter():
            with cv:
                while not done:
                    cv.wait(timeout=5.0)

        t = threading.Thread(target=waiter)
        t.start()
        with cv:
            done.append(1)
            cv.notify()
        t.join(timeout=10.0)
    assert san.check() == []


def test_locksan_sanitized_raises_on_inversion():
    with pytest.raises(locksan.LockOrderViolation):
        with locksan.sanitized():
            a = threading.Lock()
            b = threading.Lock()
            with a:
                with b:
                    pass
            with b:
                with a:
                    pass


# ---------------------------------------------------------------------------
# flow: the exception-edge CFG both HL009 and HL010 run on
# ---------------------------------------------------------------------------
def _cfg_of(src):
    tree = ast.parse(textwrap.dedent(src))
    fn = next(n for n in ast.walk(tree) if isinstance(n, ast.FunctionDef))
    return flow.build_cfg(fn)


def test_cfg_finally_runs_on_normal_and_exception_paths():
    cfg = _cfg_of("""\
        def f():
            x = acquire()
            try:
                work(x)
            finally:
                cleanup(x)
    """)
    (work,) = cfg.nodes_at(4)
    cleanups = cfg.nodes_at(6)
    # the finally body is duplicated per continuation so the normal and
    # exceptional passes through it stay distinct
    assert len(cleanups) >= 2
    assert cfg.has_path(work.idx, cfg.exit, exceptional=False)
    assert any(cfg.has_path(work.idx, c.idx, exceptional=False)
               for c in cleanups)
    # the raise continuation ALSO runs a cleanup copy, reached only via
    # the exception edge out of work(x)
    assert cfg.has_path(work.idx, cfg.raise_, exceptional=True)
    assert not cfg.has_path(work.idx, cfg.raise_, exceptional=False)


def test_cfg_with_suppression_resumes_after_the_block():
    cfg = _cfg_of("""\
        def f():
            with contextlib.suppress(KeyError):
                raise KeyError
            after()
    """)
    (rs,) = cfg.nodes_at(3, "raise-stmt")
    (after,) = cfg.nodes_at(4)
    assert cfg.has_path(rs.idx, after.idx)

    plain = _cfg_of("""\
        def f():
            with self._lock:
                raise KeyError
            after()
    """)
    (rs,) = plain.nodes_at(3, "raise-stmt")
    (after,) = plain.nodes_at(4)
    assert not plain.has_path(rs.idx, after.idx)
    assert plain.has_path(rs.idx, plain.raise_)


def test_cfg_early_return_threads_through_finally():
    cfg = _cfg_of("""\
        def f(c):
            try:
                if c:
                    return 1
                work()
            finally:
                cleanup()
    """)
    (ret,) = cfg.nodes_at(4, "return")
    (work,) = cfg.nodes_at(5)
    cleanups = cfg.nodes_at(7)
    assert any(cfg.has_path(ret.idx, c.idx, exceptional=False)
               for c in cleanups)
    assert cfg.has_path(ret.idx, cfg.exit, exceptional=False)
    assert not cfg.has_path(ret.idx, work.idx)


def test_cfg_nested_handlers_dispatch_and_catch_all():
    cfg = _cfg_of("""\
        def f():
            try:
                try:
                    risky()
                except KeyError:
                    pass
            except Exception:
                pass
            done()
    """)
    (risky,) = cfg.nodes_at(4)
    (h_inner,) = cfg.nodes_at(5, "except")
    (h_outer,) = cfg.nodes_at(7, "except")
    (done,) = cfg.nodes_at(9)
    assert cfg.has_path(risky.idx, h_inner.idx)
    assert not cfg.has_path(risky.idx, h_inner.idx, exceptional=False)
    # KeyError is not catch-all: the inner dispatch escapes to the outer
    assert cfg.has_path(risky.idx, h_outer.idx)
    assert cfg.has_path(h_inner.idx, done.idx, exceptional=False)
    assert cfg.has_path(h_outer.idx, done.idx, exceptional=False)
    (inner_disp,) = cfg.nodes_at(3, "except-dispatch")
    (outer_disp,) = cfg.nodes_at(2, "except-dispatch")
    assert any(cfg.has_path(s, outer_disp.idx) for s in inner_disp.succ)
    # except Exception IS catch-all: the outer dispatch cannot escalate
    assert cfg.raise_ not in outer_disp.succ


# ---------------------------------------------------------------------------
# HL009: resource lifecycle (acquire/release pairing on every path)
# ---------------------------------------------------------------------------
ARENA_PREAMBLE = """
class ArenaPool:
    def acquire(self, sig, factory):
        return object()

    def release(self, a):
        pass

"""

ARENA_EXC_LEAK = ARENA_PREAMBLE + """
def handler(pool, sig, factory):
    a = pool.acquire(sig, factory)
    write_args(a)
    pool.release(a)
"""

ARENA_PAIRED = ARENA_PREAMBLE + """
def handler(pool, sig, factory):
    a = pool.acquire(sig, factory)
    try:
        write_args(a)
    finally:
        pool.release(a)
"""


def test_hl009_flags_acquire_without_release_on_exception_path(tmp_path):
    res = lint_fixture(tmp_path, {"src/m.py": ARENA_EXC_LEAK}, "HL009")
    assert len(res.findings) == 1
    f = res.findings[0]
    assert "exception" in f.message
    assert f.detail.startswith("handler:arena:a")


def test_hl009_try_finally_pairing_passes(tmp_path):
    res = lint_fixture(tmp_path, {"src/m.py": ARENA_PAIRED}, "HL009")
    assert res.findings == []


def test_hl009_release_in_except_settles_the_error_path(tmp_path):
    src = ARENA_PREAMBLE + """
def handler(pool, sig, factory):
    a = pool.acquire(sig, factory)
    try:
        write_args(a)
    except Exception:
        pool.release(a)
        raise
    pool.release(a)
"""
    res = lint_fixture(tmp_path, {"src/m.py": src}, "HL009")
    assert res.findings == []


def test_hl009_flags_normal_path_leak(tmp_path):
    src = ARENA_PREAMBLE + """
def handler(pool, sig, factory):
    a = pool.acquire(sig, factory)
    if a is not None:
        pool.release(a)
"""
    res = lint_fixture(tmp_path, {"src/m.py": src}, "HL009")
    assert len(res.findings) == 1
    assert res.findings[0].detail.startswith("handler:arena:a")


def test_hl009_escape_transfers_ownership(tmp_path):
    src = ARENA_PREAMBLE + """
def claim(pool, sig, factory):
    a = pool.acquire(sig, factory)
    return a
"""
    res = lint_fixture(tmp_path, {"src/m.py": src}, "HL009")
    assert res.findings == []


def test_hl009_interprocedural_release_via_helper(tmp_path):
    src = ARENA_PREAMBLE + """
def _put_back(pool, a):
    pool.release(a)

def handler(pool, sig, factory):
    a = pool.acquire(sig, factory)
    try:
        write_args(a)
    finally:
        _put_back(pool, a)
"""
    res = lint_fixture(tmp_path, {"src/m.py": src}, "HL009")
    assert res.findings == []


def test_hl009_manual_lock_acquire_needs_try_finally(tmp_path):
    src = """
def f(self):
    self._lock.acquire()
    work()
    self._lock.release()
"""
    res = lint_fixture(tmp_path, {"src/m.py": src}, "HL009")
    assert len(res.findings) == 1
    assert "lock" in res.findings[0].detail

    good = """
def f(self):
    self._lock.acquire()
    try:
        work()
    finally:
        self._lock.release()
"""
    res = lint_fixture(tmp_path, {"src/m.py": good}, "HL009")
    assert res.findings == []


# ---------------------------------------------------------------------------
# HL010: exception safety under locks (the PR 4 _try_admit bug)
# ---------------------------------------------------------------------------
ADMIT_BUG = """
class Platform:
    def _try_admit(self, fid, rt):
        with self._lock:
            rec = self._recs[fid]
            rec.runtime = rt
            rt.register_function(fid)
            rec.placed = True
"""

ADMIT_FIXED = """
class Platform:
    def _try_admit(self, fid, rt):
        with self._lock:
            rec = self._recs[fid]
            rec.runtime = rt
            try:
                rt.register_function(fid)
            except BaseException:
                rec.runtime = None
                raise
            rec.placed = True
"""


def test_hl010_flags_partial_multi_field_update_under_lock(tmp_path):
    res = lint_fixture(tmp_path, {"src/m.py": ADMIT_BUG}, "HL010")
    assert len(res.findings) == 1
    f = res.findings[0]
    assert "runtime" in f.detail
    assert "_try_admit" in f.detail


def test_hl010_rollback_handler_protects_the_write(tmp_path):
    res = lint_fixture(tmp_path, {"src/m.py": ADMIT_FIXED}, "HL010")
    assert res.findings == []


def test_hl010_constant_resets_do_not_arm(tmp_path):
    src = """
class Platform:
    def evict(self, fid):
        with self._lock:
            rec = self._recs[fid]
            rec.runtime = None
            self._notify(fid)
            rec.placed = False
"""
    res = lint_fixture(tmp_path, {"src/m.py": src}, "HL010")
    assert res.findings == []


# ---------------------------------------------------------------------------
# HL011: sim/live accounting parity (conservation over the mapping layer)
# ---------------------------------------------------------------------------
SIM_ENGINE = """
class SimResult:
    requests = 0
    dropped = 0
"""

PARITY_TARGETS = """
class Adapter:
    def counters(self):
        return {"served": 1, "dropped": 2}
"""


def test_hl011_balanced_mapping_passes(tmp_path):
    rec = """
def finish(adapter):
    c = adapter.counters()
    return SimResult(requests=c["served"], dropped=c["dropped"])
"""
    res = lint_fixture(tmp_path, {"src/engine.py": SIM_ENGINE,
                                  "src/recorder.py": rec,
                                  "src/targets.py": PARITY_TARGETS},
                       "HL011")
    assert res.findings == []


def test_hl011_flags_unfed_simresult_field(tmp_path):
    rec = """
def finish(adapter):
    c = adapter.counters()
    return SimResult(requests=c["served"] + c["dropped"])
"""
    res = lint_fixture(tmp_path, {"src/engine.py": SIM_ENGINE,
                                  "src/recorder.py": rec,
                                  "src/targets.py": PARITY_TARGETS},
                       "HL011")
    assert [f.detail for f in res.findings] == ["unfed:dropped"]


def test_hl011_flags_dead_and_phantom_counters(tmp_path):
    rec = """
def finish(adapter):
    c = adapter.counters()
    return SimResult(requests=c["served"], dropped=c.get("cold", 0))
"""
    targets = """
class Adapter:
    def counters(self):
        return {"served": 1, "evicted": 3}
"""
    res = lint_fixture(tmp_path, {"src/engine.py": SIM_ENGINE,
                                  "src/recorder.py": rec,
                                  "src/targets.py": targets}, "HL011")
    details = sorted(f.detail for f in res.findings)
    assert len(details) == 2
    assert any(d.startswith("dead-counter:evicted:") for d in details)
    assert any(d.startswith("phantom-counter:cold:") for d in details)


# ---------------------------------------------------------------------------
# leaksan: runtime resource-leak sanitizer
# ---------------------------------------------------------------------------
def _leak_pool():
    import jax.numpy as jnp

    from repro.core.arena import ArenaPool
    pool = ArenaPool(ttl_s=60)
    factory = lambda: {"x": jnp.zeros((4,), jnp.float32)}
    return pool, factory


def test_leaksan_balanced_claims_pass_and_restore_patches():
    from repro.core.arena import ArenaPool
    with leaksan.sanitized() as san:
        pool, factory = _leak_pool()
        a = pool.acquire(("x",), factory)
        pool.release(a)
    assert (san.claims, san.releases) == (1, 1)
    # the paired APIs are restored on exit
    assert ArenaPool.acquire.__name__ == "acquire"


def test_leaksan_reports_leaked_claim_with_acquiring_site():
    with pytest.raises(leaksan.ResourceLeakError) as ei:
        with leaksan.sanitized():
            pool, factory = _leak_pool()
            pool.acquire(("x",), factory)
    msg = str(ei.value)
    assert "arena" in msg
    assert "test_hydralint.py" in msg      # the acquiring call site


def test_leaksan_trace_pairing_and_null_trace_exempt():
    # hydralint: disable=HL008 — this file only LOOKS like sim code (the
    # HL003 fixtures above carry sim-module markers); the import exercises
    # leaksan's trace pairing, nothing here simulates
    from repro.core.tracing import Tracer
    with leaksan.sanitized() as san:
        tr = Tracer(1.0)
        tr.start_request("f").finish("ok")
        Tracer(0.0).start_request("g")     # NULL_TRACE: never ledgered
    assert (san.claims, san.releases) == (1, 1)


# ---------------------------------------------------------------------------
# CLI: --format=github annotations, --explain, and the lint-speed gate
# ---------------------------------------------------------------------------
def test_cli_github_format_emits_workflow_annotations(tmp_path):
    bad = tmp_path / "src" / "m.py"
    bad.parent.mkdir(parents=True)
    bad.write_text(textwrap.dedent(BAD_LOCK))
    r = _run_cli(["src", "--root", str(tmp_path), "--select", "HL001",
                  "--format=github"], cwd=REPO_ROOT)
    assert r.returncode == 1
    first = r.stdout.splitlines()[0]
    assert first.startswith("::error file=src/m.py,line=")
    assert "title=HL001" in first


def test_cli_explain_prints_invariant_entry():
    for code in ("HL009", "HL010", "HL011"):
        r = _run_cli(["--explain", code], cwd=REPO_ROOT)
        assert r.returncode == 0, r.stderr
        assert code in r.stdout
        assert "suppress" in r.stdout.lower()


def test_cli_budget_gate(tmp_path):
    good = tmp_path / "src" / "m.py"
    good.parent.mkdir(parents=True)
    good.write_text(textwrap.dedent(GOOD_LOCK))
    budget = tmp_path / "budget.json"

    budget.write_text(json.dumps({"lint": {"hydralint_sweep_s": 300.0}}))
    r = _run_cli(["src", "--root", str(tmp_path), "--select", "HL001",
                  "--budget", str(budget)], cwd=REPO_ROOT)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "budget — ok" in r.stdout

    budget.write_text(json.dumps({"lint": {"hydralint_sweep_s": 1e-9}}))
    r = _run_cli(["src", "--root", str(tmp_path), "--select", "HL001",
                  "--budget", str(budget)], cwd=REPO_ROOT)
    assert r.returncode == 1
    assert "OVER" in r.stdout + r.stderr


# ---------------------------------------------------------------------------
# the real tree is clean — the gate this PR turns on in CI
# ---------------------------------------------------------------------------
def test_real_tree_lints_clean():
    res = run_lint(["src", "tests"], REPO_ROOT)
    assert res.findings == [], "\n".join(f.render() for f in res.findings)
