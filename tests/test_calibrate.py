"""Measured-cost calibration: schema validation, round-trip, and
SimParams override semantics."""
import json
import os

import pytest

from repro.core.calibrate import (CALIBRATABLE_FIELDS, SCHEMA,
                                  apply_calibration, load_calibration,
                                  write_calibration)
from repro.core.sim import SimParams

BUNDLED = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                       "data", "calibration_example.json")


def test_round_trip(tmp_path):
    path = str(tmp_path / "cal.json")
    measured = {"hydra_runtime_cold_s": 0.033, "isolate_cold_s": 0.0007,
                "isolate_warm_s": 2e-5, "snapshot_restore_s": 0.002,
                "hydra_runtime_base": 52.7 * (1 << 20)}
    doc = write_calibration(path, measured, meta={"host": "test"})
    assert doc["schema"] == SCHEMA
    loaded = load_calibration(path)
    params = apply_calibration(SimParams(), loaded)
    assert params.hydra_runtime_cold_s == 0.033
    assert params.isolate_cold_s == 0.0007
    assert params.snapshot_restore_s == 0.002
    # int fields are rounded to whole bytes
    assert params.hydra_runtime_base == int(round(52.7 * (1 << 20)))
    # untouched fields keep the paper defaults
    assert params.fn_register_s == SimParams().fn_register_s


def test_apply_accepts_path_or_dict(tmp_path):
    path = str(tmp_path / "cal.json")
    write_calibration(path, {"vm_boot_s": 0.2})
    assert apply_calibration(SimParams(), path).vm_boot_s == 0.2
    assert apply_calibration(SimParams(),
                             {"vm_boot_s": 0.3}).vm_boot_s == 0.3


def test_unknown_field_is_a_schema_error(tmp_path):
    with pytest.raises(ValueError, match="unknown field"):
        write_calibration(str(tmp_path / "x.json"),
                          {"machine_cap": 123})     # not calibratable
    path = str(tmp_path / "y.json")
    with open(path, "w") as f:
        json.dump({"schema": SCHEMA,
                   "measured": {"not_a_field": 1.0}}, f)
    with pytest.raises(ValueError, match="unknown field"):
        load_calibration(path)


def test_bad_values_and_schema_rejected(tmp_path):
    with pytest.raises(ValueError, match="non-negative"):
        write_calibration(str(tmp_path / "x.json"),
                          {"vm_boot_s": -1.0})
    with pytest.raises(ValueError, match="non-negative"):
        write_calibration(str(tmp_path / "x.json"),
                          {"vm_boot_s": float("nan")})
    with pytest.raises(ValueError, match="non-empty"):
        write_calibration(str(tmp_path / "x.json"), {})
    path = str(tmp_path / "wrong.json")
    with open(path, "w") as f:
        json.dump({"schema": "other/v9", "measured": {}}, f)
    with pytest.raises(ValueError, match="hydra-calibration"):
        load_calibration(path)


def test_bundled_example_is_valid():
    measured = load_calibration(BUNDLED)
    assert set(measured) <= set(CALIBRATABLE_FIELDS)
    params = apply_calibration(SimParams(), BUNDLED)
    assert params.hydra_runtime_cold_s == measured["hydra_runtime_cold_s"]
