"""Gateway subsystem: open-loop wall-clock replay against the real
stack — admission control (bounded queues, token buckets), SLO
timeouts, the platform autoscaler, the cluster balancer (mid-burst
snapshot migration), SimResult-schema recording, the sim-vs-live
validation harness, and the gateway -> calibration -> sim round trip."""
import time

import pytest

from repro.core.calibrate import (CALIBRATABLE_FIELDS, apply_calibration,
                                  calibration_from_replay)
from repro.core.platform import HydraPlatform, PlatformParams
from repro.core.sim import SimParams, simulate
from repro.core.sim.engine import SimResult
from repro.core.traces import Invocation, Trace
from repro.gateway import (Autoscaler, ClusterBalancer, Gateway,
                           GatewayParams, LoadGenerator, Recorder,
                           ReplayConfig, replay_trace, run_validation,
                           sim_params_for_live, wrap_target)
from repro.gateway.replay import build_workload
from repro.gateway.validate import gate, round_trip_check
from tools.hydralint import leaksan, locksan

MB = 1 << 20


def make_trace(n=24, gap_s=0.5, duration_s=0.2, n_fns=4, n_tenants=2,
               mem_mb=80):
    invs = tuple(
        Invocation(t=i * gap_s, fid=i % n_fns, tenant=(i % n_fns) % n_tenants,
                   duration_s=duration_s, mem_bytes=mem_mb * MB)
        for i in range(n))
    return Trace(invocations=invs, source="synthetic")


def small_platform(compress=30.0, pool=1, budget=64 * MB):
    return HydraPlatform(PlatformParams(
        pool_size=pool, runtime_budget_bytes=budget,
        arena_ttl_s=10.0 / compress, n_workers=2))


# ---------------------------------------------------------------------------
def test_replay_emits_simresult_schema_and_full_accounting():
    # locksan: the full replay stack (gateway workers, recorder sampler,
    # platform janitor) runs under the lock-order sanitizer — the platform
    # is built inside the patch so every lock it creates is wrapped.
    # leaksan: every arena/runtime/trace claim made by the replay must be
    # returned by the time the platform finishes shutting down.
    with locksan.sanitized(), leaksan.sanitized():
        trace = make_trace(n=24, gap_s=0.4)
        plat = small_platform(compress=30.0)
        try:
            res, extras = replay_trace(
                trace, plat, ReplayConfig(compress=30.0, n_workers=4))
        finally:
            plat.shutdown()
    assert isinstance(res, SimResult)
    # EXACT summary schema parity with the simulator
    assert set(res.summary()) == set(SimResult(model="x").summary())
    s = res.summary()
    assert s["requests"] + s["dropped"] == len(trace)
    assert s["requests"] > 0
    assert all(l > 0 for l in res.latencies)
    # the pool served the first placement: a claim, never an inline boot
    assert s["pool_claims"] >= 1
    assert s["cold_runtime"] == 0
    assert res.mem_samples and res.mem_samples[-1][1] > 0
    assert extras["submitted"] == len(trace)
    assert extras["drained"]
    # per-request overhead (latency - emulated duration) in wall ms: one
    # sample per served request, and the emulated sleep never undershoots
    ovh = extras["request_overhead_ms"]
    assert ovh["count"] == s["requests"]
    assert ovh["mean"] > 0.0
    assert ovh["p99"] >= 0.0
    # fleet compile + slab counters surface through the adapter
    exe = extras["exe_cache"]
    assert exe["entries"] >= 1
    assert {"compiles", "disk_hits", "cache_hits",
            "xla_cache_enabled"} <= set(exe)
    assert {"reuse", "zeroed"} == set(extras["slab"])


def test_replay_against_cluster_target():
    from repro.core.cluster import ClusterParams, HydraCluster
    trace = make_trace(n=16, gap_s=0.4, n_fns=4, n_tenants=4)
    cluster = HydraCluster(ClusterParams(
        n_nodes=2, node_memory_bytes=256 * MB,
        platform=PlatformParams(pool_size=1, runtime_budget_bytes=64 * MB,
                                arena_ttl_s=10.0 / 30.0)))
    try:
        res, extras = replay_trace(trace, cluster,
                                   ReplayConfig(compress=30.0, n_workers=4))
    finally:
        cluster.shutdown()
    s = res.summary()
    assert res.model == "live-cluster"
    assert s["n_nodes"] == 2
    assert s["requests"] + s["dropped"] == len(trace)
    assert s["requests"] > 0


# ---------------------------------------------------------------------------
def _gateway_fixture(trace, plat, params):
    adapter = wrap_target(plat)
    workload = build_workload(adapter, ReplayConfig(compress=params.compress))
    workload.register_all(trace, adapter)
    recorder = Recorder(adapter, compress=params.compress)
    gw = Gateway(adapter, workload, params, recorder)
    return gw, recorder


def test_bounded_queue_rejects_overflow():
    # 1 worker busy sleeping 0.5s wall per request; depth 2 -> the burst
    # overflows the tenant queue and is rejected at the door
    trace = make_trace(n=8, gap_s=0.0, duration_s=0.5, n_fns=1, n_tenants=1)
    plat = small_platform(compress=1.0)
    gw, recorder = _gateway_fixture(
        trace, plat, GatewayParams(n_workers=1, queue_depth=2, compress=1.0))
    try:
        gw.start()
        accepted = sum(gw.submit(inv) for inv in trace)
        assert accepted < len(trace)
        assert gw.drain(timeout_s=30.0)
    finally:
        gw.stop()
        plat.shutdown()
    extras = recorder.extras()
    assert extras["drops"].get("rejected", 0) >= 1
    res = recorder.finish()
    assert len(res.latencies) + res.dropped == len(trace)


def test_slo_timeout_drops_stale_requests():
    # sub-ms SLO (in trace seconds) with a single busy worker: queued
    # requests expire before they are served
    trace = make_trace(n=6, gap_s=0.0, duration_s=0.4, n_fns=1, n_tenants=1)
    plat = small_platform(compress=1.0)
    gw, recorder = _gateway_fixture(
        trace, plat, GatewayParams(n_workers=1, queue_depth=64,
                                   slo_timeout_s=0.05, compress=1.0))
    try:
        gw.start()
        for inv in trace:
            gw.submit(inv)
        assert gw.drain(timeout_s=30.0)
    finally:
        gw.stop()
        plat.shutdown()
    assert recorder.extras()["drops"].get("slo_timeout", 0) >= 1


def test_token_bucket_throttles_hot_tenant():
    trace = make_trace(n=10, gap_s=0.0, duration_s=0.01, n_fns=1,
                       n_tenants=1)
    plat = small_platform(compress=1.0)
    gw, recorder = _gateway_fixture(
        trace, plat, GatewayParams(n_workers=2, tenant_rate=0.001,
                                   tenant_burst=2.0, compress=1.0))
    try:
        gw.start()
        for inv in trace:
            gw.submit(inv)
        gw.drain(timeout_s=30.0)
    finally:
        gw.stop()
        plat.shutdown()
    drops = recorder.extras()["drops"]
    # burst of 2 admitted, the rest throttled by the per-tenant bucket
    assert drops.get("throttled", 0) >= len(trace) - 3


def test_unknown_function_rejected_at_door():
    plat = small_platform()
    gw, recorder = _gateway_fixture(make_trace(n=4), plat, GatewayParams())
    try:
        stranger = Invocation(t=0.0, fid=999, tenant=0, duration_s=0.1,
                              mem_bytes=MB)
        assert gw.submit(stranger) is False
    finally:
        gw.stop()
        plat.shutdown()
    assert recorder.extras()["drops"].get("unknown") == 1


# ---------------------------------------------------------------------------
def test_autoscaler_grows_on_burst_and_shrinks_when_idle():
    plat = small_platform(pool=1)
    try:
        scaler = Autoscaler(plat, pool_min=1, pool_max=4, cover_s=1.0)
        t = 1000.0
        for i in range(32):            # 100 req/s burst
            scaler.observe(t + i * 0.01)
        target = scaler.tick(t + 0.32)
        assert target == 4             # ceil(rate * cover) clamped to max
        assert plat.params.pool_size == 4
        assert scaler.resizes == 1
        # long idle: the rate estimate collapses, pool shrinks to floor
        target = scaler.tick(t + 500.0)
        assert target == 1
        assert plat.params.pool_size == 1
    finally:
        plat.shutdown()


def test_workload_arenas_capped_to_runtime_budget():
    # 8 GB trace functions against a 16 MB runtime: arenas are capped so
    # registration always admits (no HydraOOMError at the door)
    trace = make_trace(n=4, n_fns=2, mem_mb=8192)
    plat = HydraPlatform(PlatformParams(pool_size=1,
                                        runtime_budget_bytes=16 * MB))
    try:
        adapter = wrap_target(plat)
        workload = build_workload(adapter, ReplayConfig())
        n = workload.register_all(trace, adapter)
        assert n == 2
        for inv in trace[:2]:
            adapter.invoke(workload.name_for(inv), workload.args_for(inv))
    finally:
        plat.shutdown()


def test_loadgen_schedules_open_loop():
    class StubGateway:
        def __init__(self):
            self.walls = []

        def submit(self, inv, sched_wall=None):
            self.walls.append((time.monotonic(), sched_wall))
            return True

    trace = make_trace(n=5, gap_s=1.0)     # arrivals at 0, 1, 2, 3, 4
    stub = StubGateway()
    res = LoadGenerator(trace, stub, compress=20.0).run()
    assert res.submitted == res.accepted == 5
    # open loop: submit times track the compressed schedule (50ms gaps)
    gaps = [b - a for (a, _), (b, _) in zip(stub.walls, stub.walls[1:])]
    assert all(0.03 < g < 0.3 for g in gaps), gaps
    # intended schedule is preserved exactly
    scheds = [s for _, s in stub.walls]
    for i in range(1, 5):
        assert scheds[i] - scheds[0] == pytest.approx(i * 0.05, abs=1e-6)


def test_loadgen_absolute_schedule_under_sustained_lag():
    """Open-loop fidelity regression: when the submit path is slower
    than the compressed inter-arrival gap, the generator must keep
    scheduling against the ABSOLUTE trace timeline (t0 + t_i/compress),
    not against accumulated sleeps — otherwise the drift would re-time
    the tail of the trace and hide it from measured latency."""
    class SlowGateway:
        def __init__(self):
            self.scheds = []

        def submit(self, inv, sched_wall=None):
            time.sleep(0.003)          # 3ms submit >> 1ms arrival gap
            self.scheds.append(sched_wall)
            return True

    n = 40
    trace = make_trace(n=n, gap_s=0.05)     # 1ms wall gaps at compress 50
    stub = SlowGateway()
    t0 = time.monotonic()
    res = LoadGenerator(trace, stub, compress=50.0).run(t0)
    assert res.submitted == n
    # every intended schedule time is the absolute timeline, exactly —
    # lag is never folded into later requests' schedules
    for i, sched in enumerate(stub.scheds):
        assert sched - t0 == pytest.approx(i * 0.05 / 50.0, abs=1e-9)
    # the generator fell ~2ms further behind per request: that lag is
    # REPORTED (late count + max lag), charged to latency downstream
    assert res.late >= n // 2
    assert res.max_lag_s >= 0.020
    # and the worst lag is the cumulative one (the last submit), which
    # only exists if the schedule did not slip with the drift
    assert res.max_lag_s == pytest.approx(
        res.wall_s - 0.003 - (n - 1) * 0.001, abs=0.05)


# ---------------------------------------------------------------------------
def make_cluster(tmp_path, n_nodes=2, node_mb=256, compress=30.0):
    from repro.core.cluster import ClusterParams, HydraCluster
    return HydraCluster(ClusterParams(
        n_nodes=n_nodes, node_memory_bytes=node_mb * MB,
        snapshot_dir=str(tmp_path / "snap"),
        platform=PlatformParams(pool_size=1, runtime_budget_bytes=64 * MB,
                                arena_ttl_s=10.0 / compress)))


def test_cluster_balancer_migrates_mid_burst(tmp_path):
    """A tenant-skewed burst packs one node solid (colocation); the
    balancer must rebalance() mid-replay and the migrations must reach
    the live SimResult as transfers, matching the cluster's own
    accounting — the live analog of the hydra-cluster sim model's
    cross-node snapshot transfers."""
    invs = tuple(Invocation(t=i * 0.15, fid=i % 8, tenant=0,
                            duration_s=0.3, mem_bytes=80 * MB)
                 for i in range(48))
    trace = Trace(invocations=invs, source="synthetic")
    cluster = make_cluster(tmp_path)
    cfg = ReplayConfig(compress=30.0, n_workers=4,
                       balance_interval_s=0.05, balance_imbalance=0.01,
                       balance_min_queue=1)
    try:
        res, extras = replay_trace(trace, cluster, cfg)
        placement = cluster.placement()
    finally:
        cluster.shutdown()
    b = extras["balancer"]
    assert b["armed"]
    assert b["rebalances"] >= 1 and b["moves"] >= 1
    assert res.transfers >= 1
    # live SimResult transfer accounting == the cluster's own counters
    assert res.transfers == b["migrations"]
    assert b["transfer_bytes"] > 0 and b["transfer_s"] > 0
    # the burst really was rebalanced: both nodes host functions now
    assert len(set(placement.values())) == 2
    # mid-burst migration must not lose requests: every invocation is
    # served (mid-migration races are requeued, not errored)
    assert len(res.latencies) + res.dropped == len(trace)
    assert not extras["errors"]
    assert res.n_nodes == 2


def test_cluster_balancer_disarmed_without_snapshots():
    """No snapshot_dir -> migration is impossible; the balancer must
    stay disarmed instead of erroring every tick."""
    from repro.core.cluster import ClusterParams, HydraCluster
    cluster = HydraCluster(ClusterParams(
        n_nodes=2, node_memory_bytes=64 * MB,
        platform=PlatformParams(pool_size=1,
                                runtime_budget_bytes=32 * MB)))
    try:
        balancer = ClusterBalancer(cluster, None, imbalance=0.0)
        assert not balancer.armed
        assert balancer.tick() == 0
        assert balancer.errors == 0
    finally:
        cluster.shutdown()


def test_recorder_reports_real_node_count(tmp_path):
    """recorder.finish() must default to the adapter's REAL machine
    count: a 3-node cluster replay stamped n_nodes=1 would misread as
    3x the density of the sim's fleet-wide accounting."""
    cluster = make_cluster(tmp_path, n_nodes=3, node_mb=64)
    try:
        adapter = wrap_target(cluster)
        assert adapter.n_nodes == 3
        assert len(adapter.node_mem()) == 3
        rec = Recorder(adapter, compress=30.0)
        assert rec.finish().n_nodes == 3
        assert rec.finish(n_nodes=1).n_nodes == 1   # explicit override
    finally:
        cluster.shutdown()
    plat = small_platform()
    try:
        rec = Recorder(wrap_target(plat), compress=30.0)
        assert rec.finish().n_nodes == 1
    finally:
        plat.shutdown()


# ---------------------------------------------------------------------------
def test_latency_gates_scale_with_compression():
    # |live - sim| <= atol_wall * compress + rtol * sim, evaluated via
    # the shared gate() helper validate.py enforces with
    g = gate(10.0, 2.0, atol=0.25 * 60, rtol=1.0)
    assert g["passed"] and g["limit"] == pytest.approx(17.0)
    g = gate(40.0, 2.0, atol=0.25 * 60, rtol=1.0)
    assert not g["passed"]
    # the same wall-second divergence passes at higher compression
    # (startup is compress-amplified in trace time, and so is the atol)
    assert gate(40.0, 2.0, atol=0.25 * 240, rtol=1.0)["passed"]


def test_round_trip_check_requires_no_regression():
    live = {"cold_runtime": 10, "p99_s": 8.0}
    sim = {"cold_runtime": 2, "p99_s": 2.0}
    better = {"cold_runtime": 6, "p99_s": 5.0}
    worse = {"cold_runtime": 30, "p99_s": 2.0}
    rt = round_trip_check(live, sim, better)
    assert rt["passed"] and rt["p99_s"]["cal_delta"] == pytest.approx(3.0)
    rt = round_trip_check(live, sim, worse)
    assert not rt["passed"] and not rt["cold_runtime"]["passed"]
    # equal closeness is acceptance ("at least as close"), not failure
    assert round_trip_check(live, sim, dict(sim))["passed"]


def test_calibration_from_replay_scales_wall_costs():
    res = SimResult(model="live-platform", latencies=[0.1] * 4)
    extras = {"probe": {
        "compress": 120.0,
        "wall_costs": {
            "runtime_boot_s": {"count": 3, "sum": 0.06, "mean": 0.02},
            "pool_claim_s": {"count": 5, "sum": 5e-4, "mean": 1e-4},
            "register_s": {"count": 8, "sum": 0.008, "mean": 0.001},
            "arena.alloc_s": {"count": 9, "sum": 0.009, "mean": 0.001},
        },
        "rss": {"per_runtime_bytes": 48 * MB},
    }}
    doc = calibration_from_replay(res, extras)
    assert doc["schema"] == "hydra-calibration/v1"
    m = doc["measured"]
    assert set(m) <= set(CALIBRATABLE_FIELDS)
    # wall costs are trace-time scaled by compress...
    assert m["hydra_runtime_cold_s"] == pytest.approx(0.02 * 120)
    assert m["pool_refill_s"] == pytest.approx(0.02 * 120)
    assert m["pool_claim_s"] == pytest.approx(1e-4 * 120)
    assert m["fn_register_s"] == pytest.approx(0.001 * 120)
    assert m["isolate_cold_s"] == pytest.approx(0.001 * 120)
    # ...the measured boot covers the whole cold path (no microVM under it)
    assert m["vm_boot_s"] == 0.0
    # memory is reported in meta but NOT applied unless asked
    assert "hydra_runtime_base" not in m
    assert doc["meta"]["rss_per_runtime_bytes"] == 48 * MB
    m2 = calibration_from_replay(res, extras, include_memory=True)
    assert m2["measured"]["hydra_runtime_base"] == 48 * MB
    # the overlay round-trips through apply_calibration
    params = apply_calibration(SimParams(), m)
    assert params.hydra_runtime_cold_s == pytest.approx(2.4)
    with pytest.raises(ValueError):
        calibration_from_replay(res, {})     # no probe payload
    with pytest.raises(ValueError):
        calibration_from_replay(res, {"probe": {"compress": 120.0,
                                                "wall_costs": {}}})


def test_round_trip_reproduces_live_cold_starts():
    """The acceptance loop end-to-end on a seeded trace: replay live,
    derive the calibration from that very run, re-simulate with it —
    the calibrated sim must land within the validate gate of the live
    cold-start count and be at least as close as the uncalibrated sim
    on cold starts AND p99."""
    trace = Trace.synthetic(n_functions=8, n_tenants=4, duration_s=40.0,
                            mean_rps=1.5, seed=3)
    report = run_validation(trace, compress=40.0, pool_size=2,
                            n_workers=4, round_trip=True)
    assert report["ok"], report["failures"]
    assert report["round_trip"]["passed"]
    cal = report["calibration"]
    assert set(cal["measured"]) <= set(CALIBRATABLE_FIELDS)
    # feed the derived overlay back through apply_calibration + the sim
    # ourselves: the replayed cold-start count must be reproduced within
    # the validate gate (and match the report's calibrated sim)
    params = apply_calibration(
        sim_params_for_live(trace, pool_size=2,
                            live_runtime_budget=32 * MB,
                            mem_scale=1.0 / 64),
        cal["measured"])
    sim = simulate(trace, "hydra-pool", params)
    g = gate(report["live"]["cold_runtime"], sim.cold_runtime_starts,
             atol=8, rtol=1.0)
    assert g["passed"], g
    assert sim.cold_runtime_starts \
        == report["calibrated_sim"]["cold_runtime"]


# ---------------------------------------------------------------------------
def test_validation_report_on_synthetic_trace():
    trace = Trace.synthetic(n_functions=8, n_tenants=4, duration_s=40.0,
                            mean_rps=1.5, seed=3)
    report = run_validation(trace, compress=40.0, pool_size=2,
                            n_workers=4)
    assert set(report) >= {"live", "sim", "deltas", "tolerance",
                           "failures", "ok"}
    tol = report["tolerance"]
    assert tol["passed"], report["failures"]
    assert report["live"]["requests"] > 0
    assert report["sim"]["requests"] == len(trace)
    for k in ("cold_runtime", "p99_s", "requests"):
        assert k in report["deltas"]
    # live and sim agree that the pre-warmed pool absorbed the load
    assert abs(tol["cold_live"] - tol["cold_sim"]) <= tol["limit"]


# ---------------------------------------------------------------------------
# Tenant-sharded replay (ShardedLoadGenerator / shard_trace)
# ---------------------------------------------------------------------------
def test_shard_trace_partitions_by_tenant():
    from repro.gateway import shard_trace
    trace = make_trace(n=40, gap_s=0.25, n_fns=8, n_tenants=8)
    parts = [shard_trace(trace, 3, i) for i in range(3)]
    for i, part in enumerate(parts):
        assert all(inv.tenant % 3 == i for inv in part)
    merged = sorted((inv for p in parts for inv in p),
                    key=lambda i: (i.t, i.fid))
    assert merged == list(trace)
    # degenerate single-shard request returns the trace unchanged
    assert shard_trace(trace, 1, 0) is trace


def test_sharded_loadgen_conserves_and_keeps_tenant_fifo():
    """Acceptance: sharded replay conserves every invocation and keeps
    per-tenant arrival order (each tenant lives wholly in one shard)."""
    import threading

    from repro.gateway import ShardedLoadGenerator

    class CountingGateway:
        def __init__(self):
            self.lock = threading.Lock()
            self.seen = []

        def submit(self, inv, sched_wall=None):
            with self.lock:
                self.seen.append((inv.tenant, inv.t))
            return True

    trace = make_trace(n=40, gap_s=0.25, n_fns=8, n_tenants=8)
    stub = CountingGateway()
    res = ShardedLoadGenerator(trace, stub, compress=100.0,
                               n_shards=4).run()
    assert res.submitted == res.accepted == len(trace) == len(stub.seen)
    by_tenant = {}
    for tenant, t in stub.seen:
        by_tenant.setdefault(tenant, []).append(t)
    assert len(by_tenant) == 8
    for tenant, ts in by_tenant.items():
        assert ts == sorted(ts), f"tenant {tenant} out of order"


def test_sharded_replay_matches_single_worker_counters():
    """A real sharded replay of the bundled Azure sample serves the same
    workload as the unsharded run: full conservation, equal request
    counts within the admission-control tolerance."""
    import os
    SAMPLE = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                          "data", "azure_sample.csv")
    trace = Trace.from_azure(SAMPLE, target_rps=2.0, max_minutes=5)
    results = {}
    for shards in (1, 3):
        plat = small_platform(compress=120.0, pool=2, budget=256 * MB)
        try:
            res, extras = replay_trace(
                trace, plat,
                ReplayConfig(compress=120.0, n_workers=8, shards=shards))
        finally:
            plat.shutdown()
        s = res.summary()
        # conservation: every scheduled invocation is served or rejected
        assert extras["submitted"] == len(trace)
        assert s["requests"] + s["dropped"] == len(trace)
        results[shards] = s
    # both runs served everything (tiny load, no admission pressure), so
    # the counters agree exactly
    assert results[1]["requests"] == results[3]["requests"]
    assert results[1]["dropped"] == results[3]["dropped"] == 0
