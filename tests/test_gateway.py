"""Gateway subsystem: open-loop wall-clock replay against the real
stack — admission control (bounded queues, token buckets), SLO
timeouts, the platform autoscaler, SimResult-schema recording, and the
sim-vs-live validation harness."""
import time

import pytest

from repro.core.platform import HydraPlatform, PlatformParams
from repro.core.sim.engine import SimResult
from repro.core.traces import Invocation, Trace
from repro.gateway import (Autoscaler, Gateway, GatewayParams, LoadGenerator,
                           Recorder, ReplayConfig, replay_trace,
                           run_validation, wrap_target)
from repro.gateway.replay import build_workload

MB = 1 << 20


def make_trace(n=24, gap_s=0.5, duration_s=0.2, n_fns=4, n_tenants=2,
               mem_mb=80):
    invs = tuple(
        Invocation(t=i * gap_s, fid=i % n_fns, tenant=(i % n_fns) % n_tenants,
                   duration_s=duration_s, mem_bytes=mem_mb * MB)
        for i in range(n))
    return Trace(invocations=invs, source="synthetic")


def small_platform(compress=30.0, pool=1, budget=64 * MB):
    return HydraPlatform(PlatformParams(
        pool_size=pool, runtime_budget_bytes=budget,
        arena_ttl_s=10.0 / compress, n_workers=2))


# ---------------------------------------------------------------------------
def test_replay_emits_simresult_schema_and_full_accounting():
    trace = make_trace(n=24, gap_s=0.4)
    plat = small_platform(compress=30.0)
    try:
        res, extras = replay_trace(trace, plat,
                                   ReplayConfig(compress=30.0, n_workers=4))
    finally:
        plat.shutdown()
    assert isinstance(res, SimResult)
    # EXACT summary schema parity with the simulator
    assert set(res.summary()) == set(SimResult(model="x").summary())
    s = res.summary()
    assert s["requests"] + s["dropped"] == len(trace)
    assert s["requests"] > 0
    assert all(l > 0 for l in res.latencies)
    # the pool served the first placement: a claim, never an inline boot
    assert s["pool_claims"] >= 1
    assert s["cold_runtime"] == 0
    assert res.mem_samples and res.mem_samples[-1][1] > 0
    assert extras["submitted"] == len(trace)
    assert extras["drained"]


def test_replay_against_cluster_target():
    from repro.core.cluster import ClusterParams, HydraCluster
    trace = make_trace(n=16, gap_s=0.4, n_fns=4, n_tenants=4)
    cluster = HydraCluster(ClusterParams(
        n_nodes=2, node_memory_bytes=256 * MB,
        platform=PlatformParams(pool_size=1, runtime_budget_bytes=64 * MB,
                                arena_ttl_s=10.0 / 30.0)))
    try:
        res, extras = replay_trace(trace, cluster,
                                   ReplayConfig(compress=30.0, n_workers=4))
    finally:
        cluster.shutdown()
    s = res.summary()
    assert res.model == "live-cluster"
    assert s["n_nodes"] == 2
    assert s["requests"] + s["dropped"] == len(trace)
    assert s["requests"] > 0


# ---------------------------------------------------------------------------
def _gateway_fixture(trace, plat, params):
    adapter = wrap_target(plat)
    workload = build_workload(adapter, ReplayConfig(compress=params.compress))
    workload.register_all(trace, adapter)
    recorder = Recorder(adapter, compress=params.compress)
    gw = Gateway(adapter, workload, params, recorder)
    return gw, recorder


def test_bounded_queue_rejects_overflow():
    # 1 worker busy sleeping 0.5s wall per request; depth 2 -> the burst
    # overflows the tenant queue and is rejected at the door
    trace = make_trace(n=8, gap_s=0.0, duration_s=0.5, n_fns=1, n_tenants=1)
    plat = small_platform(compress=1.0)
    gw, recorder = _gateway_fixture(
        trace, plat, GatewayParams(n_workers=1, queue_depth=2, compress=1.0))
    try:
        gw.start()
        accepted = sum(gw.submit(inv) for inv in trace)
        assert accepted < len(trace)
        assert gw.drain(timeout_s=30.0)
    finally:
        gw.stop()
        plat.shutdown()
    extras = recorder.extras()
    assert extras["drops"].get("rejected", 0) >= 1
    res = recorder.finish()
    assert len(res.latencies) + res.dropped == len(trace)


def test_slo_timeout_drops_stale_requests():
    # sub-ms SLO (in trace seconds) with a single busy worker: queued
    # requests expire before they are served
    trace = make_trace(n=6, gap_s=0.0, duration_s=0.4, n_fns=1, n_tenants=1)
    plat = small_platform(compress=1.0)
    gw, recorder = _gateway_fixture(
        trace, plat, GatewayParams(n_workers=1, queue_depth=64,
                                   slo_timeout_s=0.05, compress=1.0))
    try:
        gw.start()
        for inv in trace:
            gw.submit(inv)
        assert gw.drain(timeout_s=30.0)
    finally:
        gw.stop()
        plat.shutdown()
    assert recorder.extras()["drops"].get("slo_timeout", 0) >= 1


def test_token_bucket_throttles_hot_tenant():
    trace = make_trace(n=10, gap_s=0.0, duration_s=0.01, n_fns=1,
                       n_tenants=1)
    plat = small_platform(compress=1.0)
    gw, recorder = _gateway_fixture(
        trace, plat, GatewayParams(n_workers=2, tenant_rate=0.001,
                                   tenant_burst=2.0, compress=1.0))
    try:
        gw.start()
        for inv in trace:
            gw.submit(inv)
        gw.drain(timeout_s=30.0)
    finally:
        gw.stop()
        plat.shutdown()
    drops = recorder.extras()["drops"]
    # burst of 2 admitted, the rest throttled by the per-tenant bucket
    assert drops.get("throttled", 0) >= len(trace) - 3


def test_unknown_function_rejected_at_door():
    plat = small_platform()
    gw, recorder = _gateway_fixture(make_trace(n=4), plat, GatewayParams())
    try:
        stranger = Invocation(t=0.0, fid=999, tenant=0, duration_s=0.1,
                              mem_bytes=MB)
        assert gw.submit(stranger) is False
    finally:
        gw.stop()
        plat.shutdown()
    assert recorder.extras()["drops"].get("unknown") == 1


# ---------------------------------------------------------------------------
def test_autoscaler_grows_on_burst_and_shrinks_when_idle():
    plat = small_platform(pool=1)
    try:
        scaler = Autoscaler(plat, pool_min=1, pool_max=4, cover_s=1.0)
        t = 1000.0
        for i in range(32):            # 100 req/s burst
            scaler.observe(t + i * 0.01)
        target = scaler.tick(t + 0.32)
        assert target == 4             # ceil(rate * cover) clamped to max
        assert plat.params.pool_size == 4
        assert scaler.resizes == 1
        # long idle: the rate estimate collapses, pool shrinks to floor
        target = scaler.tick(t + 500.0)
        assert target == 1
        assert plat.params.pool_size == 1
    finally:
        plat.shutdown()


def test_workload_arenas_capped_to_runtime_budget():
    # 8 GB trace functions against a 16 MB runtime: arenas are capped so
    # registration always admits (no HydraOOMError at the door)
    trace = make_trace(n=4, n_fns=2, mem_mb=8192)
    plat = HydraPlatform(PlatformParams(pool_size=1,
                                        runtime_budget_bytes=16 * MB))
    try:
        adapter = wrap_target(plat)
        workload = build_workload(adapter, ReplayConfig())
        n = workload.register_all(trace, adapter)
        assert n == 2
        for inv in trace[:2]:
            adapter.invoke(workload.name_for(inv), workload.args_for(inv))
    finally:
        plat.shutdown()


def test_loadgen_schedules_open_loop():
    class StubGateway:
        def __init__(self):
            self.walls = []

        def submit(self, inv, sched_wall=None):
            self.walls.append((time.monotonic(), sched_wall))
            return True

    trace = make_trace(n=5, gap_s=1.0)     # arrivals at 0, 1, 2, 3, 4
    stub = StubGateway()
    res = LoadGenerator(trace, stub, compress=20.0).run()
    assert res.submitted == res.accepted == 5
    # open loop: submit times track the compressed schedule (50ms gaps)
    gaps = [b - a for (a, _), (b, _) in zip(stub.walls, stub.walls[1:])]
    assert all(0.03 < g < 0.3 for g in gaps), gaps
    # intended schedule is preserved exactly
    scheds = [s for _, s in stub.walls]
    for i in range(1, 5):
        assert scheds[i] - scheds[0] == pytest.approx(i * 0.05, abs=1e-6)


# ---------------------------------------------------------------------------
def test_validation_report_on_synthetic_trace():
    trace = Trace.synthetic(n_functions=8, n_tenants=4, duration_s=40.0,
                            mean_rps=1.5, seed=3)
    report = run_validation(trace, compress=40.0, pool_size=2,
                            n_workers=4)
    assert set(report) >= {"live", "sim", "deltas", "tolerance",
                           "failures", "ok"}
    tol = report["tolerance"]
    assert tol["passed"], report["failures"]
    assert report["live"]["requests"] > 0
    assert report["sim"]["requests"] == len(trace)
    for k in ("cold_runtime", "p99_s", "requests"):
        assert k in report["deltas"]
    # live and sim agree that the pre-warmed pool absorbed the load
    assert abs(tol["cold_live"] - tol["cold_sim"]) <= tol["limit"]
