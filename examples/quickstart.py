"""Quickstart: one Hydra runtime, many functions, many languages-worth of
architectures.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "benchmarks")
sys.path.insert(0, ".")

import jax
import jax.numpy as jnp

from benchmarks.functions import catalog, example_args
from repro.configs import get_config
from repro.core import HydraRuntime, LMSpec
from repro.models.programs import ModelProgram


def main():
    # ONE runtime instance hosts every function (the paper's density story)
    rt = HydraRuntime(memory_budget_bytes=4 << 30)

    # 1. register a couple of classic serverless functions
    specs = catalog()
    rt.register_function("tenantA/hash", specs["jv/filehashing"], tenant="A")
    rt.register_function("tenantB/thumb", specs["py/thumbnail"], tenant="B")

    out = rt.invoke("tenantA/hash", example_args(specs["jv/filehashing"]))
    print("filehashing ->", {k: v.shape if hasattr(v, 'shape') else v
                             for k, v in out.items()})
    out = rt.invoke("tenantB/thumb", example_args(specs["py/thumbnail"]))
    print("thumbnail   ->", out["thumb"].shape)

    # 2. register an LM serving function (an assigned architecture)
    cfg = get_config("qwen2.5-3b").reduced()
    prog = ModelProgram(cfg)
    params = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x,
        prog.init(jax.random.PRNGKey(0)))
    rt.register_function("tenantA/lm",
                         LMSpec(cfg=cfg, params=params, max_seq=64, slots=1),
                         tenant="A")
    toks = rt.generate("tenantA/lm", list(range(12)), max_new_tokens=8)
    print("lm generate ->", toks)

    # 3. density accounting: cold vs warm, shared executables, arena pool
    print("\nruntime stats:")
    s = rt.stats()
    print("  functions:", s["functions"])
    print("  exe cache:", s["exe_cache"])
    print("  arenas:   ", s["arena"])
    print("  budget:    %.1f / %.1f MB" % (s["budget_used"] / 2**20,
                                           rt.budget.capacity / 2**20))
    rt.shutdown()


if __name__ == "__main__":
    main()
