"""End-to-end training driver: train a ~100M-param qwen-family model for a
few hundred steps with checkpointing + an injected node failure at step 120
(restore + resume), demonstrating the fault-tolerance path.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys
import tempfile

sys.path.insert(0, ".")

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300,
                    help="training steps to run (default: 300)")
    ap.add_argument("--arch", default="qwen2.5-3b",
                    help="model architecture preset (default: qwen2.5-3b)")
    args = ap.parse_args()
    ckpt = tempfile.mkdtemp(prefix="hydra_train_ck_")
    # reduced qwen2.5 config (~2M params on CPU); scale dims up on real HW
    train_main(["--arch", args.arch, "--reduced",
                "--steps", str(args.steps),
                "--batch", "8", "--seq", "128",
                "--n-micro", "2", "--remat",
                "--ckpt-dir", ckpt, "--ckpt-every", "25",
                "--fail-at", str(min(120, args.steps // 2 + 10))])
    print(f"checkpoints in {ckpt}")
