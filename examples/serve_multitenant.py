"""End-to-end driver: multi-tenant, multi-architecture LM serving with
continuous batching through one Hydra runtime.

  PYTHONPATH=src python examples/serve_multitenant.py
"""
import sys

sys.path.insert(0, ".")

from repro.launch.serve import main

if __name__ == "__main__":
    main(["--archs", "qwen2.5-3b,mamba2-780m", "--tenants", "4",
          "--requests", "24", "--slots", "4", "--max-new", "12"])
