"""End-to-end driver: multi-tenant, multi-architecture LM serving through
the HydraPlatform — a pre-warmed runtime pool with colocation-aware
placement — with continuous batching per function.

  PYTHONPATH=src python examples/serve_multitenant.py
"""
import sys
import tempfile

sys.path.insert(0, ".")

from repro.launch.serve import main

if __name__ == "__main__":
    with tempfile.TemporaryDirectory() as snap_dir:
        main(["--archs", "qwen2.5-3b,mamba2-780m", "--tenants", "4",
              "--requests", "24", "--slots", "4", "--max-new", "12",
              "--pool", "2", "--snapshot-dir", snap_dir])
