"""End-to-end driver: multi-tenant, multi-architecture LM serving through
the Hydra stack — first a single-node ``HydraPlatform`` (pre-warmed
runtime pool, colocation-aware placement), then a two-node
``HydraCluster`` (cross-node placement + adaptive pools) — with
continuous batching per function.

  PYTHONPATH=src python examples/serve_multitenant.py
"""
import sys
import tempfile

sys.path.insert(0, ".")

from repro.launch.serve import main

if __name__ == "__main__":
    with tempfile.TemporaryDirectory() as snap_dir:
        print("=== single-node HydraPlatform ===")
        main(["--archs", "qwen2.5-3b,mamba2-780m", "--tenants", "4",
              "--requests", "24", "--slots", "4", "--max-new", "12",
              "--pool", "2", "--snapshot-dir", snap_dir])
    with tempfile.TemporaryDirectory() as snap_dir:
        print("=== two-node HydraCluster ===")
        main(["--archs", "qwen2.5-3b,mamba2-780m", "--tenants", "4",
              "--requests", "24", "--slots", "4", "--max-new", "12",
              "--nodes", "2", "--pool", "1", "--snapshot-dir", snap_dir])
