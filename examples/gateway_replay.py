"""Live gateway replay: the real Hydra stack under trace traffic, on the
wall clock — and the same trace through the simulator, side by side.

The discrete-event simulator (``examples/trace_replay.py``) *projects*
how the platform behaves under the Azure workload; this example
*measures* it: every invocation in the (thinned) trace becomes a real
request through ``repro.gateway`` — per-tenant bounded queues, a real
``HydraPlatform`` with a pre-warmed pool, real placement, real arena
allocation, real compiled executables — replayed open-loop at a
wall-clock compression factor. The run finishes with the live-vs-sim
delta table from ``repro.gateway.validate``, run in **round-trip**
mode: the replay's own CalibrationProbe measurements are folded back
into ``SimParams`` and the calibrated simulator must track the live run
at least as tightly as the paper-constant one — the gateway ->
calibration -> sim loop, closed on one trace.

  PYTHONPATH=src python examples/gateway_replay.py [azure_trace.csv]
"""
import os
import sys

sys.path.insert(0, ".")
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                ".."))

from repro.gateway import format_report, load_trace, run_validation

COMPRESS = 120.0          # trace seconds per wall second
SAMPLE = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                      "benchmarks", "data", "azure_sample.csv")


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else SAMPLE
    if not os.path.exists(path):
        sys.exit(f"trace file not found: {path}")
    # thin to CI-friendly volume; the arrival SHAPE (bursts, idle gaps)
    # is preserved by the seeded-binomial thinning in core/traces.py
    trace = load_trace(path, target_rps=2.0, max_minutes=10)
    d = trace.describe()
    print(f"trace: {d['invocations']} invocations, {d['functions']} fns, "
          f"{d['tenants']} tenants over {d['duration_s']:.0f}s "
          f"(~{d['duration_s'] / COMPRESS:.1f}s wall at {COMPRESS:g}x)\n")

    report = run_validation(trace, compress=COMPRESS, pool_size=4,
                            round_trip=True)
    live = report["live"]
    print(f"live gateway: {live['requests']} served, "
          f"{live['cold_runtime']} cold starts, "
          f"{live['pool_claims']} pool claims, "
          f"p50={live['p50_s']:.2f}s p99={live['p99_s']:.2f}s "
          f"(trace time; startup is compress-amplified)\n")
    print(format_report(report))
    calibration = report.get("calibration")
    if calibration:
        measured = calibration["measured"]
        print(f"\nderived calibration ({len(measured)} fields): "
              + ", ".join(sorted(measured)))
    if not report["ok"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
