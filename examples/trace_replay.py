"""Reproduce the paper's Azure-trace experiment (Figures 9/10):
memory-over-time and latency percentiles for OpenWhisk / Photons / Hydra
runtime models on a synthetic Shahrad-calibrated trace, plus the
multi-node cluster layer vs a statically partitioned fleet.

  PYTHONPATH=src python examples/trace_replay.py
"""
import sys

sys.path.insert(0, ".")

import numpy as np

from repro.core.tracesim import (GB, MB, SimParams, gen_trace, simulate,
                                 simulate_partitioned)


def sparkline(samples, width=60):
    vals = [m for _, m in samples]
    if not vals:
        return ""
    step = max(1, len(vals) // width)
    vals = vals[::step][:width]
    top = max(vals) or 1
    blocks = " ▁▂▃▄▅▆▇█"
    return "".join(blocks[int(v / top * (len(blocks) - 1))] for v in vals)


def main():
    trace = gen_trace()
    params = SimParams()
    n_fns = len({i.fid for i in trace})
    n_tenants = len({i.tenant for i in trace})
    print(f"trace: {len(trace)} invocations over {trace[-1].t:.0f}s, "
          f"{n_fns} fns, {n_tenants} tenants (default Azure-calibrated)\n")
    results = {}
    for model in ("openwhisk", "photons", "hydra", "hydra-pool"):
        r = simulate(trace, model, params)
        results[model] = r
        s = r.summary()
        print(f"== {model}")
        print(f"   mem  {sparkline(r.mem_samples)}")
        print(f"   mean_mem={s['mean_mem_mb']:.0f}MB "
              f"peak={s['peak_mem_mb']:.0f}MB "
              f"runtimes={s['mean_runtimes']:.1f} "
              f"cold_rt={s['cold_runtime']}")
        print(f"   p50={s['p50_s']:.3f}s p99={s['p99_s']:.3f}s "
              f"platform_overhead_p99={s['overhead_p99_ms']:.1f}ms\n")
    ow = results["openwhisk"].summary()
    hy = results["hydra"].summary()
    hp = results["hydra-pool"].summary()
    print(f"hydra vs openwhisk: memory -"
          f"{100*(1-hy['mean_mem_mb']/ow['mean_mem_mb']):.0f}% "
          f"(paper: -83%), platform-overhead p99 -"
          f"{100*(1-hy['overhead_p99_ms']/ow['overhead_p99_ms']):.0f}% "
          f"(paper: e2e p99 -68%)")
    print(f"platform pool vs hydra: cold starts {hp['cold_runtime']} vs "
          f"{hy['cold_runtime']}, p99 -"
          f"{1e3*(hy['p99_s']-hp['p99_s']):.1f}ms, memory -"
          f"{100*(1-hp['mean_mem_mb']/hy['mean_mem_mb']):.0f}%")

    # cluster layer under fleet pressure (budgets scaled with the trace —
    # see docs/benchmarks.md): 4-node cluster vs 4 independent
    # statically-partitioned hydra-pool nodes at equal aggregate memory
    fp = SimParams(n_nodes=4, runtime_cap=192 * MB, machine_cap=3 * GB)
    cl = simulate(trace, "hydra-cluster", fp)
    st = simulate_partitioned(trace, 4, fp)
    print(f"\n== hydra-cluster (4 nodes, 3 GB fleet)")
    print(f"   mem  {sparkline(cl.mem_samples)}")
    print(f"cluster vs static partition: cold starts "
          f"{cl.cold_runtime_starts} vs {st.cold_runtime_starts}, "
          f"p99 {cl.p(99):.3f}s vs {st.p(99):.3f}s, ops/GB-sec "
          f"{cl.ops_per_gb_s():.2f} vs {st.ops_per_gb_s():.2f}, "
          f"snapshot transfers {cl.transfers}")


if __name__ == "__main__":
    main()
