"""Reproduce the paper's Azure-trace experiment (Figures 9/10):
memory-over-time and latency percentiles for OpenWhisk / Photons / Hydra
runtime models on a synthetic Shahrad-calibrated trace, plus the
multi-node cluster layer vs a statically partitioned fleet, plus a
replay of a real Azure Functions 2019-format trace (the bundled
``benchmarks/data/azure_sample.csv`` by default).

  PYTHONPATH=src python examples/trace_replay.py [azure_trace.csv]
"""
import os
import sys

sys.path.insert(0, ".")
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                ".."))

import numpy as np

from repro.core.tracesim import (GB, MB, SimParams, gen_trace, simulate,
                                 simulate_partitioned)


def sparkline(samples, width=60):
    vals = [m for _, m in samples]
    if not vals:
        return ""
    step = max(1, len(vals) // width)
    vals = vals[::step][:width]
    top = max(vals) or 1
    blocks = " ▁▂▃▄▅▆▇█"
    return "".join(blocks[int(v / top * (len(blocks) - 1))] for v in vals)


def main():
    trace = gen_trace()
    params = SimParams()
    n_fns = len({i.fid for i in trace})
    n_tenants = len({i.tenant for i in trace})
    print(f"trace: {len(trace)} invocations over {trace[-1].t:.0f}s, "
          f"{n_fns} fns, {n_tenants} tenants (default Azure-calibrated)\n")
    results = {}
    for model in ("openwhisk", "photons", "hydra", "hydra-pool"):
        r = simulate(trace, model, params)
        results[model] = r
        s = r.summary()
        print(f"== {model}")
        print(f"   mem  {sparkline(r.mem_samples)}")
        print(f"   mean_mem={s['mean_mem_mb']:.0f}MB "
              f"peak={s['peak_mem_mb']:.0f}MB "
              f"runtimes={s['mean_runtimes']:.1f} "
              f"cold_rt={s['cold_runtime']}")
        print(f"   p50={s['p50_s']:.3f}s p99={s['p99_s']:.3f}s "
              f"platform_overhead_p99={s['overhead_p99_ms']:.1f}ms\n")
    ow = results["openwhisk"].summary()
    hy = results["hydra"].summary()
    hp = results["hydra-pool"].summary()
    print(f"hydra vs openwhisk: memory -"
          f"{100*(1-hy['mean_mem_mb']/ow['mean_mem_mb']):.0f}% "
          f"(paper: -83%), platform-overhead p99 -"
          f"{100*(1-hy['overhead_p99_ms']/ow['overhead_p99_ms']):.0f}% "
          f"(paper: e2e p99 -68%)")
    print(f"platform pool vs hydra: cold starts {hp['cold_runtime']} vs "
          f"{hy['cold_runtime']}, p99 -"
          f"{1e3*(hy['p99_s']-hp['p99_s']):.1f}ms, memory -"
          f"{100*(1-hp['mean_mem_mb']/hy['mean_mem_mb']):.0f}%")

    # cluster layer under fleet pressure (budgets scaled with the trace —
    # see docs/benchmarks.md): 4-node cluster vs 4 independent
    # statically-partitioned hydra-pool nodes at equal aggregate memory
    fp = SimParams(n_nodes=4, runtime_cap=192 * MB, machine_cap=3 * GB)
    cl = simulate(trace, "hydra-cluster", fp)
    st = simulate_partitioned(trace, 4, fp)
    print(f"\n== hydra-cluster (4 nodes, 3 GB fleet)")
    print(f"   mem  {sparkline(cl.mem_samples)}")
    print(f"cluster vs static partition: cold starts "
          f"{cl.cold_runtime_starts} vs {st.cold_runtime_starts}, "
          f"p99 {cl.p(99):.3f}s vs {st.p(99):.3f}s, ops/GB-sec "
          f"{cl.ops_per_gb_s():.2f} vs {st.ops_per_gb_s():.2f}, "
          f"snapshot transfers {cl.transfers}")

    # real Azure Functions 2019-format replay (bundled sample, or any
    # trace passed on the command line); sibling durations/memory tables
    # are auto-discovered by bench_trace's loader
    from benchmarks.bench_trace import (AZURE_PARAMS, AZURE_SAMPLE,
                                        load_trace_file)
    path = sys.argv[1] if len(sys.argv) > 1 else AZURE_SAMPLE
    if not os.path.exists(path):
        if len(sys.argv) > 1:
            sys.exit(f"trace file not found: {path}")
        return                         # bundled sample absent: skip leg
    azure = load_trace_file(path)
    print(f"\n== azure replay: {azure.describe()}")
    # the fleet-pressure, adaptive-vs-fixed-at-equal-peak regime that
    # bench_trace's azure rows use
    ap = SimParams(**AZURE_PARAMS)
    for model in ("hydra", "hydra-pool", "hydra-cluster"):
        r = simulate(azure, model, ap)
        print(f"   {model:14s} ops/GB-sec={r.ops_per_gb_s():.2f} "
              f"mean_mem={r.mean_mem()/MB:.0f}MB "
              f"cold_rt={r.cold_runtime_starts} p99={r.p(99):.3f}s")


if __name__ == "__main__":
    main()
