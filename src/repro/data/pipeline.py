"""Deterministic synthetic data pipeline: document sampling, sequence
packing, host sharding, background prefetch.

Every batch is a pure function of (seed, step, host_id) — restarts resume
mid-stream with no data loss or duplication (checkpoint stores only the
step counter).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

PAD = -1
EOS = 1


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int           # per-host batch
    seed: int = 0
    mean_doc_len: int = 512
    host_id: int = 0
    n_hosts: int = 1


def _doc(rng: np.random.Generator, cfg: DataConfig) -> np.ndarray:
    n = max(8, int(rng.exponential(cfg.mean_doc_len)))
    toks = rng.integers(2, cfg.vocab_size, n)
    # inject learnable structure: local repetition (so loss can decrease)
    rep = rng.integers(2, 8)
    toks[rep:] = np.where(rng.random(n - rep) < 0.3, toks[:-rep], toks[rep:])
    return np.concatenate([toks, [EOS]])


def make_batch(cfg: DataConfig, step: int) -> dict:
    """Packed (inputs, labels) for ``step`` — deterministic, host-sharded."""
    out_inp = np.zeros((cfg.batch_size, cfg.seq_len), np.int32)
    out_lab = np.full((cfg.batch_size, cfg.seq_len), PAD, np.int32)
    for row in range(cfg.batch_size):
        rs = np.random.default_rng(
            (cfg.seed, step, cfg.host_id * cfg.batch_size + row))
        buf = np.empty(0, np.int64)
        while buf.size < cfg.seq_len + 1:
            buf = np.concatenate([buf, _doc(rs, cfg)])
        seq = buf[:cfg.seq_len + 1]
        out_inp[row] = seq[:-1]
        out_lab[row] = seq[1:]
    return {"tokens": out_inp, "labels": out_lab}


class Prefetcher:
    """Background-thread double buffering (the host-side input pipeline)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2):
        self.cfg = cfg
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _loop(self):
        step = self._step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
