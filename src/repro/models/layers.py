"""Shared primitive layers: norms, init helpers, rotary embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops


def dense_init(rng, shape, in_axis: int = -2, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = fan_in ** -0.5
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    return ops.rmsnorm(x, w, eps=eps)


def rotary(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """NeoX-style half-rotation rotary embedding.

    x: (..., S, H, hd) or (..., H, hd) with matching positions (..., S)/(...,).
    """
    hd = x.shape[-1]
    half = hd // 2
    freq = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freq  # (..., S, half)
    # broadcast over the heads axis (which sits between S and hd)
    angles = angles[..., None, :]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def embed_lookup(table: jax.Array, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(table, tokens, axis=0).astype(dtype)
