"""Mixture-of-Experts layer: top-k routing, sort-based equal-capacity
dispatch, expert parallelism over the ``model`` mesh axis, load-balance aux.

Dispatch is *sort-based* (megablox/MaxText-style), not one-hot-einsum based:
tokens are routed within fixed-size groups, argsorted by expert id, gathered
into an (E, C, D) slot layout, processed by a batched per-expert matmul
(FLOPs = active params only, x capacity factor), and scatter-added back.
This keeps HLO FLOPs at the MoE's *active* compute (the one-hot einsum
formulation inflates FLOPs by O(T/K) and would poison the roofline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.sharding import shard
from repro.models.layers import dense_init
from repro.models.mlp import _act, is_gated

_GROUP_TOKENS = 2048  # routing group size (sort locality; multiple of DP shards)


def init_moe(rng, cfg, stack: int | None = None):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    lead = (stack,) if stack else ()
    ks = jax.random.split(rng, 4)
    p = {
        "router": dense_init(ks[0], lead + (d, E)),
        "w_up": dense_init(ks[1], lead + (E, d, f)),
        "w_down": dense_init(ks[2], lead + (E, f, d)),
    }
    if is_gated(cfg.activation):
        p["w_gate"] = dense_init(ks[3], lead + (E, d, f))
    return p


def n_route_groups(n_tokens: int, kind: str, batch: int) -> int:
    if kind == "decode" or n_tokens <= _GROUP_TOKENS:
        return max(1, batch if kind == "decode" else 1)
    assert n_tokens % _GROUP_TOKENS == 0, (n_tokens, _GROUP_TOKENS)
    return n_tokens // _GROUP_TOKENS


def _capacity(group_tokens: int, cfg) -> int:
    m = cfg.moe
    cap = int(group_tokens * m.top_k * m.capacity_factor / m.num_experts)
    return max(1, -(-cap // 4) * 4) if group_tokens > 64 else max(4, cap)


def apply_moe(p, x, cfg, n_groups: int = 1):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    m = cfg.moe
    E, K = m.num_experts, m.top_k
    dt = x.dtype
    T = B * S
    G = n_groups
    assert T % G == 0, (T, G)
    Tg = T // G
    C = _capacity(Tg, cfg)
    xg = x.reshape(G, Tg, D)
    xg = shard(xg, "batch", None, None)

    # --- routing (fp32) ---
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (G, Tg, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)               # (G, Tg, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # --- Switch-style load-balance aux loss ---
    me = jnp.mean(probs, axis=(0, 1))                           # (E,)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # --- sort slots by expert id within each group ---
    Sk = Tg * K
    eflat = gate_idx.reshape(G, Sk)                             # expert per slot
    gflat = gate_vals.reshape(G, Sk)
    order = jnp.argsort(eflat, axis=-1, stable=True)            # (G, Sk)
    e_sorted = jnp.take_along_axis(eflat, order, axis=-1)
    g_sorted = jnp.take_along_axis(gflat, order, axis=-1)
    tok_sorted = order // K                                     # source token

    counts = jnp.sum(eflat[..., None] == jnp.arange(E), axis=1)  # (G, E)
    starts = jnp.cumsum(counts, axis=-1) - counts               # (G, E)
    pos_in_e = jnp.arange(Sk)[None, :] - jnp.take_along_axis(
        starts, e_sorted, axis=-1)                              # (G, Sk)
    keep = pos_in_e < C
    dest = jnp.where(keep, e_sorted * C + pos_in_e, E * C)      # sentinel slot

    # --- build slot->token index and slot gate via sentinel scatter ---
    def scatter1(dst_idx, val, fill, n):
        buf = jnp.full((n + 1,), fill, dtype=val.dtype)
        return buf.at[dst_idx].set(val)[:n]

    slot_tok = jax.vmap(lambda d, v: scatter1(d, v, Tg, E * C))(
        dest, tok_sorted)                                       # (G, E*C)
    slot_gate = jax.vmap(lambda d, v: scatter1(d, v, 0.0, E * C))(
        dest, jnp.where(keep, g_sorted, 0.0))                   # (G, E*C)

    # --- gather tokens into (G, E, C, D) slots (sentinel row = zeros) ---
    x_pad = jnp.concatenate([xg, jnp.zeros((G, 1, D), dt)], axis=1)
    ex_in = jnp.take_along_axis(x_pad, slot_tok[..., None], axis=1)
    ex_in = ex_in.reshape(G, E, C, D)
    ex_in = shard(ex_in, "batch", "experts", None, None)

    # --- batched per-expert FFN (active FLOPs only) ---
    up = jnp.einsum("gecd,edf->gecf", ex_in, p["w_up"].astype(dt))
    if is_gated(cfg.activation):
        g = jnp.einsum("gecd,edf->gecf", ex_in, p["w_gate"].astype(dt))
        h = _act(g, cfg.activation) * up
    else:
        h = _act(up, cfg.activation)
    ex_out = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))
    ex_out = shard(ex_out, "batch", "experts", None, None)

    # --- combine: scatter-add slots back to tokens, weighted by gates ---
    gated = ex_out.reshape(G, E * C, D) * slot_gate[..., None].astype(dt)

    def combine1(tok_idx, vals):
        out = jnp.zeros((Tg + 1, D), dt)
        return out.at[tok_idx].add(vals)[:Tg]

    out = jax.vmap(combine1)(slot_tok, gated)                   # (G, Tg, D)
    out = shard(out.reshape(B, S, D), "batch", None, None)
    return out, aux.astype(jnp.float32)
