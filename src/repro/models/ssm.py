"""Mamba2 (SSD) block: in_proj -> causal depthwise conv -> chunked SSD scan
-> gated RMSNorm -> out_proj. Single B/C group (n_groups=1).

State for decode: (conv_state (B, k-1, conv_dim), ssm_state (B, H, P, N) f32).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.launch.sharding import shard
from repro.models.layers import dense_init


def conv_dim(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def init_mamba(rng, cfg, stack: int | None = None):
    d, din, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    cd = conv_dim(cfg)
    lead = (stack,) if stack else ()
    ks = jax.random.split(rng, 4)
    # in_proj -> [z (din), xBC (din + 2N), dt (H)]
    return {
        "in_proj": dense_init(ks[0], lead + (d, 2 * din + 2 * N + H)),
        "conv_w": dense_init(ks[1], lead + (cfg.ssm_conv, cd)) * 0.1,
        "conv_b": jnp.zeros(lead + (cd,)),
        "A_log": jnp.zeros(lead + (H,)),          # A = -exp(A_log) = -1
        "D": jnp.ones(lead + (H,)),
        "dt_bias": jnp.full(lead + (H,), -1.0),   # softplus(-1) ~ 0.31
        "norm": jnp.zeros(lead + (din,)),
        "out_proj": dense_init(ks[3], lead + (din, d)),
    }


def _causal_conv(xbc, w, b):
    """Depthwise causal conv. xbc (B,S,C), w (k,C), b (C,)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i].astype(xbc.dtype)
              for i in range(k))
    return jax.nn.silu(out + b.astype(xbc.dtype))


def _split_proj(zxbcdt, cfg):
    din, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:2 * din + 2 * N]
    dt = zxbcdt[..., 2 * din + 2 * N:]
    return z, xbc, dt


def _gated_norm(y, z, w, eps):
    dtype = y.dtype
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
            ).astype(dtype)


def mamba_prefill(p, x, cfg, *, return_state: bool = False):
    """x: (B, S, D) -> (out, (conv_state, ssm_state) | None)."""
    B, S, _ = x.shape
    din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    dt_ = x.dtype
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(dt_))
    zxbcdt = shard(zxbcdt, "batch", None, "ff")
    z, xbc_pre, dt = _split_proj(zxbcdt, cfg)
    xbc = _causal_conv(xbc_pre, p["conv_w"], p["conv_b"])
    xin, Bm, Cm = xbc[..., :din], xbc[..., din:din + N], xbc[..., din + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(B, S, H, P)
    res = ops.ssd_scan(xh, dt, A, Bm, Cm, chunk=min(cfg.ssm_chunk, S),
                       return_state=return_state)
    y, state = res if return_state else (res, None)
    y = y + p["D"].astype(dt_)[None, None, :, None] * xh
    y = _gated_norm(y.reshape(B, S, din), z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(dt_))
    out = shard(out, "batch", None, None)
    if return_state:
        k = cfg.ssm_conv
        conv_state = xbc_pre[:, S - (k - 1):, :] if S >= k - 1 else jnp.pad(
            xbc_pre, ((0, 0), (k - 1 - S, 0), (0, 0)))
        return out, (conv_state, state)
    return out, None


def mamba_decode(p, x1, cfg, conv_state, ssm_state):
    """Single-token step. x1 (B,1,D); conv_state (B,k-1,cd); ssm_state f32."""
    B = x1.shape[0]
    din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    dt_ = x1.dtype
    zxbcdt = jnp.einsum("bsd,dk->bsk", x1, p["in_proj"].astype(dt_))
    z, xbc_pre, dt = _split_proj(zxbcdt[:, 0], cfg)
    # conv over [conv_state ; xbc_pre]
    win = jnp.concatenate([conv_state, xbc_pre[:, None, :]], axis=1)  # (B,k,cd)
    xbc = jax.nn.silu(jnp.einsum("bkc,kc->bc", win, p["conv_w"].astype(dt_))
                      + p["conv_b"].astype(dt_))
    new_conv = win[:, 1:, :]
    xin, Bm, Cm = xbc[..., :din], xbc[..., din:din + N], xbc[..., din + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(B, H, P)
    y, new_state = ops.ssd_decode(xh, dt, A, Bm, Cm, ssm_state)
    y = y + p["D"].astype(dt_)[None, :, None] * xh
    y = _gated_norm(y.reshape(B, din), z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bk,kd->bd", y, p["out_proj"].astype(dt_))
    return out[:, None, :], new_conv, new_state
