"""ModelProgram: the uniform ABI every architecture exposes to the Hydra
runtime and the launchers.

Entrypoints (all pure, jit/AOT-compile friendly):
  init(rng)                          -> params (fp32 masters)
  loss_fn(params, batch)             -> (loss, metrics)
  train_step(params, opt, batch)     -> (params, opt, metrics)   [grad accum]
  prefill(params, batch)             -> (last_logits, cache)
  decode_step(params, cache, batch)  -> (logits, cache)
  input_specs(shape)                 -> ShapeDtypeStruct kwargs (no alloc)
  cache_specs(batch, seq)            -> ShapeDtypeStruct cache pytree
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeConfig
from repro.models import ssm as ssm_mod
from repro.models import transformer as tf

AUX_WEIGHT = 0.01
IGNORE = -1


def cross_entropy(logits, labels, ignore: int = IGNORE,
                  mode: str = "gather"):
    """logits (B,S,V) any dtype, labels (B,S) int32 with `ignore` masking.

    mode="gather": take_along_axis (baseline; an all-gather over
    vocab-parallel logits under TP).
    mode="onehot": iota-compare + masked reduction — contraction over the
    sharded vocab dim stays local and reduces with one tiny psum.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    safe = jnp.clip(labels, 0, logits.shape[-1] - 1)
    if mode == "onehot":
        V = logits.shape[-1]
        hit = jnp.arange(V, dtype=jnp.int32)[None, None, :] == safe[..., None]
        ll = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    else:
        ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    mask = (labels != ignore).astype(jnp.float32)
    n = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum((logz - ll) * mask) / n


class ModelProgram:
    def __init__(self, cfg: ArchConfig, *, remat=True,
                 unroll: bool = False, ce_mode: str = "gather"):
        self.cfg = cfg
        self.remat = remat
        self.unroll = unroll  # exact cost_analysis for the dry-run
        self.ce_mode = ce_mode

    # ------------------------------------------------------------------
    def init(self, rng):
        return tf.init_params(rng, self.cfg)

    def _n_groups(self, batch) -> int:
        if self.cfg.moe is None:
            return 1
        tokens = batch["tokens"] if "tokens" in batch else batch["embeds"]
        B, S = tokens.shape[0], tokens.shape[1]
        if self.cfg.family == "vlm":
            S = S + self.cfg.frontend_tokens
        from repro.models.moe import n_route_groups
        kind = "decode" if S == 1 else "other"
        return n_route_groups(B * S, kind, B)

    # ------------------------------------------------------------------
    def loss_fn(self, params, batch):
        logits, aux = tf.forward(
            params, self.cfg,
            tokens=batch.get("tokens"), embeds=batch.get("embeds"),
            remat=self.remat, n_groups=self._n_groups(batch),
            unroll=self.unroll)
        ce = cross_entropy(logits, batch["labels"], mode=self.ce_mode)
        loss = ce + AUX_WEIGHT * aux
        return loss, {"ce": ce, "aux": aux}

    def make_train_step(self, optimizer, n_micro: int = 1):
        """Builds the (donatable) train step with gradient accumulation."""
        def train_step(params, opt_state, batch):
            def micro_grads(mb):
                (loss, mets), grads = jax.value_and_grad(
                    self.loss_fn, has_aux=True)(params, mb)
                return grads, loss, mets

            if n_micro == 1:
                grads, loss, mets = micro_grads(batch)
            else:
                resh = jax.tree.map(
                    lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                        + x.shape[1:]), batch)
                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def body(carry, mb):
                    acc, loss_acc = carry
                    grads, loss, _ = micro_grads(mb)
                    acc = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32), acc, grads)
                    return (acc, loss_acc + loss), None

                (grads, loss_sum), _ = jax.lax.scan(
                    body, (g0, jnp.float32(0.0)), resh)
                grads = jax.tree.map(lambda g: g / n_micro, grads)
                loss = loss_sum / n_micro
                mets = {}
            new_params, new_opt, omets = optimizer.update(
                grads, opt_state, params)
            return new_params, new_opt, {"loss": loss, **omets}
        return train_step

    # ------------------------------------------------------------------
    def prefill(self, params, batch):
        return tf.prefill(params, self.cfg,
                          tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"),
                          n_groups=self._n_groups(batch),
                          unroll=self.unroll)

    def decode_step(self, params, cache, batch):
        return tf.decode_step(params, self.cfg, cache,
                              tokens=batch.get("tokens"),
                              embeds=batch.get("embeds"),
                              n_groups=self._n_groups(batch),
                              unroll=self.unroll)

    # ------------------------------------------------------------------
    # Shape stand-ins (dry-run & arena sizing) — never allocate.
    # ------------------------------------------------------------------
    def cache_specs(self, batch: int, seq: int):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        sds = jax.ShapeDtypeStruct
        length = sds((batch,), jnp.int32)
        if cfg.family == "ssm":
            return {
                "conv": sds((cfg.n_layers, batch, cfg.ssm_conv - 1,
                             ssm_mod.conv_dim(cfg)), dt),
                "state": sds((cfg.n_layers, batch, cfg.ssm_heads,
                              cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
                "length": length,
            }
        hd = cfg.resolved_head_dim
        kv = sds((cfg.n_layers, batch, seq, cfg.n_kv_heads, hd), dt)
        if cfg.family == "hybrid":
            napp = cfg.n_layers // cfg.hybrid_attn_every
            return {
                "conv": sds((cfg.n_layers, batch, cfg.ssm_conv - 1,
                             ssm_mod.conv_dim(cfg)), dt),
                "state": sds((cfg.n_layers, batch, cfg.ssm_heads,
                              cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
                "k": sds((napp, batch, seq, cfg.n_kv_heads, hd), dt),
                "v": sds((napp, batch, seq, cfg.n_kv_heads, hd), dt),
                "length": length,
            }
        return {"k": kv, "v": kv, "length": length}

    def cache_bytes(self, batch: int, seq: int) -> int:
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(self.cache_specs(batch, seq)))

    def input_specs(self, shape: ShapeConfig):
        """ShapeDtypeStruct stand-ins for the entrypoint named by shape.kind.

        train  -> {tokens?, embeds?, labels}
        prefill-> {tokens?, embeds?}
        decode -> {tokens?/embeds?} (cache comes from cache_specs)
        """
        cfg = self.cfg
        sds = jax.ShapeDtypeStruct
        B, S = shape.global_batch, shape.seq_len
        dt = jnp.dtype(cfg.dtype)
        if shape.kind == "decode":
            if cfg.family == "audio":
                return {"embeds": sds((B, 1, cfg.d_model), dt)}
            return {"tokens": sds((B, 1), jnp.int32)}
        batch = {}
        if cfg.family == "audio":
            batch["embeds"] = sds((B, S, cfg.d_model), dt)
        elif cfg.family == "vlm":
            ft = cfg.frontend_tokens
            batch["embeds"] = sds((B, ft, cfg.d_model), dt)
            batch["tokens"] = sds((B, S - ft), jnp.int32)
        else:
            batch["tokens"] = sds((B, S), jnp.int32)
        if shape.kind == "train":
            batch["labels"] = sds((B, S), jnp.int32)
        return batch

    def param_bytes(self, dtype_bytes: int = 4) -> int:
        return self.cfg.param_count() * dtype_bytes
