"""Unified model assembly for all assigned families.

dense / moe / vlm / audio  -> attention+FFN blocks, lax.scan over stacked
                              layer params (HLO size O(1) in depth)
ssm                        -> Mamba2 (SSD) blocks
hybrid (zamba2)            -> Mamba2 backbone + ONE shared attention+FFN
                              block applied every ``hybrid_attn_every`` layers

Three entrypoints per model: ``forward`` (train), ``prefill`` (build KV/SSM
cache, last-token logits), ``decode_step`` (one token against the cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.sharding import shard
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import GLOBAL_WINDOW
from repro.models.layers import dense_init, embed_lookup, rmsnorm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(rng, cfg):
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab_size
    ks = jax.random.split(rng, 8)
    params = {}
    if cfg.family != "audio":
        params["embed"] = {"tok": dense_init(ks[0], (V, D), in_axis=-1)}
    if cfg.family == "ssm":
        params["layers"] = {"ln1": jnp.zeros((L, D)),
                            "ssm": ssm_mod.init_mamba(ks[1], cfg, stack=L)}
    elif cfg.family == "hybrid":
        params["layers"] = {"ln1": jnp.zeros((L, D)),
                            "ssm": ssm_mod.init_mamba(ks[1], cfg, stack=L)}
        params["shared"] = {
            "ln1": jnp.zeros((D,)),
            "attn": attn.init_attention(ks[2], cfg),
            "ln2": jnp.zeros((D,)),
            "mlp": mlp_mod.init_mlp(ks[3], cfg),
        }
    else:
        layer = {"ln1": jnp.zeros((L, D)),
                 "attn": attn.init_attention(ks[2], cfg, stack=L),
                 "ln2": jnp.zeros((L, D))}
        if cfg.moe is not None:
            layer["moe"] = moe_mod.init_moe(ks[3], cfg, stack=L)
        else:
            layer["mlp"] = mlp_mod.init_mlp(ks[3], cfg, stack=L)
        params["layers"] = layer
    params["final_norm"] = jnp.zeros((D,))
    if not cfg.tie_embeddings and cfg.family != "audio":
        params["lm_head"] = dense_init(ks[4], (D, V))
    elif cfg.family == "audio":
        params["lm_head"] = dense_init(ks[4], (D, V))
    return params


def layer_windows(cfg, static: bool = False):
    """Per-layer attention window (int32). GLOBAL_WINDOW = full attention.

    ``static=True`` (unrolled paths) returns a numpy array so each layer's
    window is a Python int at trace time — enabling windowed KV-cache reads
    and static-window Pallas kernels."""
    import numpy as np
    L = cfg.n_layers
    if cfg.sliding_window is None:
        out = np.full((L,), GLOBAL_WINDOW, np.int32)
    else:
        idx = np.arange(L)
        is_global = (idx + 1) % (cfg.global_every or L + 1) == 0
        out = np.where(is_global, GLOBAL_WINDOW,
                       cfg.sliding_window).astype(np.int32)
    return out if static else jnp.asarray(out)


def _embed(params, cfg, tokens, embeds):
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "audio":
        return embeds.astype(dt)
    h = embed_lookup(params["embed"]["tok"], tokens, dt)
    if cfg.family == "vlm" and embeds is not None:
        h = jnp.concatenate([embeds.astype(dt), h], axis=1)
    return h


def _unembed(params, cfg, h):
    dt = h.dtype
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        w = params["embed"]["tok"].astype(dt)
        logits = jnp.einsum("bsd,vd->bsv", h, w)
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"].astype(dt))
    return shard(logits, "batch", None, "vocab")


def _scan(body, carry, xs, unroll: bool = False):
    """lax.scan, or a python unroll (exact cost_analysis for the dry-run:
    XLA cost analysis counts a scan body ONCE, not x trip-count)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    L = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        xs_i = jax.tree.map(lambda x: x[i], xs)
        carry, y = body(carry, xs_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


def _group_tree(tree, k):
    """Reshape stacked (L, ...) leaves to (L//k, k, ...)."""
    return jax.tree.map(lambda x: x.reshape((x.shape[0] // k, k) + x.shape[1:]),
                        tree)


# ---------------------------------------------------------------------------
# shared attn+FFN block bodies
# ---------------------------------------------------------------------------
def _attn_block(p_l, h, cfg, positions, window):
    hn = rmsnorm(h, p_l["ln1"], cfg.norm_eps)
    a, kv = attn.attention_prefill(p_l["attn"], hn, cfg, positions, window)
    h = h + a
    hn = rmsnorm(h, p_l["ln2"], cfg.norm_eps)
    return h, hn, kv


def _ffn(p_l, hn, cfg, n_groups):
    if "moe" in p_l:
        out, aux = moe_mod.apply_moe(p_l["moe"], hn, cfg, n_groups)
    else:
        out, aux = mlp_mod.apply_mlp(p_l["mlp"], hn, cfg), 0.0
    return out, aux


# ---------------------------------------------------------------------------
# train forward (no cache)
# ---------------------------------------------------------------------------
def _remat_wrap(body, remat):
    if not remat:
        return body
    if remat == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(body)


def forward(params, cfg, tokens=None, embeds=None, *, remat=False,
            n_groups: int = 1, unroll: bool = False):
    """Returns (logits (B,S,V) in cfg.dtype, aux_loss scalar fp32)."""
    h = _embed(params, cfg, tokens, embeds)
    h = shard(h, "batch", None, None)
    B, S, _ = h.shape
    positions = jnp.arange(S)[None, :]

    if cfg.family == "ssm":
        def body(h, p_l):
            hn = rmsnorm(h, p_l["ln1"], cfg.norm_eps)
            o, _ = ssm_mod.mamba_prefill(p_l["ssm"], hn, cfg)
            return h + o, None
        body = _remat_wrap(body, remat)
        h, _ = _scan(body, h, params["layers"], unroll)
        return _unembed(params, cfg, h), jnp.float32(0.0)

    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        grouped = _group_tree(params["layers"], k)
        shared = params["shared"]

        def group_body(h, pg):
            def mamba_body(h, p_l):
                hn = rmsnorm(h, p_l["ln1"], cfg.norm_eps)
                o, _ = ssm_mod.mamba_prefill(p_l["ssm"], hn, cfg)
                return h + o, None
            h, _ = _scan(mamba_body, h, pg, unroll)
            h, hn, _ = _attn_block(shared, h, cfg, positions, None)
            h = h + mlp_mod.apply_mlp(shared["mlp"], hn, cfg)
            return h, None
        group_body = _remat_wrap(group_body, remat)
        h, _ = _scan(group_body, h, grouped, unroll)
        return _unembed(params, cfg, h), jnp.float32(0.0)

    windows = layer_windows(cfg, static=unroll)

    def body(carry, xs):
        h, aux = carry
        p_l, w_l = xs
        h, hn, _ = _attn_block(p_l, h, cfg, positions, w_l)
        out, a = _ffn(p_l, hn, cfg, n_groups)
        return (h + out, aux + a), None

    body = _remat_wrap(body, remat)
    (h, aux), _ = _scan(body, (h, jnp.float32(0.0)),
                               (params["layers"], windows), unroll)
    return _unembed(params, cfg, h), aux


# ---------------------------------------------------------------------------
# prefill: build the cache, return last-token logits
# ---------------------------------------------------------------------------
def prefill(params, cfg, tokens=None, embeds=None, *, n_groups: int = 1,
            unroll: bool = False):
    h = _embed(params, cfg, tokens, embeds)
    h = shard(h, "batch", None, None)
    B, S, _ = h.shape
    positions = jnp.arange(S)[None, :]
    lengths = jnp.full((B,), S, jnp.int32)

    if cfg.family == "ssm":
        def body(h, p_l):
            hn = rmsnorm(h, p_l["ln1"], cfg.norm_eps)
            o, st = ssm_mod.mamba_prefill(p_l["ssm"], hn, cfg, return_state=True)
            return h + o, st
        h, (conv, state) = _scan(body, h, params["layers"], unroll)
        cache = {"conv": conv, "state": state, "length": lengths}
        return _unembed(params, cfg, h[:, -1:, :])[:, 0], cache

    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        grouped = _group_tree(params["layers"], k)
        shared = params["shared"]

        def group_body(h, pg):
            def mamba_body(h, p_l):
                hn = rmsnorm(h, p_l["ln1"], cfg.norm_eps)
                o, st = ssm_mod.mamba_prefill(p_l["ssm"], hn, cfg,
                                              return_state=True)
                return h + o, st
            h, (conv, state) = _scan(mamba_body, h, pg, unroll)
            h, hn, kv = _attn_block(shared, h, cfg, positions, None)
            h = h + mlp_mod.apply_mlp(shared["mlp"], hn, cfg)
            return h, (conv, state, kv[0].astype(jnp.dtype(cfg.dtype)),
                       kv[1].astype(jnp.dtype(cfg.dtype)))
        h, (conv, state, kc, vc) = _scan(group_body, h, grouped, unroll)
        # conv/state are (Gh, k, B, ...) -> flatten back to (L, B, ...)
        conv = jax.tree.map(
            lambda x: x.reshape((-1,) + x.shape[2:]), conv)
        state = jax.tree.map(
            lambda x: x.reshape((-1,) + x.shape[2:]), state)
        cache = {"conv": conv, "state": state, "k": kc, "v": vc,
                 "length": lengths}
        return _unembed(params, cfg, h[:, -1:, :])[:, 0], cache

    windows = layer_windows(cfg, static=unroll)

    def body(h, xs):
        p_l, w_l = xs
        h, hn, kv = _attn_block(p_l, h, cfg, positions, w_l)
        out, _ = _ffn(p_l, hn, cfg, n_groups)
        dt = jnp.dtype(cfg.dtype)
        return h + out, (kv[0].astype(dt), kv[1].astype(dt))

    h, (kc, vc) = _scan(body, h, (params["layers"], windows), unroll)
    kc = shard(kc, None, "batch", "kv_seq", "kv_heads", None)
    vc = shard(vc, None, "batch", "kv_seq", "kv_heads", None)
    cache = {"k": kc, "v": vc, "length": lengths}
    return _unembed(params, cfg, h[:, -1:, :])[:, 0], cache


# ---------------------------------------------------------------------------
# decode: one token against the cache
# ---------------------------------------------------------------------------
def decode_step(params, cfg, cache, tokens=None, embeds=None,
                *, n_groups: int = 1, unroll: bool = False):
    """tokens (B,1) / embeds (B,1,D) -> (logits (B,V), new cache)."""
    h = _embed(params, cfg, tokens, embeds)
    lengths = cache["length"]

    if cfg.family == "ssm":
        def body(h, xs):
            p_l, conv_l, state_l = xs
            hn = rmsnorm(h, p_l["ln1"], cfg.norm_eps)
            o, nc, ns = ssm_mod.mamba_decode(p_l["ssm"], hn, cfg, conv_l, state_l)
            return h + o, (nc, ns)
        h, (conv, state) = _scan(
            body, h, (params["layers"], cache["conv"], cache["state"]),
            unroll)
        new_cache = {"conv": conv, "state": state, "length": lengths + 1}
        return _unembed(params, cfg, h)[:, 0], new_cache

    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        grouped = _group_tree(params["layers"], k)
        shared = params["shared"]
        conv_g = _group_tree(cache["conv"], k)
        state_g = _group_tree(cache["state"], k)

        def group_body(h, xs):
            pg, conv_l, state_l, k_i, v_i = xs

            def mamba_body(h, xs_i):
                p_l, c_l, s_l = xs_i
                hn = rmsnorm(h, p_l["ln1"], cfg.norm_eps)
                o, nc, ns = ssm_mod.mamba_decode(p_l["ssm"], hn, cfg, c_l, s_l)
                return h + o, (nc, ns)
            h, (nconv, nstate) = _scan(mamba_body, h,
                                       (pg, conv_l, state_l), unroll)
            hn = rmsnorm(h, shared["ln1"], cfg.norm_eps)
            a, nk, nv = attn.attention_decode(shared["attn"], hn, cfg,
                                              k_i, v_i, lengths, None)
            h = h + a
            hn = rmsnorm(h, shared["ln2"], cfg.norm_eps)
            h = h + mlp_mod.apply_mlp(shared["mlp"], hn, cfg)
            return h, (nconv, nstate, nk, nv)

        h, (conv, state, kc, vc) = _scan(
            group_body, h,
            (grouped, conv_g, state_g, cache["k"], cache["v"]), unroll)
        conv = conv.reshape((-1,) + conv.shape[2:])
        state = state.reshape((-1,) + state.shape[2:])
        new_cache = {"conv": conv, "state": state, "k": kc, "v": vc,
                     "length": lengths + 1}
        return _unembed(params, cfg, h)[:, 0], new_cache

    windows = layer_windows(cfg, static=unroll)

    # xs/ys pattern: per-layer cache slices flow through the scan as inputs
    # and outputs (never a full-stack dynamic-update-slice chain, which XLA
    # cost analysis — and a non-aliasing compiler — would treat as an
    # O(L x cache) copy; with donation the ys buffer aliases the input).
    def body(h, xs):
        p_l, w_l, k_i, v_i = xs
        hn = rmsnorm(h, p_l["ln1"], cfg.norm_eps)
        a, nk, nv = attn.attention_decode(p_l["attn"], hn, cfg, k_i, v_i,
                                          lengths, w_l)
        h = h + a
        hn = rmsnorm(h, p_l["ln2"], cfg.norm_eps)
        out, _ = _ffn(p_l, hn, cfg, n_groups)
        return h + out, (nk, nv)

    h, (kc, vc) = _scan(body, h,
                        (params["layers"], windows, cache["k"], cache["v"]),
                        unroll)
    new_cache = {"k": kc, "v": vc, "length": lengths + 1}
    return _unembed(params, cfg, h)[:, 0], new_cache
