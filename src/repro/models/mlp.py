"""Feed-forward variants: SwiGLU (silu), GeGLU (gelu), squared-ReLU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.sharding import shard
from repro.models.layers import dense_init


def is_gated(activation: str) -> bool:
    return activation in ("silu", "gelu")


def init_mlp(rng, cfg, stack: int | None = None):
    d, f = cfg.d_model, cfg.d_ff
    lead = (stack,) if stack else ()
    ks = jax.random.split(rng, 3)
    p = {"w_up": dense_init(ks[0], lead + (d, f)),
         "w_down": dense_init(ks[1], lead + (f, d))}
    if is_gated(cfg.activation):
        p["w_gate"] = dense_init(ks[2], lead + (d, f))
    return p


def _act(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def apply_mlp(p, x, cfg):
    """x: (B, S, D) -> (B, S, D)."""
    dt = x.dtype
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    up = shard(up, "batch", None, "ff")
    if is_gated(cfg.activation):
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        h = _act(gate, cfg.activation) * up
    else:
        h = _act(up, cfg.activation)
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
    return shard(out, "batch", None, None)
