"""GQA attention: train/prefill path + KV-cache decode path.

Supports: grouped-query attention, QKV bias, rotary embeddings, sliding
windows (static or per-layer traced, for gemma3's 5:1 local:global pattern).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.launch.sharding import shard
from repro.models.layers import dense_init, rotary

GLOBAL_WINDOW = jnp.iinfo(jnp.int32).max // 2  # "no window" sentinel
WINDOWED_DECODE_READS = False  # see note in attention_decode


def init_attention(rng, cfg, stack: int | None = None):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    lead = (stack,) if stack else ()
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], lead + (d, hq * hd)),
        "wk": dense_init(ks[1], lead + (d, hkv * hd)),
        "wv": dense_init(ks[2], lead + (d, hkv * hd)),
        "wo": dense_init(ks[3], lead + (hq * hd, d), in_axis=-2),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros(lead + (hq * hd,))
        p["bk"] = jnp.zeros(lead + (hkv * hd,))
        p["bv"] = jnp.zeros(lead + (hkv * hd,))
    return p


def _project_qkv(p, x, cfg):
    """x: (B, S, D) -> q (B,S,Hq,hd), k/v (B,S,Hkv,hd)."""
    B, S, _ = x.shape
    dt = x.dtype
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = shard(q.reshape(B, S, cfg.n_heads, hd), "batch", None, "heads", None)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    return q, k, v


def attention_prefill(p, x, cfg, positions, window=None):
    """Full-sequence attention. Returns (out (B,S,D), (k, v) for the cache)."""
    q, k, v = _project_qkv(p, x, cfg)
    q = rotary(q, positions, cfg.rope_theta)
    k = rotary(k, positions, cfg.rope_theta)
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.n_heads * cfg.resolved_head_dim)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))
    return shard(out, "batch", None, None), (k, v)


def attention_decode(p, x1, cfg, k_cache, v_cache, lengths, window=None):
    """Single-token decode.

    x1: (B, 1, D); k_cache/v_cache: (B, S, Hkv, hd); lengths: (B,) valid
    entries per row. Returns (out (B,1,D), new_k_cache, new_v_cache).
    """
    B = x1.shape[0]
    q, k, v = _project_qkv(p, x1, cfg)            # q (B,1,Hq,hd)
    pos = lengths.astype(jnp.int32)
    q = rotary(q, pos[:, None], cfg.rope_theta)
    k = rotary(k, pos[:, None], cfg.rope_theta)

    # scatter new k/v at each row's write position
    def write(cache, val, i):
        return jax.lax.dynamic_update_slice(cache, val, (i, 0, 0))

    k_cache = shard(jax.vmap(write)(k_cache, k, pos),
                    "batch", "kv_seq", "kv_heads", None)
    v_cache = shard(jax.vmap(write)(v_cache, v, pos),
                    "batch", "kv_seq", "kv_heads", None)

    S = k_cache.shape[1]
    w = int(window) if isinstance(window, (int, jnp.integer,
                                           np.integer)) else None
    # NOTE: disabled by default — XLA SPMD lowers the per-row dynamic_slice
    # as a gather that replicates the cache operand (an all-gather per
    # layer), wiping out the read savings. The production fix is a
    # ring-buffer cache (w entries) for sliding-window layers; see
    # EXPERIMENTS.md §Perf (gemma) for the measured failure + design.
    if WINDOWED_DECODE_READS and w is not None and w < S:
        # windowed read: a sliding-window layer only ever attends to the
        # last `w` cache entries — slice before attention so HBM traffic is
        # O(w), not O(S) (the full cache is still updated above).
        start = jnp.clip(lengths.astype(jnp.int32) + 1 - w, 0, S - w)

        def win(c, st):
            return jax.lax.dynamic_slice(
                c, (st, 0, 0), (w, c.shape[1], c.shape[2]))
        k_eff = jax.vmap(win)(k_cache, start)
        v_eff = jax.vmap(win)(v_cache, start)
        len_eff = jnp.minimum(lengths + 1, w)
        out = ops.decode_attention(q[:, 0], k_eff, v_eff, len_eff,
                                   window=None)
    else:
        out = ops.decode_attention(q[:, 0], k_cache, v_cache, lengths + 1,
                                   window=window)
    out = out.reshape(B, 1, cfg.n_heads * cfg.resolved_head_dim)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x1.dtype))
    return shard(out, "batch", None, None), k_cache, v_cache
