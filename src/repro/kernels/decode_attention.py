"""Flash-decode Pallas TPU kernel: one query token per sequence against a
(possibly partially filled) KV cache.

Grid = (B, num_kv_blocks); each instance processes ALL query heads of one
sequence (the whole q row fits VMEM easily: Hq x hd). The KV axis is the
innermost "arbitrary" dimension with the online-softmax state in VMEM
scratch. Per-row valid lengths arrive as a scalar-prefetch operand (SMEM),
which also lets fully-invalid KV blocks skip their compute.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, window, bk: int, nk: int, group: int):
    b = pl.program_id(0)
    ki = pl.program_id(1)
    length = len_ref[b]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ki * bk < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale         # (Hq, hd)
        k = k_ref[0].astype(jnp.float32)                 # (bk, Hkv, hd)
        v = v_ref[0].astype(jnp.float32)
        Hq = q.shape[0]
        Hkv = k.shape[1]
        qg = q.reshape(Hkv, group, q.shape[-1])
        # s (Hkv, group, bk)
        s = jax.lax.dot_general(
            qg, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)          # (Hkv, group, bk)
        kpos = ki * bk + jax.lax.broadcasted_iota(
            jnp.int32, (Hkv, group, bk), 2)
        mask = kpos < length
        if window is not None:
            mask &= kpos > length - 1 - window
        s = jnp.where(mask, s, NEG_INF)
        s = s.reshape(Hq, bk)

        m_prev = m_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                           # (Hq, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        pg = p.reshape(Hkv, group, bk)
        pv = jax.lax.dot_general(
            pg, v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)          # (Hkv, group, hd)
        acc_scr[...] = acc_scr[...] * alpha + pv.reshape(Hq, -1)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, window=None,
                     scale=None, interpret=False, block_k=256):
    """q (B,Hq,hd), k/v cache (B,S,Hkv,hd), lengths (B,) -> (B,Hq,hd)."""
    B, Hq, hd = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    group = Hq // Hkv
    if not isinstance(window, (int, type(None))):
        raise ValueError("Pallas path needs a static window")
    scale = scale if scale is not None else hd ** -0.5

    bk = min(block_k, S)
    s_pad = math.ceil(S / bk) * bk
    if s_pad != S:
        pad = ((0, 0), (0, s_pad - S), (0, 0), (0, 0))
        k_cache, v_cache = jnp.pad(k_cache, pad), jnp.pad(v_cache, pad)
    nk = s_pad // bk

    kernel = functools.partial(_kernel, scale=scale, window=window,
                               bk=bk, nk=nk, group=group)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nk),
        in_specs=[
            pl.BlockSpec((1, Hq, hd), lambda b, j, lens: (b, 0, 0)),
            pl.BlockSpec((1, bk, Hkv, hd), lambda b, j, lens: (b, j, 0, 0)),
            pl.BlockSpec((1, bk, Hkv, hd), lambda b, j, lens: (b, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, hd), lambda b, j, lens: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hq, 1), jnp.float32),
            pltpu.VMEM((Hq, 1), jnp.float32),
            pltpu.VMEM((Hq, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k_cache, v_cache)
    return out
