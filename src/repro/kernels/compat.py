"""Version-compat shims for Pallas TPU APIs.

JAX renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` in newer
releases; the pinned 0.4.x only has the TPU-prefixed name. Resolve once here
so every kernel stays release-agnostic.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None)
if CompilerParams is None:
    CompilerParams = pltpu.TPUCompilerParams
