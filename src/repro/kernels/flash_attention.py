"""Flash attention Pallas TPU kernel (prefill/train path).

Online-softmax blocked attention with GQA, causal masking and an optional
static sliding window. Grid = (B, Hq, num_q_blocks, num_kv_blocks); the KV
axis is the innermost ("arbitrary") dimension and the running (m, l, acc)
state lives in VMEM scratch across KV iterations — the canonical TPU
flash-attention schedule (HBM->VMEM tiles, MXU for the two matmuls).

Block sizes are multiples of 128 on the MXU-facing dims (q/kv block length,
head_dim padded by the wrapper if needed).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window, bq: int, bk: int,
            s_orig: int, nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < s_orig
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                  # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    interpret=False, block_q=128, block_k=128):
    """q (B,S,Hq,hd), k/v (B,S,Hkv,hd) -> (B,S,Hq,hd)."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    if not isinstance(window, (int, type(None))):
        raise ValueError("Pallas path needs a static window; use the ref "
                         "path for traced per-layer windows")
    scale = scale if scale is not None else hd ** -0.5

    bq = min(block_q, max(8, S))
    bk = min(block_k, max(8, S))
    s_pad = math.ceil(S / max(bq, bk)) * max(bq, bk)
    # layout: (B, H, S, hd) for clean 2D tiles
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if s_pad != S:
        pad = ((0, 0), (0, 0), (0, s_pad - S), (0, 0))
        qt, kt, vt = jnp.pad(qt, pad), jnp.pad(kt, pad), jnp.pad(vt, pad)
    nq, nk = s_pad // bq, s_pad // bk

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, s_orig=S, nk=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, s_pad, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :S, :]
    return jnp.moveaxis(out, 1, 2)
