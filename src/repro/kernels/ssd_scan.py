"""Mamba2 SSD chunked-scan Pallas TPU kernel.

Grid = (B, H, num_chunks); the chunk axis is sequential ("arbitrary") with
the running (P, N) state held in VMEM scratch — the TPU-native shape of the
SSD recurrence: the intra-chunk part is two MXU matmuls over (chunk x chunk)
and (chunk x N) tiles, the inter-chunk part is a rank-N state update that
never leaves VMEM. Chunk length and P/N are MXU-aligned by config (chunk a
multiple of 8, P/N of 16+).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, s0_ref, y_ref, sf_ref,
            state_scr, *, nc: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)          # (chunk, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (1, chunk)
    A = a_ref[0].astype(jnp.float32)             # scalar
    Bm = b_ref[0].astype(jnp.float32)            # (chunk, N)
    Cm = c_ref[0].astype(jnp.float32)            # (chunk, N)

    a = dt[0] * A                                # (chunk,) log-decay
    a_cs = jnp.cumsum(a)                         # (chunk,)
    seg = a_cs[:, None] - a_cs[None, :]          # (l, s)
    tril = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tril, jnp.exp(seg), 0.0)

    dtx = dt[0][:, None] * x                     # (chunk, P)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_diag = jax.lax.dot_general(CB * L, dtx, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    state = state_scr[...]                       # (P, N)
    y_off = jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_off = y_off * jnp.exp(a_cs)[:, None]       # (chunk, P)
    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    decay_tail = jnp.exp(a_cs[-1] - a_cs)        # (chunk,)
    new_contrib = jax.lax.dot_general(
        dtx, Bm * decay_tail[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # (P, N)
    state_scr[...] = state * jnp.exp(a_cs[-1]) + new_contrib

    @pl.when(ci == nc - 1)
    def _finish():
        sf_ref[0, 0] = state_scr[...]


def ssd_scan(x, dt, A, Bm, Cm, *, chunk=64, init_state=None,
             return_state=False, interpret=False):
    """x (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N) -> y (B,S,H,P)
    [, final_state (B,H,P,N) f32]."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, max(8, S))
    s_pad = math.ceil(S / chunk) * chunk
    if s_pad != S:
        # dt=0 padding: decay 1, contribution 0 (state-exact; see ref.py)
        x = jnp.pad(x, ((0, 0), (0, s_pad - S), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, s_pad - S), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, s_pad - S), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, s_pad - S), (0, 0)))
    nc = s_pad // chunk

    xt = jnp.moveaxis(x, 2, 1)                   # (B, H, S, P)
    dtt = jnp.moveaxis(dt, 2, 1)[:, :, None, :]  # (B, H, 1, S)
    s0 = (jnp.zeros((B, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    kernel = functools.partial(_kernel, nc=nc, chunk=chunk)
    y, sf = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, h, c: (b, h, 0, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, s_pad, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xt, dtt, A, Bm, Cm, s0)
    y = jnp.moveaxis(y, 1, 2)[:, :S]
    if return_state:
        return y, sf
    return y
