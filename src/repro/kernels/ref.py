"""Pure-jnp oracles for every Pallas kernel.

These are the numerical ground truth the kernels are validated against
(``tests/test_kernels.py``), and also the path used on non-TPU backends and
in the dry-run (so XLA cost analysis sees real FLOPs, not an opaque
callback).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# Flash attention (train / prefill): GQA + causal + optional sliding window
# ---------------------------------------------------------------------------
def flash_attention_ref(
    q: jax.Array,          # (B, S, Hq, hd)
    k: jax.Array,          # (B, S, Hkv, hd)
    v: jax.Array,          # (B, S, Hkv, hd)
    *,
    causal: bool = True,
    window: int | None = None,   # sliding window size (keys within [i-w+1, i])
    scale: float | None = None,
) -> jax.Array:
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = scale if scale is not None else hd ** -0.5

    # grouped-query einsum: never materialize repeated KV heads or fp32
    # copies of K/V (fp32 accumulation via preferred_element_type)
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(B, S, Hkv, group, hd)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(q.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, Hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention: one query token against a (possibly partial) KV cache
# ---------------------------------------------------------------------------
def decode_attention_ref(
    q: jax.Array,          # (B, Hq, hd)
    k_cache: jax.Array,    # (B, S, Hkv, hd)
    v_cache: jax.Array,    # (B, S, Hkv, hd)
    lengths: jax.Array,    # (B,) int32 — number of valid cache entries
    *,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    B, Hq, hd = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    group = Hq // Hkv
    scale = scale if scale is not None else hd ** -0.5

    # grouped-query einsum against the cache in its native dtype — never
    # materialize repeated KV heads or an fp32 cache copy
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(B, Hkv, group, hd)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                        preferred_element_type=jnp.float32)
    kpos = jnp.arange(S)[None, :]                       # (1, S)
    valid = kpos < lengths[:, None]                     # (B, S)
    if window is not None:
        valid &= kpos > (lengths[:, None] - 1 - window)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(q.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba2 SSD chunked scan (state-space duality)
# ---------------------------------------------------------------------------
def _segsum(a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} a[..., k] for j<=i."""
    L = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    seg = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), dtype=bool), k=0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_scan_ref(
    x: jax.Array,        # (B, S, H, P)  — inputs per head
    dt: jax.Array,       # (B, S, H)     — softplus-activated step sizes
    A: jax.Array,        # (H,)          — negative decay rates
    Bm: jax.Array,       # (B, S, N)     — input matrix (single group)
    Cm: jax.Array,       # (B, S, N)     — output matrix (single group)
    *,
    chunk: int = 64,
    init_state: jax.Array | None = None,   # (B, H, P, N)
    return_state: bool = False,
):
    """Chunked SSD computation (Mamba2, arXiv:2405.21060 listing 1).

    y[t] = C[t] . state[t],  state[t] = exp(dt[t]*A) * state[t-1]
                                        + dt[t] * B[t] (outer) x[t]
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    if S % chunk:
        # pad with dt=0 tokens: decay=exp(0)=1 and contribution dt*Bx=0, so
        # the final state is unchanged and padded outputs are discarded.
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        out = ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk,
                           init_state=init_state, return_state=return_state)
        if return_state:
            return out[0][:, :S], out[1]
        return out[:, :S]
    nc = S // chunk

    f32 = jnp.float32
    x_ = x.astype(f32).reshape(Bsz, nc, chunk, H, P)
    dt_ = dt.astype(f32).reshape(Bsz, nc, chunk, H)
    B_ = Bm.astype(f32).reshape(Bsz, nc, chunk, N)
    C_ = Cm.astype(f32).reshape(Bsz, nc, chunk, N)

    a = dt_ * A.astype(f32)[None, None, None, :]        # (b,c,l,h) log-decay
    a = jnp.moveaxis(a, -1, -2)                         # (b,c,h,l)
    a_cs = jnp.cumsum(a, axis=-1)                       # (b,c,h,l)

    # 1. intra-chunk (diagonal block) output
    L = jnp.exp(_segsum(a))                             # (b,c,h,l,l)
    Y_diag = jnp.einsum("bcln,bcsn,bchls,bcshp->bclhp", C_, B_, L, dt_[..., None] * x_)

    # 2. per-chunk final states
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)       # (b,c,h,l)
    states = jnp.einsum("bcln,bchl,bclhp->bchpn", B_, decay_states, dt_[..., None] * x_)

    # 3. inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(a_cs[..., -1])                # (b,c,h)
    s0 = (jnp.zeros((Bsz, H, P, N), f32) if init_state is None
          else init_state.astype(f32))

    def step(carry, inp):
        st_new, decay = inp                             # (b,h,p,n), (b,h)
        prev = carry
        cur = prev * decay[..., None, None] + st_new
        return cur, prev

    chunk_states = jnp.moveaxis(states, 1, 0)           # (c,b,h,p,n)
    chunk_decays = jnp.moveaxis(chunk_decay, 1, 0)      # (c,b,h)
    final, prevs = jax.lax.scan(step, s0, (chunk_states, chunk_decays))
    prev_states = jnp.moveaxis(prevs, 0, 1)             # (b,c,h,p,n)

    # 4. state -> output contribution
    state_decay = jnp.exp(a_cs)                         # (b,c,h,l)
    Y_off = jnp.einsum("bcln,bchpn,bchl->bclhp", C_, prev_states, state_decay)

    y = (Y_diag + Y_off).reshape(Bsz, S, H, P).astype(x.dtype)
    if return_state:
        return y, final.astype(f32)
    return y


def ssd_decode_ref(
    x: jax.Array,        # (B, H, P) — single-token input
    dt: jax.Array,       # (B, H)
    A: jax.Array,        # (H,)
    Bm: jax.Array,       # (B, N)
    Cm: jax.Array,       # (B, N)
    state: jax.Array,    # (B, H, P, N) fp32
):
    """Single-token SSD state update + output."""
    f32 = jnp.float32
    xf, dtf = x.astype(f32), dt.astype(f32)
    decay = jnp.exp(dtf * A.astype(f32)[None, :])       # (B, H)
    dBx = jnp.einsum("bh,bhp,bn->bhpn", dtf, xf, Bm.astype(f32))
    new_state = state * decay[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(f32))
    return y.astype(x.dtype), new_state
