"""Fused RMSNorm Pallas TPU kernel (row-tiled, fp32 accumulation)."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    w = 1.0 + w_ref[...].astype(jnp.float32)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w).astype(o_ref.dtype)


def rmsnorm(x, w, *, eps: float = 1e-5, interpret: bool = False,
            block_rows: int = 256):
    shape = x.shape
    D = shape[-1]
    x2 = x.reshape(-1, D)
    R = x2.shape[0]
    br = min(block_rows, R)
    grid = (math.ceil(R / br),)
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out.reshape(shape)
