"""jit'd public wrappers for the Pallas kernels.

Dispatch policy (``kernel_mode()``):
  * ``auto``      — Pallas kernel on TPU, jnp reference elsewhere (CPU dry-run
                    must see real HLO FLOPs, not an opaque callback).
  * ``pallas``    — force the compiled Pallas kernel.
  * ``interpret`` — Pallas kernel in interpret mode (CPU correctness tests).
  * ``ref``       — force the pure-jnp oracle.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import ref as _ref

_MODE_ENV = "REPRO_KERNEL_MODE"
_mode_override: str | None = None


def set_kernel_mode(mode: str | None) -> None:
    global _mode_override
    assert mode in (None, "auto", "pallas", "interpret", "ref"), mode
    _mode_override = mode


def kernel_mode() -> str:
    if _mode_override is not None:
        return _mode_override
    return os.environ.get(_MODE_ENV, "auto")


def _use_pallas() -> tuple[bool, bool]:
    """-> (use_kernel, interpret)"""
    mode = kernel_mode()
    if mode == "pallas":
        return True, False
    if mode == "interpret":
        return True, True
    if mode == "ref":
        return False, False
    return jax.default_backend() == "tpu", False


# ---------------------------------------------------------------------------
def rmsnorm(x, w, eps: float = 1e-5):
    use, interp = _use_pallas()
    if use:
        from repro.kernels import rmsnorm as _k
        return _k.rmsnorm(x, w, eps=eps, interpret=interp)
    return _ref.rmsnorm_ref(x, w, eps)


def flash_attention(q, k, v, *, causal=True, window=None, scale=None):
    use, interp = _use_pallas()
    if use:
        from repro.kernels import flash_attention as _k
        return _k.flash_attention(
            q, k, v, causal=causal, window=window, scale=scale, interpret=interp)
    return _ref.flash_attention_ref(q, k, v, causal=causal, window=window, scale=scale)


def decode_attention(q, k_cache, v_cache, lengths, *, window=None, scale=None):
    use, interp = _use_pallas()
    if use:
        from repro.kernels import decode_attention as _k
        return _k.decode_attention(
            q, k_cache, v_cache, lengths, window=window, scale=scale, interpret=interp)
    return _ref.decode_attention_ref(
        q, k_cache, v_cache, lengths, window=window, scale=scale)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk=64, init_state=None, return_state=False):
    use, interp = _use_pallas()
    if use:
        from repro.kernels import ssd_scan as _k
        return _k.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, init_state=init_state,
                           return_state=return_state, interpret=interp)
    return _ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk, init_state=init_state,
                             return_state=return_state)


def ssd_decode(x, dt, A, Bm, Cm, state):
    # Single-token state update is bandwidth-trivial; jnp path is used on all
    # backends (XLA fuses it into one pass).
    return _ref.ssd_decode_ref(x, dt, A, Bm, Cm, state)
