"""HydraPlatform: the paper's platform layer over many HydraRuntimes.

The single-node ``HydraRuntime`` converts *compilation* cold starts into
arena cold starts; this layer removes the remaining *runtime* cold start
and drives density (paper §4: 2.41x density, 21-44% memory reduction):

  * **Pre-warmed instance pool** — generic, function-agnostic runtimes are
    booted ahead of demand (the paper's "caching layer of pre-allocated
    Hydra instances") and claimed by ANY tenant/function on its first
    invocation, so no request ever waits on a runtime boot.
  * **Colocation-aware placement** — invocations are packed across owners
    and functions into already-running runtimes (tightest-fit first) until
    the per-runtime memory budget saturates, then spill to a pool instance,
    and only cold-boot when the pool is drained.
  * **Sandbox snapshot/restore** — a function's weights + registry state
    checkpoint to disk (``repro.ft.checkpoint``); an evicted function is
    restored into a pooled runtime WITHOUT recompiling because every
    runtime shares one ``ExecutableCache`` (and optionally its persistent
    on-disk executables), so restore re-registration is a pure cache hit.

All runtimes share one ExecutableCache: code-cache sharing spans the fleet,
not just tenants within a runtime. A ``HydraCluster``
(``repro.core.cluster``) composes N of these platforms — one per machine —
and adds cross-node placement, snapshot migration, and adaptive pool
sizing; the hooks it uses live here: an injectable ``exe_cache`` (so the
whole fleet, not just one node, shares compiled executables),
``resize_pool`` (the adaptive policy retargets the warm pool), and
``export_function``/``import_function`` (detach a function's portable
record on one node and adopt it on another).

``PlatformParams`` fields:

  * ``pool_size`` — target number of pre-warmed generic runtimes kept
    ready; ``resize_pool`` retargets it at runtime (adaptive sizing).
  * ``runtime_budget_bytes`` — per-runtime memory budget (paper: 2 GB);
    placement packs functions into a runtime until this saturates.
  * ``max_runtimes`` — node-level cap on simultaneous runtimes (pooled +
    active); beyond it placement fails (a cluster spills to another node).
  * ``arena_ttl_s`` / ``n_workers`` / ``janitor`` — passed through to each
    ``HydraRuntime`` (isolate pool TTL, worker threads, TTL evictor).
  * ``refill`` — re-warm the pool on a background thread after a claim.
  * ``snapshot_dir`` — enables sandbox snapshot/evict/restore under this
    directory; required for eviction-with-snapshot and migration.
  * ``persist_executables`` — also persist compiled executables under
    ``snapshot_dir`` so a re-booted platform restores with zero compiles.
    Defaults to ON whenever ``snapshot_dir`` is set (pass False to opt
    out) — the ROADMAP "snapshot warm-path".
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax

from repro.core.errors import (FunctionNotRegisteredError, HydraError,
                               HydraOOMError)
from repro.core.executable_cache import ExecutableCache
from repro.core.metrics import Metrics
from repro.core.runtime import GB, HydraRuntime, registration_budget
from repro.core.tracing import NULL_TRACE
from repro.ft import checkpoint as ckpt


def estimate_bytes(spec) -> int:
    """Placement-time estimate of a function's runtime footprint: the
    reservation HydraRuntime.register_function makes PLUS one live arena
    (the arena pool reserves budget again at first acquisition), so a
    placement that fits the estimate can also serve without OOM."""
    reserve, arena = registration_budget(spec)
    return reserve + arena


@dataclass
class _FunctionRecord:
    """Platform-side registry state for one function (survives eviction)."""
    fid: str
    spec: Any
    tenant: str
    mem_budget: Optional[int]
    need_bytes: int
    runtime: Optional[HydraRuntime] = None
    snapshot_path: Optional[str] = None
    params_spec: Any = None          # ShapeDtypeStruct tree of the weights
    invocations: int = 0
    evicted: bool = False            # weights dropped; restore() required
    # serializes placement of THIS function so racing first invocations
    # cannot register it into two runtimes
    place_lock: threading.Lock = field(default_factory=threading.Lock)


@dataclass
class PlatformParams:
    pool_size: int = 2                        # pre-warmed generic runtimes
    runtime_budget_bytes: int = 2 * GB        # paper: 2 GB per runtime
    max_runtimes: int = 64                    # node-level instance cap
    arena_ttl_s: float = 10.0
    n_workers: int = 2
    janitor: bool = True                      # per-runtime arena TTL evictor
    refill: bool = True                       # top pool back up after claim
    snapshot_dir: Optional[str] = None        # enables snapshot/restore
    # share the exe cache across platform boots; None = auto (ON whenever
    # snapshot_dir is set, so snapshot restore is zero-recompile across
    # boots by default). Pass False to opt out explicitly.
    persist_executables: Optional[bool] = None
    # bound per-histogram sample storage (reservoir above the bound;
    # count/sum stay exact) for this platform's metrics and every runtime
    # it boots — the gateway path sets metrics.DEFAULT_RESERVOIR so a
    # full-day replay's histograms stay O(bound). None = unbounded exact.
    hist_max_samples: Optional[int] = None

    def persist_executables_on(self) -> bool:
        if self.persist_executables is None:
            return bool(self.snapshot_dir)
        return self.persist_executables


class HydraPlatform:
    """Fleet manager: pool + placement + snapshot, one shared code cache."""

    def __init__(self, params: Optional[PlatformParams] = None, *,
                 exe_cache: Optional[ExecutableCache] = None, **kw):
        self.params = params or PlatformParams(**kw)
        p = self.params
        if exe_cache is None:
            persist = xla_dir = None
            if p.snapshot_dir and p.persist_executables_on():
                persist = os.path.join(p.snapshot_dir, "executables")
                # second persistence layer: jax's own compilation cache,
                # so even entries without a serialized payload (or with a
                # stale one) skip XLA on the next boot
                xla_dir = os.path.join(p.snapshot_dir, "xla_cache")
            exe_cache = ExecutableCache(persist_dir=persist,
                                        xla_cache_dir=xla_dir)
        self.exe_cache = exe_cache
        self.metrics = Metrics(hist_max_samples=p.hist_max_samples)
        self._lock = threading.RLock()
        self._pool: list[HydraRuntime] = []
        self._active: list[HydraRuntime] = []
        self._records: dict[str, _FunctionRecord] = {}
        self._refills: list[threading.Thread] = []
        self._booting = 0            # boot slots reserved but not finished
        self._stopping = False
        self.prewarm(p.pool_size)

    # ------------------------------------------------------------------
    # Pool
    # ------------------------------------------------------------------
    def _boot_runtime(self) -> HydraRuntime:
        p = self.params
        with self.metrics.timeit("runtime_boot_s"):
            rt = HydraRuntime(memory_budget_bytes=p.runtime_budget_bytes,
                              arena_ttl_s=p.arena_ttl_s,
                              n_workers=p.n_workers,
                              executable_cache=self.exe_cache,
                              janitor=p.janitor,
                              hist_max_samples=p.hist_max_samples)
        self.metrics.inc("runtime.boots")
        return rt

    def prewarm(self, n: Optional[int] = None) -> None:
        """Top the pool up to ``n`` (default: configured pool size)."""
        n = self.params.pool_size if n is None else n
        while True:
            with self._lock:
                # reserve a boot slot under the lock so concurrent refill
                # threads cannot overshoot the pool or the node cap
                if (self._stopping
                        or len(self._pool) + self._booting >= n
                        or (self.n_runtimes + self._booting
                            >= self.params.max_runtimes)):
                    return
                self._booting += 1
            rt = None
            try:
                rt = self._boot_runtime()
            finally:
                # release the slot and hand over the runtime atomically,
                # so another thread cannot reserve + append in between
                with self._lock:
                    self._booting -= 1
                    if rt is not None and not self._stopping:
                        self._pool.append(rt)
                        rt = None
            if rt is not None:       # booted into a closing platform
                rt.shutdown()
                return

    def _prune_refills(self) -> None:
        """Drop finished refill/resize threads from the bookkeeping list.
        Runs on EVERY claim (not only when a new refill spawns), so a long
        replay with ``refill=False`` phases cannot accumulate dead thread
        objects without bound."""
        with self._lock:
            self._refills = [x for x in self._refills if x.is_alive()]

    def _claim_runtime(self, ctx=None) -> HydraRuntime:
        """Pop a pre-warmed runtime; cold-boot only when the pool is dry.
        The replacement boot happens on a background thread — the claiming
        request never waits on it."""
        ctx = ctx or NULL_TRACE
        with ctx.span("pool_claim") as sp:
            self._prune_refills()
            t0 = time.perf_counter()
            with self._lock:
                rt = self._pool.pop() if self._pool else None
                if rt is None:
                    # reserve the boot slot atomically with the cap check
                    if (self.n_runtimes + self._booting
                            >= self.params.max_runtimes):
                        raise HydraError(
                            f"node runtime cap ({self.params.max_runtimes}) "
                            "reached; a multi-node platform would spill to "
                            "another host")
                    self._booting += 1
            if rt is not None:
                sp.set(source="pool")
                self.metrics.inc("pool.claim")
                with self._lock:
                    self._active.append(rt)
                # the whole warm handover — lock wait, pop, activation — so a
                # live replay can calibrate the simulator's pool_claim_s from
                # measured claims (core/calibrate)
                self.metrics.observe("pool_claim_s",
                                     time.perf_counter() - t0)
            else:
                sp.set(source="boot")
                self.metrics.inc("pool.miss")
                booted = None
                try:
                    booted = self._boot_runtime()
                finally:
                    with self._lock:
                        self._booting -= 1
                        if booted is not None:
                            self._active.append(booted)
                rt = booted
            if self.params.refill:
                t = threading.Thread(target=self.prewarm, daemon=True,
                                     name="hydra-pool-refill")
                t.start()
                with self._lock:
                    self._refills.append(t)
            return rt

    def _return_runtime(self, rt: HydraRuntime) -> None:
        """An emptied runtime goes back to the pool (or shuts down if the
        pool is already full)."""
        # release idle-arena budget immediately: a pooled instance must be
        # generic again, not carry reservations from its previous tenant
        rt.arena_pool.drain()
        with self._lock:
            if len(rt.registry) > 0 or rt not in self._active:
                return               # raced a placement (or already gone)
            self._active.remove(rt)
            if len(self._pool) < self.params.pool_size:
                self._pool.append(rt)
                returned = True
            else:
                returned = False
        if returned:
            self.metrics.inc("pool.return")
        else:
            rt.shutdown()
            self.metrics.inc("runtime.shutdowns")

    def resize_pool(self, n: int, *, background: bool = True) -> None:
        """Retarget the pre-warmed pool to ``n`` instances. Shrinking shuts
        surplus pooled runtimes down immediately (releasing their memory);
        growing tops the pool back up through ``prewarm`` — on a background
        thread by default, so the request that triggered an adaptive grow
        never waits on runtime boots. This is the knob the cluster's
        adaptive sizing policy turns."""
        n = max(0, int(n))
        extra = []
        with self._lock:
            self.params.pool_size = n
            while len(self._pool) > n:
                extra.append(self._pool.pop())
        for rt in extra:
            rt.shutdown()
            self.metrics.inc("runtime.shutdowns")
        if extra:
            self.metrics.inc("pool.shrink", len(extra))
        if background:
            t = threading.Thread(target=self.prewarm, daemon=True,
                                 name="hydra-pool-resize")
            t.start()
            with self._lock:
                self._refills = [x for x in self._refills
                                 if x.is_alive()] + [t]
        else:
            self.prewarm()

    @property
    def refill_backlog(self) -> int:
        """Refill/resize thread objects still tracked (for tests/stats)."""
        with self._lock:
            return len(self._refills)

    @property
    def pool_available(self) -> int:
        with self._lock:
            return len(self._pool)

    @property
    def n_runtimes(self) -> int:
        with self._lock:
            return len(self._pool) + len(self._active)

    # ------------------------------------------------------------------
    # Registration + placement
    # ------------------------------------------------------------------
    def register_function(self, fid: str, spec, *, tenant: str = "default",
                          mem_budget: Optional[int] = None,
                          eager: bool = False) -> bool:
        """Admit a function to the platform. Placement is lazy by default:
        the first invocation claims/packs a runtime (paper: pool instances
        are claimed on first invocation). ``eager=True`` places now, keeping
        even the arena cold start off the request path."""
        need = mem_budget or estimate_bytes(spec)
        if need > self.params.runtime_budget_bytes:
            # reject at admission (paper §3.1) instead of OOMing on the
            # first request: no runtime can ever host this function
            raise HydraOOMError(
                f"{fid}: needs {need} bytes, above the per-runtime budget "
                f"of {self.params.runtime_budget_bytes}")
        with self._lock:
            if fid in self._records:
                return False
            rec = _FunctionRecord(
                fid=fid, spec=spec, tenant=tenant, mem_budget=mem_budget,
                need_bytes=need,
                params_spec=jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                    spec.params))
            self._records[fid] = rec
        if eager:
            self._ensure_placed(rec)
        return True

    def _admitted_map(self) -> dict:
        """id(runtime) -> sum of placement estimates admitted onto it.
        Placement must check estimates against the runtime budget, not
        ``budget.free``: an estimate covers one live arena beyond the
        registration reservation, and that headroom is not reserved
        until the arena pool allocates it — packing by ``free`` would
        let later registrations eat earlier functions' arena headroom
        and OOM their first invocation. Caller holds ``self._lock``."""
        admitted: dict = {}
        for r in self._records.values():
            if r.runtime is not None:
                key = id(r.runtime)
                admitted[key] = admitted.get(key, 0) + r.need_bytes
        return admitted

    def _try_admit(self, rec: _FunctionRecord, rt: HydraRuntime) -> bool:
        """Atomically re-check budget/estimate headroom for ``rt`` and
        optimistically assign ``rec.runtime`` so RACING placements of
        other fids (serialized only by their own place_lock) see this
        admission in the estimate sum and cannot co-admit past the
        runtime budget. Caller must clear ``rec.runtime`` on failure."""
        with self._lock:
            if rt not in self._active:
                return False
            admitted = sum(r.need_bytes for r in self._records.values()
                           if r.runtime is rt)
            if (rt.budget.free < rec.need_bytes
                    or admitted + rec.need_bytes
                    > self.params.runtime_budget_bytes):
                return False
            rec.runtime = rt
            return True

    def _ensure_placed(self, rec: _FunctionRecord,
                       ctx=None) -> HydraRuntime:
        # per-record lock: racing first invocations of one fid must not
        # both run placement (the loser would register a zombie copy into
        # a second runtime)
        ctx = ctx or NULL_TRACE
        with rec.place_lock:
            if rec.runtime is not None:
                return rec.runtime
            if rec.evicted:
                raise FunctionNotRegisteredError(
                    f"{rec.fid} (evicted; call restore() first)")
            with self._lock:
                # colocation: pack into the fullest runtime that still
                # fits — first-fit-decreasing keeps spare runtimes empty
                # so they can drain back to the pool
                candidates = sorted(self._active,
                                    key=lambda r: r.budget.used,
                                    reverse=True)
                admitted = self._admitted_map()
            for rt in candidates:
                # lock-free pre-filter on the snapshot; _try_admit
                # re-checks the chosen runtime atomically
                if (rt.budget.free < rec.need_bytes
                        or (admitted.get(id(rt), 0) + rec.need_bytes
                            > self.params.runtime_budget_bytes)):
                    continue
                if not self._try_admit(rec, rt):
                    continue
                try:
                    with ctx.span("register"):
                        ok = rt.register_function(rec.fid, rec.spec,
                                                  tenant=rec.tenant,
                                                  mem_budget=rec.mem_budget)
                except HydraOOMError:
                    rec.runtime = None
                    continue        # raced/underestimated: try the next
                except BaseException:
                    # the optimistic admission must NEVER outlive a
                    # failed registration — a dangling rec.runtime would
                    # brick every future invocation of this fid
                    rec.runtime = None
                    raise
                if not ok:
                    rec.runtime = None
                    continue
                with self._lock:
                    still_active = rt in self._active
                if not still_active:
                    # raced an eviction that returned/shut down this
                    # runtime during registration
                    rt.deregister_function(rec.fid)
                    rec.runtime = None
                    continue
                self.metrics.inc("place.colocated")
                return rt
            # saturated everywhere: spill to a pool instance
            rt = self._claim_runtime(ctx)
            with self._lock:
                rec.runtime = rt     # visible to racing admission checks
            try:
                with ctx.span("register"):
                    ok = rt.register_function(rec.fid, rec.spec,
                                              tenant=rec.tenant,
                                              mem_budget=rec.mem_budget)
            except BaseException:
                rec.runtime = None
                self._return_runtime(rt)
                raise
            if not ok:
                rec.runtime = None
                self._return_runtime(rt)
                raise HydraError(f"placement of {rec.fid} rejected")
            self.metrics.inc("place.spill")
            return rt

    def _record(self, fid: str) -> _FunctionRecord:
        with self._lock:
            rec = self._records.get(fid)
        if rec is None:
            raise FunctionNotRegisteredError(fid)
        return rec

    def runtime_for(self, fid: str) -> HydraRuntime:
        """The runtime hosting ``fid`` (placing it first if needed)."""
        return self._ensure_placed(self._record(fid))

    def runtimes(self) -> list:
        """Point-in-time snapshot of every live runtime (pooled + active),
        safe to iterate while placement proceeds; the gateway recorder
        aggregates per-runtime arena/invocation counters through this."""
        with self._lock:
            return list(self._pool) + list(self._active)

    def function_records(self) -> list:
        """Point-in-time snapshot of this node's function records, safe
        to iterate while registrations proceed (cluster placement and
        rebalancing read these)."""
        with self._lock:
            return list(self._records.values())

    def placement(self) -> dict:
        """fid -> runtime index (active runtimes only), for introspection."""
        with self._lock:
            idx = {id(rt): i for i, rt in enumerate(self._active)}
            return {fid: idx[id(rec.runtime)]
                    for fid, rec in self._records.items()
                    if rec.runtime is not None}

    # ------------------------------------------------------------------
    # Request path
    # ------------------------------------------------------------------
    def invoke(self, fid: str, args: Any, ctx=None) -> Any:
        rec = self._record(fid)
        rt = self._ensure_placed(rec, ctx)
        rec.invocations += 1
        return rt.invoke(fid, args, ctx)

    def generate(self, fid: str, prompt_tokens, max_new_tokens: int = 16):
        rec = self._record(fid)
        rt = self._ensure_placed(rec)
        rec.invocations += 1
        return rt.generate(fid, prompt_tokens, max_new_tokens)

    # ------------------------------------------------------------------
    # Snapshot / evict / restore (paper: sandbox checkpointing)
    # ------------------------------------------------------------------
    def _snapshot_root(self, fid: str) -> str:
        if not self.params.snapshot_dir:
            raise HydraError("snapshot_dir not configured")
        safe = fid.replace("/", "__")
        return os.path.join(self.params.snapshot_dir, "functions", safe)

    def snapshot(self, fid: str) -> str:
        """Checkpoint weights + registry state for one function."""
        rec = self._record(fid)
        with rec.place_lock:     # atomic vs evict() nulling the weights
            return self._snapshot_locked(rec)

    def _snapshot_locked(self, rec: _FunctionRecord) -> str:
        if rec.evicted:
            # weights are gone from memory; the existing checkpoint is the
            # only copy — never overwrite it with an empty tree
            if rec.snapshot_path:
                return rec.snapshot_path
            raise HydraError(f"{rec.fid}: evicted without a snapshot")
        root = self._snapshot_root(rec.fid)
        with self.metrics.timeit("snapshot_s"):
            path = ckpt.save(root, 0, {"params": rec.spec.params})
            state = {"fid": rec.fid, "tenant": rec.tenant,
                     "mem_budget": rec.mem_budget,
                     "invocations": rec.invocations,
                     "kind": type(rec.spec).__name__}
            with open(os.path.join(root, "registry.json"), "w") as f:
                json.dump(state, f)
        rec.snapshot_path = root
        self.metrics.inc("snapshots")
        return path

    def evict(self, fid: str, *, snapshot: bool = True) -> None:
        """Deregister ``fid`` from its runtime (if placed), freeing budget;
        weights are snapshotted first so the function can be restored
        later, then dropped from host memory either way. A runtime left
        empty drains back to the pre-warmed pool."""
        rec = self._record(fid)
        with rec.place_lock:
            if rec.evicted:
                return
            if snapshot and rec.snapshot_path is None:
                self._snapshot_locked(rec)
            rt, rec.runtime = rec.runtime, None
            if rt is not None:
                rt.deregister_function(fid)
            # drop the weights so eviction actually releases memory; the
            # snapshot (or the caller's restore) is now the only copy
            rec.spec = dataclasses.replace(rec.spec, params=None)
            rec.evicted = True
            self.metrics.inc("evictions")
            if rt is not None and len(rt.registry) == 0:
                self._return_runtime(rt)

    def restore(self, fid: str, *, eager: bool = True, ctx=None) -> None:
        """Reload an evicted function from its snapshot into the fleet.
        Re-registration hits the shared ExecutableCache, so no request-path
        (or restore-path) compilation happens."""
        ctx = ctx or NULL_TRACE
        rec = self._record(fid)
        with rec.place_lock:
            if rec.runtime is not None:
                return
            if rec.evicted:
                if rec.snapshot_path is None:
                    raise HydraError(f"{fid}: no snapshot to restore from")
                with ctx.span("restore"):
                    with self.metrics.timeit("restore_s"):
                        tree = ckpt.restore(rec.snapshot_path, 0,
                                            {"params": rec.params_spec})
                rec.spec = dataclasses.replace(rec.spec,
                                               params=tree["params"])
                rec.evicted = False
                self.metrics.inc("restores")
        if eager:
            self._ensure_placed(rec, ctx)

    # ------------------------------------------------------------------
    # Migration hooks (used by HydraCluster to move a sandbox off-node)
    # ------------------------------------------------------------------
    def export_function(self, fid: str) -> dict:
        """Evict ``fid`` (snapshotting it first) and detach its portable
        record from this platform. The returned dict plus the on-disk
        snapshot are everything another node needs to ``import_function``
        and restore it — the cluster's cross-machine migration path."""
        rec = self._record(fid)
        self.evict(fid, snapshot=True)
        if rec.snapshot_path is None:
            # previously evicted without a snapshot: nothing to carry over
            # — refuse BEFORE detaching so the record is not orphaned
            raise HydraError(f"{fid}: cannot export without a snapshot")
        with self._lock:
            del self._records[fid]
        self.metrics.inc("exports")
        return {"fid": rec.fid, "spec": rec.spec, "tenant": rec.tenant,
                "mem_budget": rec.mem_budget, "need_bytes": rec.need_bytes,
                "params_spec": rec.params_spec,
                "invocations": rec.invocations,
                "snapshot_path": rec.snapshot_path}

    def import_function(self, exported: dict,
                        snapshot_path: Optional[str] = None) -> None:
        """Adopt a record produced by another platform's
        ``export_function``. The function arrives evicted; ``restore``
        (or the next cluster-level restore) brings it live from the
        snapshot — which must already sit under THIS node's reachable
        path (``snapshot_path`` overrides the exported one after a copy)."""
        path = snapshot_path or exported["snapshot_path"]
        if path is None:
            raise HydraError(f"{exported['fid']}: cannot import without a "
                             "snapshot")
        rec = _FunctionRecord(
            fid=exported["fid"], spec=exported["spec"],
            tenant=exported["tenant"], mem_budget=exported["mem_budget"],
            need_bytes=exported["need_bytes"],
            params_spec=exported["params_spec"],
            invocations=exported["invocations"],
            snapshot_path=path, evicted=True)
        with self._lock:
            if rec.fid in self._records:
                raise HydraError(f"{rec.fid}: already known to this node")
            self._records[rec.fid] = rec
        self.metrics.inc("imports")

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            active = list(self._active)
            n_pool = len(self._pool)
            n_funcs = sum(r.runtime is not None for r in
                          self._records.values())
            n_known = len(self._records)   # HL001: _records mutates under lock
        return {
            "runtimes_active": len(active),
            "runtimes_pooled": n_pool,
            "functions_placed": n_funcs,
            "functions_known": n_known,
            "budget_used": sum(rt.budget.used for rt in active),
            "exe_cache": self.exe_cache.stats(),
            "metrics": self.metrics.snapshot(),
        }

    def shutdown(self) -> None:
        with self._lock:
            self._stopping = True
            refills = list(self._refills)
        for t in refills:
            t.join(timeout=5.0)
        with self._lock:
            rts = self._pool + self._active
            self._pool, self._active = [], []
        for rt in rts:
            rt.shutdown()
