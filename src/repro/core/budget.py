"""Byte-accurate memory budgets (paper §3.1 registration budget + §3.7
scaling/OOM semantics)."""
from __future__ import annotations

import threading

from repro.core.errors import AdmissionError, HydraOOMError


class MemoryBudget:
    """Thread-safe byte accounting with a hard capacity.

    ``reserve`` raises — the paper's behaviour is an explicit OOM error when
    a function over-allocates, and admission failure when the runtime is
    saturated (a real deployment spills to another worker node).
    """

    def __init__(self, capacity_bytes: int, *, name: str = "runtime"):
        self.capacity = int(capacity_bytes)
        self.name = name
        self._used = 0
        self._peak = 0
        self._lock = threading.Lock()

    @property
    def used(self) -> int:
        with self._lock:                   # HL001: paired with reserve()
            return self._used

    @property
    def peak(self) -> int:
        with self._lock:
            return self._peak

    @property
    def free(self) -> int:
        with self._lock:
            return self.capacity - self._used

    def reserve(self, nbytes: int, *, admission: bool = False) -> None:
        nbytes = int(nbytes)
        with self._lock:
            if self._used + nbytes > self.capacity:
                err = AdmissionError if admission else HydraOOMError
                raise err(
                    f"{self.name}: reserve {nbytes} exceeds capacity "
                    f"{self.capacity} (used {self._used})")
            self._used += nbytes
            self._peak = max(self._peak, self._used)

    def release(self, nbytes: int) -> None:
        with self._lock:
            self._used = max(0, self._used - int(nbytes))

    def try_reserve(self, nbytes: int) -> bool:
        try:
            self.reserve(nbytes, admission=True)
            return True
        except AdmissionError:
            return False
