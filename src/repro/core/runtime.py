"""HydraRuntime: one virtualized runtime hosting many functions (paper §3).

The request path mirrors the paper's Listing 1:
  invoke -> registry lookup -> arena (isolate) acquire from pool ->
  AOT-compiled program execution -> arena release.

Registration (paper §3.1/§3.4) materializes weights and AOT-compiles every
entrypoint through the shared ExecutableCache — compilation NEVER happens on
the request path, converting runtime cold starts into arena cold starts.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arena import ArenaPool, tree_bytes
from repro.core.budget import MemoryBudget
from repro.core.executable_cache import ExecutableCache
from repro.core.metrics import Metrics
from repro.core.registry import (CallableSpec, Function, FunctionRegistry,
                                 LMSpec)
from repro.core.tracing import NULL_TRACE, trace_now
from repro.models.programs import ModelProgram

GB = 1 << 30


def registration_budget(spec, prog=None) -> tuple:
    """(registration reservation bytes, one-arena bytes) for a spec — the
    single source of truth for admission math, shared by the runtime's
    reservation and the platform's placement estimate. Pass ``prog`` when
    an LMSpec's ModelProgram is already built."""
    if isinstance(spec, CallableSpec):
        reserve = (tree_bytes(spec.example_args) + tree_bytes(spec.params)
                   + spec.arena_bytes)
        return reserve, spec.arena_bytes
    if isinstance(spec, LMSpec):
        prog = prog or ModelProgram(spec.cfg, remat=False)
        cache = prog.cache_bytes(spec.slots, spec.max_seq)
        return tree_bytes(spec.params) + cache, cache
    raise TypeError(type(spec))


class HydraRuntime:
    def __init__(self, *,
                 memory_budget_bytes: int = 2 * GB,  # paper: 2 GB per runtime
                 arena_ttl_s: float = 10.0,
                 n_workers: int = 4,
                 executable_cache: Optional[ExecutableCache] = None,
                 janitor: bool = True,
                 hist_max_samples: Optional[int] = None):
        self.metrics = Metrics(hist_max_samples=hist_max_samples)
        self.budget = MemoryBudget(memory_budget_bytes, name="hydra")
        self.registry = FunctionRegistry()
        self.exe_cache = executable_cache or ExecutableCache()
        self.arena_pool = ArenaPool(budget=self.budget, ttl_s=arena_ttl_s,
                                    metrics=self.metrics,
                                    exe_cache=self.exe_cache)
        self._queue: "queue.Queue" = queue.Queue()
        self._workers = [threading.Thread(target=self._worker_loop,
                                          daemon=True, name=f"hydra-w{i}")
                         for i in range(n_workers)]
        self._shutdown = threading.Event()
        for w in self._workers:
            w.start()
        self._janitor = None
        if janitor:
            self._janitor = threading.Thread(target=self._janitor_loop,
                                             daemon=True, name="hydra-janitor")
            self._janitor.start()

    # ------------------------------------------------------------------
    # Registration (paper §3.1)
    # ------------------------------------------------------------------
    def register_function(self, fid: str, spec, *, tenant: str = "default",
                          # hydralint: disable=HL002 — registration on first
                          # invocation is the modeled fn_register_s cost:
                          # jit/compile + snapshot I/O hit the shared
                          # ExecutableCache, not the steady-state path
                          mem_budget: Optional[int] = None) -> bool:
        with self.metrics.timeit("register_s"):
            if isinstance(spec, CallableSpec):
                func = self._register_callable(fid, spec, tenant, mem_budget)
            elif isinstance(spec, LMSpec):
                func = self._register_lm(fid, spec, tenant, mem_budget)
            else:
                raise TypeError(type(spec))
        ok = self.registry.add(func)
        if not ok:
            self.budget.release(func.mem_budget)
        self.metrics.inc("registered", int(ok))
        return ok

    def _register_callable(self, fid, spec: CallableSpec, tenant,
                           mem_budget) -> Function:
        budget = mem_budget or registration_budget(spec)[0]
        self.budget.reserve(budget)
        args_spec = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            spec.example_args)
        params_spec = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), spec.params)
        shapes_key = tuple(
            (tuple(x.shape), str(x.dtype))
            for x in jax.tree.leaves((params_spec, args_spec)))
        key = ("callable", spec.name, shapes_key)
        # fresh closure: defeat jax's in-process pjit cache so executable
        # sharing is provided (and measured) by OUR ExecutableCache only
        raw = spec.fn
        fresh = lambda p, a: raw(p, a)
        entry = self.exe_cache.get_or_compile(
            key, lambda: jax.jit(fresh).lower(params_spec, args_spec),
            fid=fid)
        nb = max(spec.arena_bytes, 8)
        # the factory mints a slab at most once per pooled arena (cold
        # path only); host-zeros + device_put keeps the mint itself free
        # of per-size XLA fill kernels. Warm claims never run this: the
        # slab allocator hands back pooled device memory, scrubbed by the
        # per-signature donate-in-place zeroer registered below
        factory = lambda: {"scratch": jax.device_put(
            np.zeros((nb // 4,), np.float32))}
        arena_sig = ("scratch", nb)
        self.arena_pool.register_signature(
            arena_sig, factory,
            {"scratch": jax.ShapeDtypeStruct((nb // 4,), jnp.float32)})
        return Function(fid=fid, tenant=tenant, spec=spec, mem_budget=budget,
                        entry={"invoke": entry.compiled},
                        arena_sig=arena_sig, arena_factory=factory)

    def _register_lm(self, fid, spec: LMSpec, tenant, mem_budget) -> Function:
        prog = ModelProgram(spec.cfg, remat=False)
        B, S = spec.slots, spec.max_seq
        cache_specs = prog.cache_specs(B, S)
        budget = mem_budget or registration_budget(spec, prog)[0]
        self.budget.reserve(budget)
        params_spec = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), spec.params)
        fkey = spec.family_key

        # decode+greedy-sample fused step over all slots (cache donated)
        def decode_sample(params, cache, tokens):
            logits, new_cache = prog.decode_step(params, cache,
                                                 {"tokens": tokens})
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache

        tok_spec = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        entry_dec = self.exe_cache.get_or_compile(
            fkey + ("decode",),
            lambda: jax.jit(decode_sample, donate_argnums=(1,)).lower(
                params_spec, cache_specs, tok_spec),
            fid=fid)

        def factory():
            return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                cache_specs)

        self.arena_pool.register_signature(("lm",) + fkey, factory,
                                           cache_specs)
        func = Function(fid=fid, tenant=tenant, spec=spec, mem_budget=budget,
                        entry={"decode": entry_dec.compiled},
                        arena_sig=("lm",) + fkey, arena_factory=factory)
        func.prog = prog
        func.params_spec = params_spec
        return func

    def prewarm_arenas(self, fid: str, n: int = 1) -> None:
        """Pre-touch ``n`` slabs for ``fid``'s arena signature off the
        clock, so the function's first invocations are allocation-free
        (paper: pre-allocated cached isolates)."""
        func = self.registry.get(fid)
        self.arena_pool.prealloc(func.arena_sig, func.arena_factory, n,
                                 owner=fid)

    def _lm_prefill_exe(self, func: Function, prompt_len: int):
        """Exact-length prefill program, AOT-compiled + cached on first use
        of this prompt length (production would use length buckets)."""
        spec: LMSpec = func.spec
        prog: ModelProgram = func.prog
        key = spec.family_key + ("prefill", prompt_len)

        def prefill_insert(params, arena_cache, tokens, slot):
            """prefill (1, prompt_len) then write into the given slot of the
            arena cache slab (donated)."""
            logits, cache = prog.prefill(params, {"tokens": tokens})
            out = dict(arena_cache)
            for k in cache:
                if k == "length":
                    out[k] = arena_cache[k].at[slot].set(prompt_len)
                else:
                    dst, src = out[k], cache[k]
                    pad = [(0, a - b) for a, b in zip(dst.shape, src.shape)]
                    start = [jnp.int32(0)] * dst.ndim
                    start[1] = slot  # batch/slot axis is dim 1 (L, B, ...)
                    src = jnp.pad(src, pad).astype(dst.dtype)
                    # src padded to full slab shape; restrict to one slot row
                    src = jax.lax.slice_in_dim(src, 0, 1, axis=1)
                    out[k] = jax.lax.dynamic_update_slice(
                        dst, src, tuple(start))
            first_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return first_tok, out

        cache_specs = prog.cache_specs(spec.slots, spec.max_seq)
        tok_spec = jax.ShapeDtypeStruct((1, prompt_len), jnp.int32)
        slot_spec = jax.ShapeDtypeStruct((), jnp.int32)
        entry = self.exe_cache.get_or_compile(
            key, lambda: jax.jit(prefill_insert, donate_argnums=(1,)).lower(
                func.params_spec, cache_specs, tok_spec, slot_spec),
            fid=func.fid)
        return entry.compiled

    # ------------------------------------------------------------------
    # Invocation (paper Listing 1)
    # ------------------------------------------------------------------
    def invoke(self, fid: str, args: Any, ctx=None) -> Any:
        return self.invoke_async(fid, args, ctx).result()

    def invoke_async(self, fid: str, args: Any, ctx=None) -> Future:
        # the trace context rides the queue item: the worker thread that
        # dequeues it continues the same request's spans (contextvars
        # would not survive this thread hop)
        fut: Future = Future()
        self._queue.put(("invoke", fid, args, time.perf_counter(), fut, ctx))
        return fut

    def generate(self, fid: str, prompt_tokens, max_new_tokens: int = 16):
        fut: Future = Future()
        self._queue.put(("generate", fid, (prompt_tokens, max_new_tokens),
                         time.perf_counter(), fut, None))
        return fut.result()

    def deregister_function(self, fid: str) -> bool:
        try:
            func = self.registry.get(fid)
        except Exception:
            return False
        ok = self.registry.remove(fid)
        if ok:
            self.budget.release(func.mem_budget)
            self.metrics.inc("deregistered")
        return ok

    # ------------------------------------------------------------------
    def _worker_loop(self):
        while not self._shutdown.is_set():
            try:
                item = self._queue.get(timeout=0.1)
            except queue.Empty:
                continue
            kind, fid, args, t_enq, fut, ctx = item
            if ctx is not None and ctx.sampled:
                # t_enq is already trace_now()'s clock (perf_counter)
                ctx.add_span("dispatch", t_enq, trace_now())
            try:
                if kind == "invoke":
                    result = self._do_invoke(fid, args, ctx)
                else:
                    result = self._do_generate(fid, *args)
                self.metrics.observe("invoke_latency_s",
                                     time.perf_counter() - t_enq)
                fut.set_result(result)
            except Exception as e:  # surface to caller
                fut.set_exception(e)

    def _do_invoke(self, fid: str, args, ctx=None):
        ctx = ctx or NULL_TRACE
        func = self.registry.get(fid)
        func.invocations += 1
        arena = self.arena_pool.acquire(func.arena_sig, func.arena_factory,
                                        owner=fid, ctx=ctx)
        try:
            with ctx.span("compute"):
                result = func.entry["invoke"](func.spec.params, args)
                result = jax.block_until_ready(result)
        finally:
            self.arena_pool.release(arena)
        return result

    def _do_generate(self, fid: str, prompt_tokens, max_new: int):
        func = self.registry.get(fid)
        func.invocations += 1
        spec: LMSpec = func.spec
        prompt = jnp.asarray(prompt_tokens, jnp.int32).reshape(1, -1)
        prefill_exe = self._lm_prefill_exe(func, prompt.shape[1])
        arena = self.arena_pool.acquire(func.arena_sig, func.arena_factory,
                                        owner=fid)
        try:
            tok, cache = prefill_exe(spec.params, arena.buffers, prompt,
                                     jnp.int32(0))
            toks = [int(tok[0])]
            tok = jnp.tile(tok.reshape(1, 1), (spec.slots, 1))
            for _ in range(max_new - 1):
                tok, cache = func.entry["decode"](spec.params, cache, tok)
                toks.append(int(tok[0]))
                tok = tok.reshape(spec.slots, 1)
            arena.buffers = cache   # donated in place; hand back the slab
        finally:
            self.arena_pool.release(arena)
        return toks

    def _janitor_loop(self):
        while not self._shutdown.is_set():
            time.sleep(min(1.0, self.arena_pool.ttl_s / 4))
            self.arena_pool.evict_idle()

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        return {
            "functions": len(self.registry),
            "budget_used": self.budget.used,
            "budget_peak": self.budget.peak,
            "arena": self.arena_pool.stats(),
            "exe_cache": self.exe_cache.stats(),
            "metrics": self.metrics.snapshot(),
        }

    def shutdown(self):
        self._shutdown.set()
        for w in self._workers:
            w.join(timeout=2.0)
        if self._janitor:
            self._janitor.join(timeout=2.0)
        self.arena_pool.drain()
