"""ExecutableCache: AOT compilation + code-cache sharing (paper §3.3/§3.4).

Programs are compiled ONCE per *program signature* — (architecture family,
entrypoint, abstract shapes, mesh, dtype) — with weights passed as traced
arguments, never closed over. Every tenant whose function shares a signature
therefore shares a single compiled executable: the analog of Graalvisor
co-locating Truffle contexts of one function so JIT code caches are reused.

Compilation happens at registration (AOT, paper §3.4 Native Image analog),
never on the request path. Optionally executables are persisted to disk via
``jax.experimental.serialize_executable`` so a restarted runtime skips
recompilation entirely (the Native-Image-binary-on-disk analog).
"""
from __future__ import annotations

import hashlib
import os
import pickle
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional


_XLA_CACHE_DIR: Optional[str] = None


def enable_persistent_compilation_cache(cache_dir: str) -> bool:
    """Point jax's process-global persistent compilation cache at
    ``cache_dir`` so XLA compilations are written to disk and replayed by
    later processes (layered UNDER our ``serialize_executable`` payloads:
    even when an entry's pickle is stale, the recompile becomes a cache
    read instead of a real XLA run).

    The thresholds are lowered to cache everything — serverless programs
    are small and compile fast, exactly the entries the defaults skip.
    Returns True when the cache is active; False (and stays inert) on jax
    builds without the experimental API.
    """
    global _XLA_CACHE_DIR
    if _XLA_CACHE_DIR == cache_dir:
        return True
    try:
        import jax
        from jax.experimental.compilation_cache import (
            compilation_cache as cc)
        os.makedirs(cache_dir, exist_ok=True)
        cc.set_cache_dir(cache_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        try:
            jax.config.update("jax_persistent_cache_min_entry_size_bytes",
                              -1)
        except Exception:
            pass  # knob absent on older jax: size floor stays default
        _XLA_CACHE_DIR = cache_dir
        return True
    except Exception:
        return False


@dataclass
class CacheEntry:
    key: tuple
    compiled: Any
    compile_s: float
    hits: int = 0
    created_at: float = field(default_factory=time.monotonic)


class ExecutableCache:
    def __init__(self, persist_dir: Optional[str] = None,
                 shared: bool = True,
                 xla_cache_dir: Optional[str] = None):
        """``shared=False`` emulates the per-context-JIT baseline (every
        registration compiles its own copy) for the Fig 4 experiment.

        ``xla_cache_dir``: enable jax's persistent compilation cache at
        this path (process-global; see
        ``enable_persistent_compilation_cache``)."""
        self._entries: dict[tuple, CacheEntry] = {}
        self._lock = threading.Lock()
        self.persist_dir = persist_dir
        self.shared = shared
        self.total_compile_s = 0.0
        self.compiles = 0        # actual XLA compilations (not disk loads)
        self.disk_hits = 0       # executables deserialized from persist_dir
        self.xla_cache_enabled = (
            enable_persistent_compilation_cache(xla_cache_dir)
            if xla_cache_dir else False)
        if persist_dir:
            os.makedirs(persist_dir, exist_ok=True)

    # ------------------------------------------------------------------
    def _disk_path(self, key: tuple) -> Optional[str]:
        if not self.persist_dir:
            return None
        # stable across processes (builtin hash() is salted per process,
        # which would make every restart miss its own persisted files)
        h = hashlib.sha256(repr(key).encode()).hexdigest()[:16]
        return os.path.join(self.persist_dir, f"exe_{h}.bin")

    def get_or_compile(self, key: tuple,
                       lower_fn: Callable[[], Any],
                       *, fid: Optional[str] = None) -> CacheEntry:
        """lower_fn() must return a jax ``Lowered`` (we .compile() it)."""
        if not self.shared and fid is not None:
            key = key + ("fid", fid)
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                entry.hits += 1
                return entry

        compiled = None
        t0 = time.perf_counter()
        path = self._disk_path(key)
        if path and os.path.exists(path):
            try:
                from jax.experimental import serialize_executable as se
                with open(path, "rb") as f:
                    payload, in_tree, out_tree = pickle.load(f)
                compiled = se.deserialize_and_load(payload, in_tree, out_tree)
            except Exception:
                compiled = None  # stale/incompatible snapshot: recompile
        loaded_from_disk = compiled is not None
        if compiled is None:
            lowered = lower_fn()
            compiled = lowered.compile()
            if path:
                try:
                    from jax.experimental import serialize_executable as se
                    payload, in_tree, out_tree = se.serialize(compiled)
                    tmp = path + ".tmp"
                    with open(tmp, "wb") as f:
                        pickle.dump((payload, in_tree, out_tree), f)
                    os.replace(tmp, path)
                except Exception:
                    pass
        compile_s = time.perf_counter() - t0

        entry = CacheEntry(key=key, compiled=compiled, compile_s=compile_s)
        with self._lock:
            # racing registration of the same signature: first one wins
            existing = self._entries.get(key)
            if existing is not None:
                existing.hits += 1
                return existing
            self._entries[key] = entry
            self.total_compile_s += compile_s
            if loaded_from_disk:
                self.disk_hits += 1
            else:
                self.compiles += 1
        return entry

    # ------------------------------------------------------------------
    def contains(self, key: tuple) -> bool:
        with self._lock:
            return key in self._entries

    def invalidate(self, key: tuple) -> None:
        with self._lock:
            self._entries.pop(key, None)

    def stats(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._entries),
                "hits": sum(e.hits for e in self._entries.values()),
                "compiles": self.compiles,
                "disk_hits": self.disk_hits,
                "total_compile_s": self.total_compile_s,
                "xla_cache_enabled": self.xla_cache_enabled,
            }
