"""Hydra runtime error types (paper §3.1/§3.7 semantics)."""


class HydraError(Exception):
    pass


class FunctionNotRegisteredError(HydraError):
    """Invocation of an unknown fid (paper Listing 1, line 24)."""


class HydraOOMError(HydraError):
    """A function over-allocated its memory budget (paper §3.7)."""


class AdmissionError(HydraError):
    """Runtime-level capacity exhausted; request must go to another worker."""
