"""HydraCluster: cross-machine placement, spill, migration, and adaptive
pool sizing over N per-node ``HydraPlatform``s.

The paper's headline density wins (2.41x ops/GB-sec vs OpenWhisk, 21-44%
lower footprint on the Azure trace) come from colocation-aware placement
across a *fleet* of machines; ``HydraPlatform`` manages one host. This
layer adds what the fleet needs:

  * **Cross-node placement** — a new function packs onto the node already
    hosting its tenant (colocation keeps code/arena sharing local) while
    that node's memory budget holds, and spills to the least-committed
    node when it saturates. Admission fails only when no node can fit it.
  * **Snapshot migration** — ``migrate`` moves a live function between
    nodes through the ``ft/checkpoint`` sandbox snapshot: evict+export on
    the source, copy the snapshot across (charged an explicit transfer
    cost at ``transfer_gbps``), import+restore on the destination. The
    fleet shares one ``ExecutableCache``, so the restored function serves
    with zero recompilation. ``rebalance`` uses this to drain overloaded
    nodes into underloaded ones.
  * **Adaptive pool sizing** — instead of a fixed per-node ``pool_size``,
    an EWMA arrival-rate estimator per node drives the pre-warmed pool:
    bursts grow it toward ``pool_max`` (so claims, not cold boots, absorb
    the burst), idle periods shrink it to ``pool_min`` (releasing memory),
    and the target never commits more memory than the node budget allows.

The tracesim twin of this layer is the ``"hydra-cluster"`` model in
``repro.core.tracesim``; ``benchmarks/bench_trace.py`` sweeps it 1-8 nodes.
"""
from __future__ import annotations

import math
import os
import shutil
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

from repro.core.errors import (FunctionNotRegisteredError, HydraError,
                               HydraOOMError)
from repro.core.executable_cache import ExecutableCache
from repro.core.metrics import Metrics
from repro.core.platform import (GB, HydraPlatform, PlatformParams,
                                 estimate_bytes)


class ArrivalRateEstimator:
    """EWMA arrival-rate estimator over inter-arrival gaps.

    ``observe(t)`` folds the instantaneous rate ``1/gap`` into an EWMA;
    ``rate(now)`` caps the estimate by the most recent inter-arrival gap
    (and by ``1/(now - last)`` when queried later), so a stream that goes
    quiet collapses toward zero instead of holding its burst-time
    estimate forever, while in-burst arrivals keep the smoothed estimate.
    """

    def __init__(self, alpha: float = 0.3):
        self.alpha = alpha
        self._rate = 0.0
        self._gap: Optional[float] = None
        self._last: Optional[float] = None

    def observe(self, t: float) -> None:
        if self._last is not None:
            gap = max(t - self._last, 1e-9)
            self._gap = gap
            self._rate = (1.0 - self.alpha) * self._rate + self.alpha / gap
        self._last = max(t, self._last or t)

    def rate(self, now: Optional[float] = None) -> float:
        if self._last is None:
            return 0.0
        r = self._rate
        if self._gap is not None:
            r = min(r, 1.0 / self._gap)
        if now is not None and now > self._last:
            r = min(r, 1.0 / (now - self._last))
        return r


@dataclass
class AdaptivePoolPolicy:
    """Map an arrival-rate estimate to a pre-warmed pool target.

    The pool should hold enough warm runtimes to absorb the arrivals that
    land during one cold boot window (``cover_s``), clamped to
    ``[pool_min, pool_max]`` and to what the node's memory budget can
    still commit (``runtime_bytes`` per pooled instance).
    """
    pool_min: int = 1
    pool_max: int = 8
    cover_s: float = 1.0
    runtime_bytes: int = 2 * GB

    def target(self, rate: float, free_bytes: Optional[int] = None) -> int:
        want = math.ceil(rate * self.cover_s)
        want = max(self.pool_min, min(self.pool_max, want))
        if free_bytes is not None:
            want = min(want, max(0, int(free_bytes // self.runtime_bytes)))
        return want


@dataclass
class ClusterParams:
    n_nodes: int = 2
    node_memory_bytes: int = 16 * GB     # per-node placement budget
    transfer_gbps: float = 10.0          # cross-node snapshot bandwidth
    share_exe_cache: bool = True         # one fleet-wide executable cache
    snapshot_dir: Optional[str] = None   # root; nodes use <dir>/nodeN/
    # adaptive pool sizing
    adaptive_pool: bool = True
    pool_min: int = 2
    pool_max: int = 4
    pool_cover_s: float = 2.0            # arrivals one boot window absorbs
    ewma_alpha: float = 0.5
    resize_every: int = 8                # invocations between pool resizes
    # template for each node's platform (snapshot_dir is set per node)
    platform: PlatformParams = field(default_factory=PlatformParams)


@dataclass
class _NodeState:
    idx: int
    platform: HydraPlatform
    committed: int = 0                   # placement-estimate bytes placed
    estimator: ArrivalRateEstimator = field(
        default_factory=ArrivalRateEstimator)
    since_resize: int = 0


class HydraCluster:
    """N machines, one serverless fleet: placement, spill, migration,
    adaptive pools — over per-node ``HydraPlatform``s."""

    def __init__(self, params: Optional[ClusterParams] = None, **kw):
        self.params = params or ClusterParams(**kw)
        p = self.params
        if p.n_nodes < 1:
            raise HydraError("cluster needs at least one node")
        self.metrics = Metrics()
        self._lock = threading.RLock()
        self.exe_cache = None
        if p.share_exe_cache:
            # the fleet-wide cache persists to disk whenever the cluster
            # has a snapshot root, unless the platform template explicitly
            # opted out (persist_executables=False) — matching the
            # platform-level default of zero-recompile restores across
            # boots
            persist = xla_dir = None
            if p.snapshot_dir and p.platform.persist_executables is not False:
                persist = os.path.join(p.snapshot_dir, "executables")
                xla_dir = os.path.join(p.snapshot_dir, "xla_cache")
            self.exe_cache = ExecutableCache(persist_dir=persist,
                                             xla_cache_dir=xla_dir)
        self.nodes: list[_NodeState] = []
        for i in range(p.n_nodes):
            plat_params = PlatformParams(**vars(p.platform))
            if p.snapshot_dir:
                plat_params.snapshot_dir = os.path.join(p.snapshot_dir,
                                                        f"node{i}")
            plat = HydraPlatform(plat_params, exe_cache=self.exe_cache)
            self.nodes.append(_NodeState(idx=i, platform=plat))
        self._node_of: dict[str, int] = {}
        # fids with a migration in flight; request routing waits on the
        # condition so no invocation lands in the export->import window
        self._migrating: set = set()
        self._migrate_cv = threading.Condition(self._lock)
        self._policy = AdaptivePoolPolicy(
            pool_min=p.pool_min, pool_max=p.pool_max, cover_s=p.pool_cover_s,
            runtime_bytes=p.platform.runtime_budget_bytes)

    # ------------------------------------------------------------------
    # Placement
    # ------------------------------------------------------------------
    def _pick_node(self, tenant: str, need: int) -> _NodeState:
        """Pack-first: the tenant's most-committed node that still fits;
        else spill to the least-committed node with room."""
        with self._lock:
            cap = self.params.node_memory_bytes
            # nodes already hosting this tenant, most-committed first
            hosting = []
            for node in self.nodes:
                if any(r.tenant == tenant
                       for r in node.platform.function_records()):
                    hosting.append(node)
            hosting.sort(key=lambda n: n.committed, reverse=True)
            for node in hosting:
                if node.committed + need <= cap:
                    self.metrics.inc("place.colocated")
                    return node
            spill = sorted(self.nodes, key=lambda n: n.committed)
            for node in spill:
                if node.committed + need <= cap:
                    if hosting:
                        self.metrics.inc("place.spill")
                    return node
        raise HydraOOMError(
            f"no node can fit {need} bytes (per-node budget "
            f"{self.params.node_memory_bytes}, "
            f"{self.params.n_nodes} nodes)")

    def register_function(self, fid: str, spec, *, tenant: str = "default",
                          mem_budget: Optional[int] = None,
                          eager: bool = False) -> bool:
        """Admit ``fid`` to the fleet: colocation-aware node choice, then
        delegate to that node's platform (which does runtime-level
        packing). Returns False if the fid is already known."""
        need = mem_budget or estimate_bytes(spec)
        # reserve the fid + its budget atomically so racing registrations
        # of one fid cannot both pick a node (the loser would strand a
        # zombie copy and inflate that node's committed bytes)
        with self._lock:
            if fid in self._node_of:
                return False
            node = self._pick_node(tenant, need)
            self._node_of[fid] = node.idx
            node.committed += need
        try:
            ok = node.platform.register_function(fid, spec, tenant=tenant,
                                                 mem_budget=mem_budget,
                                                 eager=eager)
        except BaseException:
            ok = False
            raise
        finally:
            if not ok:
                with self._lock:
                    self._node_of.pop(fid, None)
                    node.committed -= need
        return ok

    def _settled_node_idx(self, fid: str):
        """fid's node index, waiting out any in-flight migration first."""
        with self._migrate_cv:
            while fid in self._migrating:
                self._migrate_cv.wait(timeout=30.0)
            return self._node_of.get(fid)

    def node_for(self, fid: str) -> HydraPlatform:
        """The per-node platform hosting ``fid``."""
        idx = self._settled_node_idx(fid)
        if idx is None:
            raise FunctionNotRegisteredError(fid)
        return self.nodes[idx].platform

    def runtime_for(self, fid: str):
        """The runtime hosting ``fid`` (placing it on its node if needed)."""
        return self.node_for(fid).runtime_for(fid)

    def placement(self) -> dict:
        """fid -> node index, for introspection."""
        with self._lock:
            return dict(self._node_of)

    # ------------------------------------------------------------------
    # Request path
    # ------------------------------------------------------------------
    def observe_arrival(self, fid: str,
                        now: Optional[float] = None) -> None:
        """Feed one arrival for ``fid`` into its node's rate estimator
        (and retarget that node's pool when due). ``invoke``/``generate``
        do this automatically; drivers that route requests to runtimes
        directly (e.g. a batcher holding ``runtime_for(fid)``) call this
        per request so adaptive pool sizing still sees the load."""
        self._on_arrival(fid, now)

    def _on_arrival(self, fid: str, now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        idx = self._settled_node_idx(fid)
        with self._lock:
            if idx is None:
                raise FunctionNotRegisteredError(fid)
            node = self.nodes[idx]
            node.estimator.observe(now)
            node.since_resize += 1
            resize = (self.params.adaptive_pool
                      and node.since_resize >= self.params.resize_every)
            if resize:
                node.since_resize = 0
        if resize:
            self._resize_node_pool(node, now)
        return node

    def _resize_node_pool(self, node: _NodeState, now: float) -> None:
        free = self.params.node_memory_bytes - node.committed
        target = self._policy.target(node.estimator.rate(now),
                                     free_bytes=free)
        if target != node.platform.params.pool_size:
            self.metrics.inc("pool.resize")
            node.platform.resize_pool(target)

    def _maybe_restore(self, node: _NodeState, fid: str, ctx=None) -> None:
        # a migrated/rebalanced function arrives on its new node evicted;
        # the next invocation restores it lazily from the local snapshot
        rec = node.platform._records.get(fid)
        if rec is not None and rec.evicted:
            node.platform.restore(fid, eager=False, ctx=ctx)

    def invoke(self, fid: str, args, *, now: Optional[float] = None,
               ctx=None):
        node = self._on_arrival(fid, now)
        self._maybe_restore(node, fid, ctx)
        return node.platform.invoke(fid, args, ctx)

    def generate(self, fid: str, prompt_tokens, max_new_tokens: int = 16, *,
                 now: Optional[float] = None):
        node = self._on_arrival(fid, now)
        self._maybe_restore(node, fid)
        return node.platform.generate(fid, prompt_tokens, max_new_tokens)

    # ------------------------------------------------------------------
    # Migration + rebalancing
    # ------------------------------------------------------------------
    def _transfer(self, src_root: str, dst_root: str) -> int:
        """Copy a function's snapshot tree to the destination node's
        snapshot area; returns bytes moved and charges the explicit
        cross-node transfer cost (bytes / transfer_gbps) to metrics."""
        nbytes = 0
        for root, _, files in os.walk(src_root):
            for f in files:
                nbytes += os.path.getsize(os.path.join(root, f))
        if os.path.abspath(src_root) != os.path.abspath(dst_root):
            if os.path.exists(dst_root):
                shutil.rmtree(dst_root)
            shutil.copytree(src_root, dst_root)
        cost_s = nbytes / (self.params.transfer_gbps * 1e9 / 8)
        self.metrics.observe("transfer_s", cost_s)
        self.metrics.inc("transfer_bytes", nbytes)
        return nbytes

    def migrate(self, fid: str, dst_idx: int, *, eager: bool = True) -> int:
        """Move ``fid`` to node ``dst_idx`` through its sandbox snapshot:
        evict+export on the source, transfer the snapshot (explicit cost),
        import+restore on the destination. Returns bytes transferred."""
        with self._migrate_cv:
            while fid in self._migrating:
                self._migrate_cv.wait(timeout=30.0)
            src_idx = self._node_of.get(fid)
            if src_idx is None:
                raise FunctionNotRegisteredError(fid)
            if not (0 <= dst_idx < len(self.nodes)):
                raise HydraError(f"no such node: {dst_idx}")
            src, dst = self.nodes[src_idx], self.nodes[dst_idx]
            if src_idx == dst_idx:
                return 0
            # mark in flight: request routing blocks in _settled_node_idx
            # until the record is importable on the destination
            self._migrating.add(fid)
        try:
            exported = src.platform.export_function(fid)
            try:
                dst_path = dst.platform._snapshot_root(fid)
                nbytes = self._transfer(exported["snapshot_path"],
                                        dst_path)
                dst.platform.import_function(exported,
                                             snapshot_path=dst_path)
            except Exception:
                # roll back: re-adopt the exported record on the source
                # node so a failed transfer/import never orphans the fid
                src.platform.import_function(exported)
                raise
            with self._lock:
                self._node_of[fid] = dst_idx
                src.committed -= exported["need_bytes"]
                dst.committed += exported["need_bytes"]
        finally:
            with self._migrate_cv:
                self._migrating.discard(fid)
                self._migrate_cv.notify_all()
        if eager:
            dst.platform.restore(fid)
        self.metrics.inc("migrations")
        return nbytes

    def rebalance(self, *, max_moves: int = 8) -> list:
        """Drain the most-committed node into the least-committed one by
        migrating its smallest functions until the spread drops below one
        function's footprint. Returns [(fid, src, dst), ...].

        Runs mid-burst under the gateway's ``ClusterBalancer``, so the
        call and its moves are counted in cluster metrics
        (``rebalance.calls``/``rebalance.moves``) for the live-vs-sim
        migration accounting."""
        self.metrics.inc("rebalance.calls")
        moves = []
        for _ in range(max_moves):
            with self._lock:
                order = sorted(self.nodes, key=lambda n: n.committed)
                lo, hi = order[0], order[-1]
                cands = sorted(hi.platform.function_records(),
                               key=lambda r: r.need_bytes)
            if not cands:
                break
            rec = cands[0]
            # moving it must strictly shrink the spread, or we are done
            if hi.committed - lo.committed <= rec.need_bytes:
                break
            self.migrate(rec.fid, lo.idx, eager=False)
            moves.append((rec.fid, hi.idx, lo.idx))
        if moves:
            self.metrics.inc("rebalance.moves", len(moves))
        return moves

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            per_node = []
            for node in self.nodes:
                s = node.platform.stats()
                s["committed_bytes"] = node.committed
                s["pool_target"] = node.platform.params.pool_size
                per_node.append(s)
            return {
                "n_nodes": len(self.nodes),
                "functions_known": len(self._node_of),
                "nodes": per_node,
                "metrics": self.metrics.snapshot(),
                "exe_cache": (self.exe_cache.stats()
                              if self.exe_cache else None),
            }

    def shutdown(self) -> None:
        for node in self.nodes:
            node.platform.shutdown()
