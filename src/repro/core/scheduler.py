"""Request scheduling: continuous batching for decode (the high-density
serving analog of the paper's many-isolates-per-runtime) and per-tenant
token buckets (the cgroup CPU-share analog, §3.7).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field

import jax.numpy as jnp


class TokenBucket:
    """Per-tenant rate limiting (cgroup CPU-share analog)."""

    def __init__(self, rate: float, burst: float):
        self.rate = rate
        self.burst = burst
        self._tokens = burst
        self._t = time.monotonic()
        self._lock = threading.Lock()

    def try_take(self, n: float = 1.0) -> bool:
        with self._lock:
            now = time.monotonic()
            self._tokens = min(self.burst,
                               self._tokens + (now - self._t) * self.rate)
            self._t = now
            if self._tokens >= n:
                self._tokens -= n
                return True
            return False


@dataclass
class _Request:
    prompt: list
    max_new: int
    future: Future
    slot: int = -1
    emitted: list = field(default_factory=list)


class ContinuousBatcher:
    """Slot-based continuous batching over one LM function's decode program.

    All active requests share ONE compiled decode executable and ONE arena
    (the batched cache slab); new requests prefill into free slots while
    others keep decoding — runtime virtualization at the request level.
    """

    def __init__(self, runtime, fid: str):
        self.rt = runtime
        self.fid = fid
        self.func = runtime.registry.get(fid)
        self.spec = self.func.spec
        self.slots = self.spec.slots
        self.pending: list[_Request] = []
        self.active: dict[int, _Request] = {}
        self.free = list(range(self.slots))
        self._lock = threading.Lock()
        self.arena = runtime.arena_pool.acquire(
            self.func.arena_sig, self.func.arena_factory)
        self.cache = self.arena.buffers
        self.cur_tok = jnp.zeros((self.slots, 1), jnp.int32)
        self.steps = 0

    def submit(self, prompt_tokens, max_new: int) -> Future:
        fut: Future = Future()
        with self._lock:
            self.pending.append(_Request(list(prompt_tokens), max_new, fut))
        return fut

    # ------------------------------------------------------------------
    def _admit(self):
        while self.free and self.pending:
            with self._lock:
                req = self.pending.pop(0)
            slot = self.free.pop(0)
            req.slot = slot
            prompt = jnp.asarray(req.prompt, jnp.int32).reshape(1, -1)
            exe = self.rt._lm_prefill_exe(self.func, prompt.shape[1])
            tok, self.cache = exe(self.spec.params, self.cache, prompt,
                                  jnp.int32(slot))
            req.emitted.append(int(tok[0]))
            self.cur_tok = self.cur_tok.at[slot, 0].set(int(tok[0]))
            self.active[slot] = req

    def step(self) -> int:
        """One scheduler tick: admit, then decode every active slot."""
        self._admit()
        if not self.active:
            return 0
        tok, self.cache = self.func.entry["decode"](
            self.spec.params, self.cache, self.cur_tok)
        self.cur_tok = tok.reshape(self.slots, 1)
        self.steps += 1
        done = []
        for slot, req in self.active.items():
            req.emitted.append(int(tok[slot]))
            if len(req.emitted) >= req.max_new:
                done.append(slot)
        for slot in done:
            req = self.active.pop(slot)
            self.free.append(slot)
            req.future.set_result(req.emitted)
        return len(self.active) + len(done)

    def run_until_done(self, max_steps: int = 10_000):
        while (self.active or self.pending) and max_steps > 0:
            self.step()
            max_steps -= 1

    def close(self):
        self.arena.buffers = self.cache
        self.rt.arena_pool.release(self.arena)
