"""Trace layer for the discrete-event simulator: one ``Trace`` interface,
two sources.

  * ``Trace.synthetic(...)`` — the Shahrad-calibrated generator the repo
    has always shipped (Zipf popularity, hyperexponential bursts,
    lognormal durations/memory). ``gen_trace`` remains the raw
    list-returning entry point for back-compat.
  * ``Trace.from_azure(...)`` — the Azure Functions 2019 dataset
    (Shahrad et al. '20): the ``invocations_per_function_md`` CSV
    (HashOwner/HashApp/HashFunction + per-minute counts) plus the
    optional ``function_durations_percentiles`` and
    ``app_memory_percentiles`` tables. Counts are expanded to arrival
    timestamps (seeded-uniform within each minute) and can be
    deterministically *thinned* to a target mean rps so CI-sized replays
    of the 1440-minute dataset stay fast.
  * ``Trace.stream_azure(...)`` — the same CSVs through
    ``repro.core.streaming``: chunked ingestion, lazy per-minute
    expansion with bounded memory, top-K/stratified tenant selection,
    minute-range windowing, and tenant sharding. ``from_azure`` is this
    stream materialized, so the two agree invocation-for-invocation.

A ``Trace`` is a ``Sequence[Invocation]`` — everything that accepted the
old ``list`` of invocations (``simulate``, ``len``, indexing) accepts a
``Trace`` unchanged.
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

MB = 1 << 20
GB = 1 << 30

# Shahrad-calibrated lognormal shapes, shared by the synthetic generator
# and the Azure loader's fallbacks for absent duration/memory tables
DUR_LOG_MEAN, DUR_SIGMA = math.log(0.35), 0.7
DUR_CLIP_S = (0.1, 3.0)
MEM_LOG_MEAN, MEM_SIGMA = math.log(140), 0.35
MEM_CLIP_MB = (64, 512)


@dataclass(frozen=True)
class Invocation:
    t: float
    fid: int
    tenant: int
    duration_s: float
    mem_bytes: int


def gen_trace(n_functions: int = 120, n_tenants: int = 40,
              duration_s: float = 1800.0, mean_rps: float = 3.0,
              seed: int = 0) -> list:
    """Synthetic Azure-like trace (Shahrad et al. statistics): many owners,
    most of them sparse — rare tenants idle past the keep-alive window, so
    per-tenant runtimes churn (the cold-start regime the platform's
    pre-warmed pool targets)."""
    rng = np.random.default_rng(seed)
    # Zipf popularity over functions; functions assigned to tenants
    pop = 1.0 / np.arange(1, n_functions + 1) ** 1.1
    pop /= pop.sum()
    tenant_of = rng.integers(0, n_tenants, n_functions)
    # per-function memory: lognormal centered ~140 MB, clipped [64, 512] MB
    fn_mem = np.clip(rng.lognormal(MEM_LOG_MEAN, MEM_SIGMA, n_functions),
                     *MEM_CLIP_MB) * MB
    out = []
    t = 0.0
    # heavy-tailed inter-arrival (Shahrad et al.: bursty traffic): a
    # hyperexponential mix of short within-burst gaps and long idle gaps,
    # with the same mean as a Poisson process at ``mean_rps``
    burst_frac, burst_scale = 0.7, 0.1
    idle_scale = (1.0 - burst_frac * burst_scale) / (1.0 - burst_frac)
    while t < duration_s:
        scale = burst_scale if rng.random() < burst_frac else idle_scale
        t += rng.exponential(scale / mean_rps)
        fid = int(rng.choice(n_functions, p=pop))
        dur = float(np.clip(rng.lognormal(DUR_LOG_MEAN, DUR_SIGMA),
                            *DUR_CLIP_S))
        out.append(Invocation(t=t, fid=fid, tenant=int(tenant_of[fid]),
                              duration_s=dur, mem_bytes=int(fn_mem[fid])))
    return out


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Trace(Sequence):
    """An ordered sequence of :class:`Invocation` plus provenance."""
    invocations: tuple
    source: str = "synthetic"
    meta: dict = field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.invocations)

    def __getitem__(self, i):
        got = self.invocations[i]
        if isinstance(i, slice):
            return Trace(invocations=got, source=self.source, meta=self.meta)
        return got

    def __iter__(self):
        return iter(self.invocations)

    @property
    def duration_s(self) -> float:
        return self.invocations[-1].t if self.invocations else 0.0

    @property
    def mean_rps(self) -> float:
        d = self.duration_s
        return len(self) / d if d > 0 else 0.0

    def describe(self) -> dict:
        fids = {i.fid for i in self.invocations}
        tenants = {i.tenant for i in self.invocations}
        # meta first: the realized duration/rate must win over any
        # same-named generator kwargs recorded as provenance
        return {**self.meta,
                "source": self.source, "invocations": len(self),
                "functions": len(fids), "tenants": len(tenants),
                "duration_s": self.duration_s, "mean_rps": self.mean_rps}

    # -- sources -----------------------------------------------------------
    @classmethod
    def synthetic(cls, **kw) -> "Trace":
        return cls(invocations=tuple(gen_trace(**kw)), source="synthetic",
                   meta={k: v for k, v in kw.items()})

    @classmethod
    def from_azure(cls, invocations_csv: str,
                   durations_csv: Optional[str] = None,
                   memory_csv: Optional[str] = None,
                   target_rps: Optional[float] = None,
                   max_minutes: Optional[int] = None,
                   seed: int = 0) -> "Trace":
        return load_azure_trace(invocations_csv, durations_csv=durations_csv,
                                memory_csv=memory_csv, target_rps=target_rps,
                                max_minutes=max_minutes, seed=seed)

    @classmethod
    def stream_azure(cls, invocations_csv: str, **kw):
        """A lazily-expanded :class:`repro.core.streaming.StreamingTrace`
        over the same CSV schema as :meth:`from_azure`, plus the
        streaming-only knobs (``minute_range``, ``chunk_rows``,
        ``top_k``/``select``, ``n_shards``/``shard_index``). Same seed
        and window -> byte-identical invocations to ``from_azure``."""
        from repro.core.streaming import StreamingTrace
        return StreamingTrace(invocations_csv, **kw)


# ---------------------------------------------------------------------------
# Azure Functions 2019 dataset loader
# ---------------------------------------------------------------------------
def discover_azure_tables(invocations_csv: str) -> dict:
    """Sibling-table convention: ``<stem>_durations.csv`` /
    ``<stem>_memory.csv`` next to the invocations CSV. Returns the
    keyword arguments (``durations_csv`` / ``memory_csv``) for the
    tables that exist, ready to splat into :func:`load_azure_trace`."""
    stem = invocations_csv[:-4] if invocations_csv.endswith(".csv") \
        else invocations_csv
    out = {}
    if os.path.exists(stem + "_durations.csv"):
        out["durations_csv"] = stem + "_durations.csv"
    if os.path.exists(stem + "_memory.csv"):
        out["memory_csv"] = stem + "_memory.csv"
    return out


def load_azure_trace(invocations_csv: str,
                     durations_csv: Optional[str] = None,
                     memory_csv: Optional[str] = None,
                     target_rps: Optional[float] = None,
                     max_minutes: Optional[int] = None,
                     seed: int = 0) -> Trace:
    """Load an Azure Functions 2019-format trace into a :class:`Trace`.

    ``invocations_csv`` must carry the dataset's schema — ``HashOwner``,
    ``HashApp``, ``HashFunction`` plus integer-named per-minute count
    columns (``"1".."1440"``). ``durations_csv`` refines durations:
    per-function inverse-CDF sampling over the ``percentile_Average_*``
    columns (falling back to the ``Average`` ms column). ``memory_csv``
    refines memory with the per-app ``AverageAllocatedMb`` mean (the
    ``_pct*`` columns are accepted but not sampled — every invocation of
    an app shares its mean allocation). Absent tables fall back to the
    synthetic generator's seeded lognormals, so the invocations CSV
    alone is a complete workload.

    ``target_rps`` deterministically thins the replay: each per-minute
    count is down-sampled with a seeded binomial at
    ``min(1, target_rps / actual_rps)``, preserving the arrival *shape*
    (bursts, diurnal pattern) at CI-friendly volume. Same seed, same
    inputs -> byte-identical trace.

    This materializes :class:`repro.core.streaming.StreamingTrace` (the
    chunked lazy loader), so the two paths agree invocation-for-
    invocation by construction; an empty expansion (all counts zero, or
    thinned to nothing) raises ``ValueError`` like any other unusable
    input.
    """
    from repro.core.streaming import StreamingTrace
    st = StreamingTrace(invocations_csv, durations_csv=durations_csv,
                        memory_csv=memory_csv, target_rps=target_rps,
                        max_minutes=max_minutes, seed=seed)
    return Trace(invocations=tuple(st), source="azure",
                 meta={"path": invocations_csv, "target_rps": target_rps,
                       "thinning_keep": st.keep,
                       "raw_invocations": st.raw_invocations,
                       "minutes": st.meta["minutes"], "seed": seed})


def _norm_ppf(u: float) -> float:
    """Acklam's rational approximation to the standard-normal inverse CDF
    (scipy-free; |err| < 1.2e-9 on (0, 1))."""
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    plow, phigh = 0.02425, 1 - 0.02425
    if u < plow:
        q = math.sqrt(-2 * math.log(u))
        return ((((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4])
                 * q + c[5])
                / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1))
    if u > phigh:
        q = math.sqrt(-2 * math.log(1 - u))
        return -((((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4])
                  * q + c[5])
                 / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1))
    q = u - 0.5
    r = q * q
    return ((((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4])
             * r + a[5]) * q
            / (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4])
               * r + 1))
