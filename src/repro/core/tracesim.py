"""Back-compat facade for the discrete-event simulator.

The monolith that used to live here is now the ``repro.core.sim``
package (``engine.py`` — model-agnostic event loop; ``models.py`` — the
``PlatformModel`` policy interface + ``MODELS`` registry) with its trace
sources in ``repro.core.traces`` (synthetic generator + Azure Functions
2019 loader) and measured-cost calibration in ``repro.core.calibrate``.
Every public name keeps importing from here:

    from repro.core.tracesim import SimParams, gen_trace, simulate

and ``python -m repro.core.tracesim`` still prints the five-model
comparison on the default synthetic trace.
"""
from __future__ import annotations

from repro.core.sim import *                  # noqa: F401,F403
from repro.core.sim import Node, RuntimeInst, compare, gen_trace
from repro.core.sim import __all__ as __all__  # single source of truth

# old private names, kept for anything that poked at the internals
_RuntimeInst = RuntimeInst
_Node = Node


if __name__ == "__main__":
    import json
    summaries = compare(gen_trace())
    print(json.dumps(summaries, indent=2))
