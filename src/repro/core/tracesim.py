"""Azure-trace reproduction (paper §4.4, Figures 9/10).

A discrete-event simulator replays a multi-function multi-tenant invocation
trace under four runtime models:

  * ``openwhisk`` — one runtime per function instance, ONE invocation at a
    time (classic FaaS worker); keep-alive TTL.
  * ``photons``   — one runtime per function, MANY concurrent invocations
    (virtualized single-function runtime).
  * ``hydra``     — one runtime per TENANT hosting any of the tenant's
    functions, many concurrent invocations, shared code caches; new runtime
    instance when the 2 GB budget saturates (paper setup).
  * ``hydra-pool`` — the HydraPlatform layer: colocation ACROSS tenants
    (any runtime hosts any owner's functions, packed until the 2 GB budget
    saturates) plus a pre-warmed pool of generic instances claimed instead
    of cold-booting, and snapshot-based function install (restoring a
    previously-seen function into a runtime skips re-registration cost).

Outputs: memory-over-time samples, per-request latencies (queue + startup +
duration), cold-start counts, active runtime ("microVM") counts.

The trace itself is synthetic but calibrated to the Shahrad et al. '20
characterization the paper uses: Zipf function popularity, heavy-tailed
inter-arrival, durations 100 ms - 3 s, per-function memory 120-170 MB.
Startup-cost constants default to the paper's measurements and can be
overridden with values measured by our own benchmarks (bench_startup).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field, replace

import numpy as np

MB = 1 << 20
GB = 1 << 30


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SimParams:
    # startup costs (seconds) — paper Fig 1/8 scale
    runtime_cold_s: float = 0.150      # native runtime boot (cold start)
    hydra_runtime_cold_s: float = 0.046  # AOT-compiled runtime boot (2-3x faster)
    isolate_cold_s: float = 0.0005     # isolate/arena allocation (<500 us)
    isolate_warm_s: float = 0.00005    # pool hit
    fn_register_s: float = 0.010       # per-function code install (hydra)
    # memory model (bytes)
    runtime_base: int = 30 * MB        # native runtime RSS
    hydra_runtime_base: int = 46 * MB  # polyglot runtime RSS (paper Fig 5)
    isolate_base: int = 1 * MB         # pre-allocated isolate heap
    runtime_cap: int = 2 * GB          # per-runtime budget (hydra/photons)
    machine_cap: int = 16 * GB         # node budget (paper: 16 GB segment)
    keepalive_s: float = 60.0          # worker keep-alive (openwhisk)
    isolate_ttl_s: float = 10.0        # isolate pool TTL
    vm_boot_s: float = 0.125           # Firecracker microVM boot
    retry_backoff_s: float = 0.05      # queue retry when machine is full
    max_wait_s: float = 30.0           # give up queueing after this
    # platform layer (hydra-pool model only)
    pool_size: int = 4                 # pre-warmed generic runtime instances
    pool_claim_s: float = 0.002        # claim a warm instance from the pool
    pool_refill_s: float = 1.0         # background re-warm after a claim
    snapshot_restore_s: float = 0.004  # install a snapshotted fn (vs
                                       # fn_register_s for a first install)


@dataclass(frozen=True)
class Invocation:
    t: float
    fid: int
    tenant: int
    duration_s: float
    mem_bytes: int


def gen_trace(n_functions: int = 120, n_tenants: int = 40,
              duration_s: float = 1800.0, mean_rps: float = 3.0,
              seed: int = 0) -> list:
    """Synthetic Azure-like trace (Shahrad et al. statistics): many owners,
    most of them sparse — rare tenants idle past the keep-alive window, so
    per-tenant runtimes churn (the cold-start regime the platform's
    pre-warmed pool targets)."""
    rng = np.random.default_rng(seed)
    # Zipf popularity over functions; functions assigned to tenants
    pop = 1.0 / np.arange(1, n_functions + 1) ** 1.1
    pop /= pop.sum()
    tenant_of = rng.integers(0, n_tenants, n_functions)
    # per-function memory: lognormal centered ~140 MB, clipped [64, 512] MB
    fn_mem = np.clip(rng.lognormal(math.log(140), 0.35, n_functions),
                     64, 512) * MB
    out = []
    t = 0.0
    # heavy-tailed inter-arrival (Shahrad et al.: bursty traffic): a
    # hyperexponential mix of short within-burst gaps and long idle gaps,
    # with the same mean as a Poisson process at ``mean_rps``
    burst_frac, burst_scale = 0.7, 0.1
    idle_scale = (1.0 - burst_frac * burst_scale) / (1.0 - burst_frac)
    while t < duration_s:
        scale = burst_scale if rng.random() < burst_frac else idle_scale
        t += rng.exponential(scale / mean_rps)
        fid = int(rng.choice(n_functions, p=pop))
        dur = float(np.clip(rng.lognormal(math.log(0.35), 0.7), 0.1, 3.0))
        out.append(Invocation(t=t, fid=fid, tenant=int(tenant_of[fid]),
                              duration_s=dur, mem_bytes=int(fn_mem[fid])))
    return out


# ---------------------------------------------------------------------------
@dataclass
class _RuntimeInst:
    key: tuple                     # grouping key (fid | tenant, index)
    base_mem: int
    cap: int
    isolate_base: int = MB
    live_mem: int = 0
    live_invocations: int = 0
    last_active: float = 0.0
    ready_at: float = 0.0          # boot completes at this time
    warm_isolates: dict = field(default_factory=dict)  # mem -> (count, t)
    functions_loaded: set = field(default_factory=set)

    def mem(self) -> int:
        # pooled isolates hold only their pre-allocated heap (~1 MB, paper
        # Fig 3); an invocation's working memory is freed at completion
        pool = sum(c for c, _ in self.warm_isolates.values()) \
            * self.isolate_base
        return self.base_mem + self.live_mem + pool


@dataclass
class SimResult:
    model: str
    latencies: list = field(default_factory=list)
    overheads: list = field(default_factory=list)  # latency - pure duration
    mem_samples: list = field(default_factory=list)     # (t, bytes)
    runtime_count_samples: list = field(default_factory=list)  # (t, n)
    cold_runtime_starts: int = 0
    cold_isolate_starts: int = 0
    warm_isolate_starts: int = 0
    evicted_runtimes: int = 0
    dropped: int = 0
    pool_claims: int = 0           # warm platform-pool instance claims

    def p(self, q) -> float:
        return float(np.percentile(self.latencies, q)) if self.latencies else float("nan")

    def mean_mem(self) -> float:
        return float(np.mean([m for _, m in self.mem_samples]))

    def mean_runtimes(self) -> float:
        return float(np.mean([n for _, n in self.runtime_count_samples]))

    def summary(self) -> dict:
        return {
            "model": self.model,
            "requests": len(self.latencies),
            "p50_s": self.p(50), "p99_s": self.p(99),
            "overhead_p99_ms": 1e3 * float(np.percentile(self.overheads, 99))
            if self.overheads else float("nan"),
            "mean_mem_mb": self.mean_mem() / MB,
            "peak_mem_mb": max(m for _, m in self.mem_samples) / MB
            if self.mem_samples else 0,
            "mean_runtimes": self.mean_runtimes(),
            "cold_runtime": self.cold_runtime_starts,
            "evicted_runtimes": self.evicted_runtimes,
            "cold_isolate": self.cold_isolate_starts,
            "warm_isolate": self.warm_isolate_starts,
            "dropped": self.dropped,
            "pool_claims": self.pool_claims,
        }


MODELS = ("openwhisk", "photons", "hydra", "hydra-pool")


def simulate(trace: list, model: str, params: SimParams = SimParams(),
             sample_dt: float = 1.0) -> SimResult:
    """Replay ``trace`` under ``model`` in MODELS."""
    assert model in MODELS, model
    p = params
    res = SimResult(model=model)
    insts: dict[tuple, list] = {}     # group key -> [_RuntimeInst]
    events: list = []                  # (t, seq, kind, payload)
    seq = 0
    hydra_like = model in ("hydra", "hydra-pool")
    # platform pool: generic warm instances, claimed instead of cold-booting
    pool = {"avail": p.pool_size if model == "hydra-pool" else 0}
    seen_fids: set = set()            # fns with a snapshot somewhere

    def pool_mem() -> int:
        return pool["avail"] * base_mem

    def total_mem() -> int:
        return sum(r.mem() for group in insts.values()
                   for r in group) + pool_mem()

    def n_runtimes() -> int:
        return sum(len(g) for g in insts.values()) + pool["avail"]

    def group_key(inv: Invocation) -> tuple:
        if model == "hydra-pool":
            return ()                  # colocate across owners AND functions
        return (inv.tenant,) if model == "hydra" else (inv.fid,)

    base_mem = p.hydra_runtime_base if hydra_like else p.runtime_base
    runtime_cold = (p.hydra_runtime_cold_s if hydra_like
                    else p.runtime_cold_s)

    for inv in trace:
        heapq.heappush(events, (inv.t, seq := seq + 1, "arrive", (inv, inv.t)))

    next_sample = 0.0
    while events:
        t, _, kind, payload = heapq.heappop(events)
        while next_sample <= t:
            res.mem_samples.append((next_sample, total_mem()))
            res.runtime_count_samples.append((next_sample, n_runtimes()))
            next_sample += sample_dt

        if kind == "done":
            inst, inv = payload
            inst.live_invocations -= 1
            inst.last_active = t
            if model == "openwhisk":
                # worker stays resident (runtime + function memory) until
                # keep-alive expiry; no isolate pool semantics
                pass
            else:
                inst.live_mem -= inv.mem_bytes + p.isolate_base
                # return isolate to pool (evicted after TTL)
                cnt, _ = inst.warm_isolates.get(inv.mem_bytes, (0, t))
                inst.warm_isolates[inv.mem_bytes] = (cnt + 1, t)
                heapq.heappush(events, (t + p.isolate_ttl_s, seq := seq + 1,
                                        "evict", (inst, inv.mem_bytes)))
            continue

        if kind == "evict":
            inst, mem = payload
            cnt, last = inst.warm_isolates.get(mem, (0, t))
            if cnt > 0 and t - last >= p.isolate_ttl_s - 1e-9:
                inst.warm_isolates[mem] = (0, last)
            continue

        if kind == "refill":
            # background re-warm of a claimed pool slot (off the request
            # path). No machine headroom right now -> retry later rather
            # than dropping the slot, like a real re-warmer would.
            if pool["avail"] < p.pool_size:
                if total_mem() + base_mem <= p.machine_cap:
                    pool["avail"] += 1
                else:
                    heapq.heappush(events, (t + p.pool_refill_s,
                                            seq := seq + 1, "refill", None))
            continue

        if kind == "expire":
            key = payload
            group = insts.get(key, [])
            keep = [r for r in group
                    if r.live_invocations > 0
                    or t - r.last_active < p.keepalive_s - 1e-9]
            insts[key] = keep
            continue

        # ---- arrival (possibly a queued retry) ----
        inv, orig_t = payload
        key = group_key(inv)
        group = insts.setdefault(key, [])
        startup = 0.0
        need = inv.mem_bytes + p.isolate_base

        inst = None
        warm_worker = False
        if model == "openwhisk":
            # one invocation per worker: find an idle warm worker (its
            # runtime + function memory are already resident)
            for r in group:
                if r.live_invocations == 0:
                    inst = r
                    warm_worker = True
                    break
        else:
            for r in group:
                if r.mem() + need <= r.cap:
                    inst = r
                    break

        if inst is None:
            # new runtime instance: claim a pre-warmed pool slot (platform
            # layer) when available, else microVM boot + runtime cold start
            # — if the machine has room; under pressure, LRU-evict idle
            # runtimes first (platforms reclaim keep-alive workers); else
            # queue with backoff (a real platform would spill to another
            # node). A pool claim adds no net base memory: the slot's RSS
            # is already counted in total_mem().
            claim_pool = model == "hydra-pool" and pool["avail"] > 0
            extra = need if claim_pool else base_mem + need
            if total_mem() + extra > p.machine_cap:
                idle = sorted((r for g in insts.values() for r in g
                               if r.live_invocations == 0),
                              key=lambda r: r.last_active)
                while idle and total_mem() + extra > p.machine_cap:
                    victim = idle.pop(0)
                    insts[victim.key[:-1]].remove(victim)
                    res.evicted_runtimes += 1
            if total_mem() + extra > p.machine_cap:
                if t - orig_t >= p.max_wait_s:
                    res.dropped += 1
                else:
                    heapq.heappush(events,
                                   (t + p.retry_backoff_s, seq := seq + 1,
                                    "arrive", (inv, orig_t)))
                continue
            cap = p.runtime_cap if model != "openwhisk" else base_mem + need
            inst = _RuntimeInst(key=key + (len(group),), base_mem=base_mem,
                                cap=cap, isolate_base=p.isolate_base)
            group.append(inst)
            if model == "openwhisk":
                inst.live_mem = inv.mem_bytes  # worker-resident fn memory
            if claim_pool:
                pool["avail"] -= 1
                startup += p.pool_claim_s
                res.pool_claims += 1
                heapq.heappush(events, (t + p.pool_refill_s,
                                        seq := seq + 1, "refill", None))
            else:
                startup += p.vm_boot_s + runtime_cold
                res.cold_runtime_starts += 1
            inst.ready_at = t + startup
        else:
            # joining an instance that may still be booting: the invocation
            # waits for the remaining boot time (cold-start amplification
            # under bursts — a warm pool instance is ready ~immediately)
            startup += max(0.0, inst.ready_at - t)

        # per-runtime code install (hydra/photons: first time this fid is
        # loaded into this runtime; shared code caches amortize the rest).
        # The platform layer restores later installs from the function's
        # sandbox snapshot instead of a full re-register/recompile.
        if model != "openwhisk" and inv.fid not in inst.functions_loaded:
            inst.functions_loaded.add(inv.fid)
            if model == "hydra-pool" and inv.fid in seen_fids:
                startup += p.snapshot_restore_s
            else:
                startup += p.fn_register_s
            seen_fids.add(inv.fid)

        # isolate acquire
        if model == "openwhisk":
            if warm_worker:
                res.warm_isolate_starts += 1
            else:
                res.cold_isolate_starts += 1
        else:
            cnt, _ = inst.warm_isolates.get(inv.mem_bytes, (0, 0.0))
            if cnt > 0:
                inst.warm_isolates[inv.mem_bytes] = (cnt - 1, t)
                startup += p.isolate_warm_s
                res.warm_isolate_starts += 1
            else:
                startup += p.isolate_cold_s
                res.cold_isolate_starts += 1
            inst.live_mem += need

        inst.live_invocations += 1
        inst.last_active = t
        latency = (t - orig_t) + startup + inv.duration_s
        res.latencies.append(latency)
        res.overheads.append(latency - inv.duration_s)
        heapq.heappush(events, (t + startup + inv.duration_s,
                                seq := seq + 1, "done", (inst, inv)))
        heapq.heappush(events, (t + startup + inv.duration_s + p.keepalive_s,
                                seq := seq + 1, "expire", key))

    return res


def compare(trace: list, params: SimParams = SimParams()) -> dict:
    return {m: simulate(trace, m, params).summary() for m in MODELS}


if __name__ == "__main__":
    import json
    summaries = compare(gen_trace())
    print(json.dumps(summaries, indent=2))
