"""Back-compat facade for the discrete-event simulator.

The monolith that used to live here is now the ``repro.core.sim``
package (``engine.py`` — model-agnostic event loop; ``models.py`` — the
``PlatformModel`` policy interface + ``MODELS`` registry) with its trace
sources in ``repro.core.traces`` (synthetic generator + Azure Functions
2019 loader) and measured-cost calibration in ``repro.core.calibrate``.
Every public name keeps importing from here:

    from repro.core.tracesim import SimParams, gen_trace, simulate

and ``python -m repro.core.tracesim`` still prints the five-model
comparison on the default synthetic trace.
"""
from __future__ import annotations

from repro.core import sim as _sim
from repro.core.sim import (GB, MB, MODELS, Engine, HydraClusterModel,
                            HydraModel, HydraPoolModel, Invocation, Node,
                            OpenWhiskModel, PhotonsModel, PlatformModel,
                            RuntimeInst, SimParams, SimResult, Trace,
                            compare, discover_azure_tables, gen_trace,
                            load_azure_trace, register_model, simulate,
                            simulate_partitioned)

__all__ = [
    "MB", "GB", "SimParams", "SimResult", "Invocation", "Engine", "Node",
    "RuntimeInst", "PlatformModel", "OpenWhiskModel", "PhotonsModel",
    "HydraModel", "HydraPoolModel", "HydraClusterModel", "MODELS",
    "register_model", "Trace", "gen_trace", "load_azure_trace",
    "discover_azure_tables", "simulate", "simulate_partitioned", "compare",
]
# the sim package stays the single source of truth for the public surface
assert set(__all__) == set(_sim.__all__), "tracesim facade drifted"

# old private names, kept for anything that poked at the internals
_RuntimeInst = RuntimeInst
_Node = Node


if __name__ == "__main__":
    import json
    summaries = compare(gen_trace())
    print(json.dumps(summaries, indent=2))
