"""Azure-trace reproduction (paper §4.4, Figures 9/10) — now fleet-scale.

A discrete-event simulator replays a multi-function multi-tenant invocation
trace under five runtime models:

  * ``openwhisk`` — one runtime per function instance, ONE invocation at a
    time (classic FaaS worker); keep-alive TTL.
  * ``photons``   — one runtime per function, MANY concurrent invocations
    (virtualized single-function runtime).
  * ``hydra``     — one runtime per TENANT hosting any of the tenant's
    functions, many concurrent invocations, shared code caches; new runtime
    instance when the 2 GB budget saturates (paper setup).
  * ``hydra-pool`` — the HydraPlatform layer: colocation ACROSS tenants
    (any runtime hosts any owner's functions, packed until the 2 GB budget
    saturates) plus a pre-warmed pool of generic instances claimed instead
    of cold-booting, and snapshot-based function install (restoring a
    previously-seen function into a runtime skips re-registration cost).
  * ``hydra-cluster`` — the HydraCluster layer: ``n_nodes`` machines, each
    a hydra-pool node. Placement packs into already-running instances
    fleet-wide (preferring the instance that already loaded the function,
    then a node holding its snapshot, then the fullest instance) and
    spills new instances to the least-loaded node. A function whose
    snapshot lives only on another node pays an explicit cross-node
    transfer cost (``snapshot_bytes`` at ``transfer_gbps``). Each node's
    pre-warmed pool is sized adaptively by an EWMA arrival-rate estimator
    (grow toward ``pool_max`` under bursts, shrink to ``pool_min`` when
    idle, never past the node memory budget) instead of the fixed
    ``pool_size``.

Outputs: memory-over-time samples, per-request latencies (queue + startup +
duration), cold-start counts, active runtime ("microVM") counts, snapshot
transfers, peak pooled memory, and ops/GB-sec density.

The trace itself is synthetic but calibrated to the Shahrad et al. '20
characterization the paper uses: Zipf function popularity, heavy-tailed
inter-arrival, durations 100 ms - 3 s, per-function memory 120-170 MB.
Startup-cost constants default to the paper's measurements and can be
overridden with values measured by our own benchmarks (bench_startup).

``SimParams`` is documented field-by-field inline below; the cluster-only
fields (``n_nodes`` .. ``pool_cover_s``) are ignored by the single-node
models, which always run one node at the full ``machine_cap``.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field, replace
from typing import Optional

import numpy as np

MB = 1 << 20
GB = 1 << 30


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SimParams:
    # startup costs (seconds) — paper Fig 1/8 scale
    runtime_cold_s: float = 0.150      # native runtime boot (cold start)
    hydra_runtime_cold_s: float = 0.046  # AOT-compiled runtime boot (2-3x faster)
    isolate_cold_s: float = 0.0005     # isolate/arena allocation (<500 us)
    isolate_warm_s: float = 0.00005    # pool hit
    fn_register_s: float = 0.010       # per-function code install (hydra)
    # memory model (bytes)
    runtime_base: int = 30 * MB        # native runtime RSS
    hydra_runtime_base: int = 46 * MB  # polyglot runtime RSS (paper Fig 5)
    isolate_base: int = 1 * MB         # pre-allocated isolate heap
    runtime_cap: int = 2 * GB          # per-runtime budget (hydra/photons)
    machine_cap: int = 16 * GB         # FLEET budget (paper: 16 GB segment)
    keepalive_s: float = 60.0          # worker keep-alive (openwhisk)
    isolate_ttl_s: float = 10.0        # isolate pool TTL
    vm_boot_s: float = 0.125           # Firecracker microVM boot
    retry_backoff_s: float = 0.05      # queue retry when machine is full
    max_wait_s: float = 30.0           # give up queueing after this
    # platform layer (hydra-pool / hydra-cluster models)
    pool_size: int = 4                 # pre-warmed instances (fixed policy)
    pool_claim_s: float = 0.002        # claim a warm instance from the pool
    pool_refill_s: float = 1.0         # background re-warm after a claim
    snapshot_restore_s: float = 0.004  # install a snapshotted fn (vs
                                       # fn_register_s for a first install)
    pool_drain_ttl_s: float = 10.0     # an idle (empty) platform runtime
                                       # drains back to the warm pool after
                                       # this, like HydraPlatform's
                                       # _return_runtime (0 disables)
    # multi-node fleet (hydra-cluster model only)
    n_nodes: int = 4                   # machines in the cluster
    node_cap: Optional[int] = None     # per-node memory; default splits
                                       # machine_cap evenly (fleet total
                                       # stays constant across node counts)
    transfer_gbps: float = 10.0        # cross-node snapshot bandwidth
    snapshot_bytes: int = 24 * MB      # serialized sandbox snapshot size
    adaptive_pool: bool = True         # EWMA-driven per-node pool sizing
    pool_min: int = 2                  # adaptive pool floor (per node)
    pool_max: Optional[int] = None     # adaptive ceiling; default pool_size
    ewma_alpha: float = 0.5            # arrival-rate EWMA smoothing
    pool_cover_s: float = 2.0          # arrivals one warm pool must absorb
                                       # (≈ one cold-boot + refill window)


@dataclass(frozen=True)
class Invocation:
    t: float
    fid: int
    tenant: int
    duration_s: float
    mem_bytes: int


def gen_trace(n_functions: int = 120, n_tenants: int = 40,
              duration_s: float = 1800.0, mean_rps: float = 3.0,
              seed: int = 0) -> list:
    """Synthetic Azure-like trace (Shahrad et al. statistics): many owners,
    most of them sparse — rare tenants idle past the keep-alive window, so
    per-tenant runtimes churn (the cold-start regime the platform's
    pre-warmed pool targets)."""
    rng = np.random.default_rng(seed)
    # Zipf popularity over functions; functions assigned to tenants
    pop = 1.0 / np.arange(1, n_functions + 1) ** 1.1
    pop /= pop.sum()
    tenant_of = rng.integers(0, n_tenants, n_functions)
    # per-function memory: lognormal centered ~140 MB, clipped [64, 512] MB
    fn_mem = np.clip(rng.lognormal(math.log(140), 0.35, n_functions),
                     64, 512) * MB
    out = []
    t = 0.0
    # heavy-tailed inter-arrival (Shahrad et al.: bursty traffic): a
    # hyperexponential mix of short within-burst gaps and long idle gaps,
    # with the same mean as a Poisson process at ``mean_rps``
    burst_frac, burst_scale = 0.7, 0.1
    idle_scale = (1.0 - burst_frac * burst_scale) / (1.0 - burst_frac)
    while t < duration_s:
        scale = burst_scale if rng.random() < burst_frac else idle_scale
        t += rng.exponential(scale / mean_rps)
        fid = int(rng.choice(n_functions, p=pop))
        dur = float(np.clip(rng.lognormal(math.log(0.35), 0.7), 0.1, 3.0))
        out.append(Invocation(t=t, fid=fid, tenant=int(tenant_of[fid]),
                              duration_s=dur, mem_bytes=int(fn_mem[fid])))
    return out


# ---------------------------------------------------------------------------
@dataclass
class _RuntimeInst:
    key: tuple                     # grouping key (fid | tenant, index)
    base_mem: int
    cap: int
    isolate_base: int = MB
    live_mem: int = 0
    live_invocations: int = 0
    last_active: float = 0.0
    ready_at: float = 0.0          # boot completes at this time
    warm_isolates: dict = field(default_factory=dict)  # mem -> (count, t)
    functions_loaded: set = field(default_factory=set)

    def mem(self) -> int:
        # pooled isolates hold only their pre-allocated heap (~1 MB, paper
        # Fig 3); an invocation's working memory is freed at completion
        pool = sum(c for c, _ in self.warm_isolates.values()) \
            * self.isolate_base
        return self.base_mem + self.live_mem + pool


@dataclass
class _Node:
    """One machine: its runtime instances, warm pool, snapshot store, and
    (cluster model) EWMA arrival-rate state for adaptive pool sizing."""
    idx: int
    cap: int
    insts: dict = field(default_factory=dict)  # group key -> [_RuntimeInst]
    pool_avail: int = 0
    pool_target: int = 0
    pool_pending: int = 0          # refills scheduled but not landed
    rate: float = 0.0              # EWMA arrivals/s
    last_arrival: float = float("-inf")
    snapshots: set = field(default_factory=set)  # fids snapshotted locally


@dataclass
class SimResult:
    model: str
    latencies: list = field(default_factory=list)
    overheads: list = field(default_factory=list)  # latency - pure duration
    mem_samples: list = field(default_factory=list)     # (t, bytes)
    pool_mem_samples: list = field(default_factory=list)  # (t, bytes)
    runtime_count_samples: list = field(default_factory=list)  # (t, n)
    cold_runtime_starts: int = 0
    cold_isolate_starts: int = 0
    warm_isolate_starts: int = 0
    evicted_runtimes: int = 0
    dropped: int = 0
    pool_claims: int = 0           # warm platform-pool instance claims
    transfers: int = 0             # cross-node snapshot transfers
    peak_pool_mem: int = 0         # max bytes held by warm pool slots
    n_nodes: int = 1

    def p(self, q) -> float:
        return float(np.percentile(self.latencies, q)) if self.latencies else float("nan")

    def mean_mem(self) -> float:
        return float(np.mean([m for _, m in self.mem_samples]))

    def mean_pool_mem(self) -> float:
        if not self.pool_mem_samples:
            return 0.0
        return float(np.mean([m for _, m in self.pool_mem_samples]))

    def mean_runtimes(self) -> float:
        return float(np.mean([n for _, n in self.runtime_count_samples]))

    def ops_per_gb_s(self) -> float:
        """Density: completed invocations per GB-second of fleet footprint
        (the paper's headline 2.41x metric)."""
        if not self.mem_samples or not self.latencies:
            return float("nan")
        duration = self.mem_samples[-1][0]
        gb = self.mean_mem() / GB
        if duration <= 0 or gb <= 0:
            return float("nan")
        return len(self.latencies) / (gb * duration)

    def summary(self) -> dict:
        return {
            "model": self.model,
            "requests": len(self.latencies),
            "p50_s": self.p(50), "p99_s": self.p(99),
            "overhead_p99_ms": 1e3 * float(np.percentile(self.overheads, 99))
            if self.overheads else float("nan"),
            "mean_mem_mb": self.mean_mem() / MB,
            "peak_mem_mb": max(m for _, m in self.mem_samples) / MB
            if self.mem_samples else 0,
            "mean_runtimes": self.mean_runtimes(),
            "cold_runtime": self.cold_runtime_starts,
            "evicted_runtimes": self.evicted_runtimes,
            "cold_isolate": self.cold_isolate_starts,
            "warm_isolate": self.warm_isolate_starts,
            "dropped": self.dropped,
            "pool_claims": self.pool_claims,
            "transfers": self.transfers,
            "peak_pool_mem_mb": self.peak_pool_mem / MB,
            "mean_pool_mem_mb": self.mean_pool_mem() / MB,
            "ops_per_gb_s": self.ops_per_gb_s(),
            "n_nodes": self.n_nodes,
        }


MODELS = ("openwhisk", "photons", "hydra", "hydra-pool", "hydra-cluster")


def simulate(trace: list, model: str, params: SimParams = SimParams(),
             sample_dt: float = 1.0) -> SimResult:
    """Replay ``trace`` under ``model`` in MODELS."""
    assert model in MODELS, model
    p = params
    cluster = model == "hydra-cluster"
    pooled = model in ("hydra-pool", "hydra-cluster")
    hydra_like = model in ("hydra", "hydra-pool", "hydra-cluster")

    base_mem = p.hydra_runtime_base if hydra_like else p.runtime_base
    runtime_cold = (p.hydra_runtime_cold_s if hydra_like
                    else p.runtime_cold_s)
    n_nodes = max(1, p.n_nodes) if cluster else 1
    node_cap = ((p.node_cap or p.machine_cap // n_nodes) if cluster
                else p.machine_cap)
    pool_max = p.pool_max if p.pool_max is not None else p.pool_size
    transfer_s = p.snapshot_bytes / (p.transfer_gbps * 1e9 / 8)

    res = SimResult(model=model, n_nodes=n_nodes)
    nodes = [_Node(idx=i, cap=node_cap) for i in range(n_nodes)]
    for nd in nodes:
        if model == "hydra-pool":
            nd.pool_avail = nd.pool_target = p.pool_size
        elif cluster:
            nd.pool_avail = nd.pool_target = (
                p.pool_min if p.adaptive_pool else p.pool_size)

    events: list = []                  # (t, seq, kind, payload)
    seq = 0

    def node_mem(nd: _Node) -> int:
        return sum(r.mem() for g in nd.insts.values() for r in g) \
            + nd.pool_avail * base_mem

    def fleet_mem() -> int:
        return sum(node_mem(nd) for nd in nodes)

    def fleet_pool_mem() -> int:
        return sum(nd.pool_avail for nd in nodes) * base_mem

    def n_runtimes() -> int:
        return sum(len(g) for nd in nodes for g in nd.insts.values()) \
            + sum(nd.pool_avail for nd in nodes)

    def group_key(inv: Invocation) -> tuple:
        if pooled:
            return ()                  # colocate across owners AND functions
        return (inv.tenant,) if model == "hydra" else (inv.fid,)

    def adapt_pool(nd: _Node, t: float) -> None:
        """EWMA arrival-rate update + pool retarget (cluster model only):
        grow toward pool_max under bursts, shrink to pool_min when idle,
        and never let pooled slots outgrow the node's free memory."""
        nonlocal seq
        if not (cluster and p.adaptive_pool):
            return
        eff = nd.rate
        if nd.last_arrival > float("-inf"):
            gap = max(t - nd.last_arrival, 1e-9)
            nd.rate = (1.0 - p.ewma_alpha) * nd.rate + p.ewma_alpha / gap
            # cap by the latest gap: a long-idle node collapses to the
            # floor immediately instead of riding its stale burst estimate
            eff = min(nd.rate, 1.0 / gap)
        nd.last_arrival = t
        want = min(pool_max,
                   max(p.pool_min, math.ceil(eff * p.pool_cover_s)))
        busy = node_mem(nd) - nd.pool_avail * base_mem
        want = min(want, max(0, (nd.cap - busy) // base_mem))
        nd.pool_target = want
        if nd.pool_avail > want:       # shrink releases memory immediately
            nd.pool_avail = want
        # growth is urgent (the estimator says a burst is on): back-boot
        # a generic runtime rather than waiting a full re-warm period
        grow_s = p.vm_boot_s + runtime_cold
        while nd.pool_avail + nd.pool_pending < want:
            nd.pool_pending += 1
            heapq.heappush(events, (t + grow_s, seq := seq + 1,
                                    "refill", nd))

    for inv in trace:
        heapq.heappush(events, (inv.t, seq := seq + 1, "arrive", (inv, inv.t)))

    res.peak_pool_mem = fleet_pool_mem()
    next_sample = 0.0
    while events:
        t, _, kind, payload = heapq.heappop(events)
        while next_sample <= t:
            res.mem_samples.append((next_sample, fleet_mem()))
            res.pool_mem_samples.append((next_sample, fleet_pool_mem()))
            res.runtime_count_samples.append((next_sample, n_runtimes()))
            res.peak_pool_mem = max(res.peak_pool_mem, fleet_pool_mem())
            next_sample += sample_dt

        if kind == "done":
            nd, inst, inv = payload
            inst.live_invocations -= 1
            inst.last_active = t
            if model == "openwhisk":
                # worker stays resident (runtime + function memory) until
                # keep-alive expiry; no isolate pool semantics
                pass
            else:
                inst.live_mem -= inv.mem_bytes + p.isolate_base
                # return isolate to pool (evicted after TTL)
                cnt, _ = inst.warm_isolates.get(inv.mem_bytes, (0, t))
                inst.warm_isolates[inv.mem_bytes] = (cnt + 1, t)
                heapq.heappush(events, (t + p.isolate_ttl_s, seq := seq + 1,
                                        "evict", (inst, inv.mem_bytes)))
                if (pooled and p.pool_drain_ttl_s > 0
                        and inst.live_invocations == 0):
                    heapq.heappush(events, (t + p.pool_drain_ttl_s,
                                            seq := seq + 1, "drain",
                                            (nd, inst)))
            continue

        if kind == "drain":
            # HydraPlatform._return_runtime: an emptied runtime that stays
            # idle past the TTL becomes a generic warm-pool slot again (or
            # shuts down when the pool is already at target) — its loaded
            # functions survive only as node-local snapshots
            nd, inst = payload
            group = nd.insts.get(inst.key[:-1], [])
            if (inst in group and inst.live_invocations == 0
                    and t - inst.last_active >= p.pool_drain_ttl_s - 1e-9):
                group.remove(inst)
                if nd.pool_avail < nd.pool_target:
                    nd.pool_avail += 1
                    res.peak_pool_mem = max(res.peak_pool_mem,
                                            fleet_pool_mem())
            continue

        if kind == "evict":
            inst, mem = payload
            cnt, last = inst.warm_isolates.get(mem, (0, t))
            if cnt > 0 and t - last >= p.isolate_ttl_s - 1e-9:
                inst.warm_isolates[mem] = (0, last)
            continue

        if kind == "refill":
            # background re-warm of a claimed pool slot (off the request
            # path). No node headroom right now -> retry later rather
            # than dropping the slot, like a real re-warmer would. An
            # adaptively-shrunk target just drops the now-surplus slot.
            nd = payload
            nd.pool_pending = max(0, nd.pool_pending - 1)
            if nd.pool_avail < nd.pool_target:
                if node_mem(nd) + base_mem <= nd.cap:
                    nd.pool_avail += 1
                    res.peak_pool_mem = max(res.peak_pool_mem,
                                            fleet_pool_mem())
                else:
                    nd.pool_pending += 1
                    heapq.heappush(events, (t + p.pool_refill_s,
                                            seq := seq + 1, "refill", nd))
            continue

        if kind == "expire":
            nd, key = payload
            group = nd.insts.get(key, [])
            keep = [r for r in group
                    if r.live_invocations > 0
                    or t - r.last_active < p.keepalive_s - 1e-9]
            nd.insts[key] = keep
            continue

        # ---- arrival (possibly a queued retry) ----
        inv, orig_t = payload
        startup = 0.0
        need = inv.mem_bytes + p.isolate_base
        key = group_key(inv)

        nd = nodes[0]
        inst = None
        warm_worker = False
        if model == "openwhisk":
            # one invocation per worker: find an idle warm worker (its
            # runtime + function memory are already resident)
            for r in nd.insts.setdefault(key, []):
                if r.live_invocations == 0:
                    inst = r
                    warm_worker = True
                    break
        elif not cluster:
            for r in nd.insts.setdefault(key, []):
                if r.mem() + need <= r.cap:
                    inst = r
                    break
        else:
            # fleet-wide packing: prefer the instance that already loaded
            # this fid (zero install), then a node holding its snapshot
            # (no transfer), then the fullest instance (pack-first keeps
            # spare capacity drainable)
            best = None
            for cand_nd in nodes:
                for r in cand_nd.insts.get((), []):
                    if r.mem() + need > r.cap:
                        continue
                    score = (inv.fid in r.functions_loaded,
                             inv.fid in cand_nd.snapshots, r.mem())
                    if best is None or score > best[0]:
                        best = (score, cand_nd, r)
            if best is not None:
                _, nd, inst = best

        if inst is None:
            # new runtime instance: claim a pre-warmed pool slot (platform
            # layer) when available, else microVM boot + runtime cold start
            # — if the node has room; under pressure, LRU-evict idle
            # runtimes first (platforms reclaim keep-alive workers); else
            # queue with backoff. The cluster picks the node: a warm pool
            # slot on the least-loaded pooled node, else a cold boot on the
            # least-loaded node (this is the cross-machine spill). A pool
            # claim adds no net base memory: the slot's RSS is already
            # counted in node_mem().
            if cluster:
                # a node "fits" if reclaiming its idle runtimes would make
                # room (the eviction loop below does the reclaiming) —
                # prefer a warm pool claim anywhere over a cold boot
                def reclaimable(x: _Node) -> int:
                    return sum(r.mem() for g in x.insts.values()
                               for r in g if r.live_invocations == 0)
                pool_fit = [x for x in nodes if x.pool_avail > 0
                            and node_mem(x) - reclaimable(x) + need
                            <= x.cap]
                if pool_fit:
                    nd = min(pool_fit, key=node_mem)
                    claim_pool = True
                else:
                    cold_fit = [x for x in nodes
                                if node_mem(x) - reclaimable(x)
                                + base_mem + need <= x.cap]
                    nd = min(cold_fit or nodes, key=node_mem)
                    claim_pool = False
            else:
                claim_pool = model == "hydra-pool" and nd.pool_avail > 0
            extra = need if claim_pool else base_mem + need
            if node_mem(nd) + extra > nd.cap:
                idle = sorted((r for g in nd.insts.values() for r in g
                               if r.live_invocations == 0),
                              key=lambda r: r.last_active)
                while idle and node_mem(nd) + extra > nd.cap:
                    victim = idle.pop(0)
                    nd.insts[victim.key[:-1]].remove(victim)
                    res.evicted_runtimes += 1
            if node_mem(nd) + extra > nd.cap:
                if t - orig_t >= p.max_wait_s:
                    res.dropped += 1
                else:
                    heapq.heappush(events,
                                   (t + p.retry_backoff_s, seq := seq + 1,
                                    "arrive", (inv, orig_t)))
                continue
            group = nd.insts.setdefault(key, [])
            cap = p.runtime_cap if model != "openwhisk" else base_mem + need
            inst = _RuntimeInst(key=key + (len(group),), base_mem=base_mem,
                                cap=cap, isolate_base=p.isolate_base)
            group.append(inst)
            if model == "openwhisk":
                inst.live_mem = inv.mem_bytes  # worker-resident fn memory
            if claim_pool:
                nd.pool_avail -= 1
                startup += p.pool_claim_s
                res.pool_claims += 1
                nd.pool_pending += 1
                heapq.heappush(events, (t + p.pool_refill_s,
                                        seq := seq + 1, "refill", nd))
            else:
                startup += p.vm_boot_s + runtime_cold
                res.cold_runtime_starts += 1
            inst.ready_at = t + startup
        else:
            # joining an instance that may still be booting: the invocation
            # waits for the remaining boot time (cold-start amplification
            # under bursts — a warm pool instance is ready ~immediately)
            startup += max(0.0, inst.ready_at - t)

        # the serving node observed an arrival: update its EWMA rate and
        # retarget its warm pool (adaptive sizing, cluster model only)
        adapt_pool(nd, t)

        # per-runtime code install (hydra/photons: first time this fid is
        # loaded into this runtime; shared code caches amortize the rest).
        # The platform layer restores later installs from the function's
        # sandbox snapshot instead of a full re-register/recompile; in the
        # cluster, a snapshot held only by ANOTHER node is fetched first —
        # the explicit cross-machine transfer cost.
        if model != "openwhisk" and inv.fid not in inst.functions_loaded:
            inst.functions_loaded.add(inv.fid)
            if pooled and inv.fid in nd.snapshots:
                startup += p.snapshot_restore_s
            elif cluster and any(inv.fid in x.snapshots for x in nodes):
                startup += p.snapshot_restore_s + transfer_s
                res.transfers += 1
            else:
                startup += p.fn_register_s
            nd.snapshots.add(inv.fid)

        # isolate acquire
        if model == "openwhisk":
            if warm_worker:
                res.warm_isolate_starts += 1
            else:
                res.cold_isolate_starts += 1
        else:
            cnt, _ = inst.warm_isolates.get(inv.mem_bytes, (0, 0.0))
            if cnt > 0:
                inst.warm_isolates[inv.mem_bytes] = (cnt - 1, t)
                startup += p.isolate_warm_s
                res.warm_isolate_starts += 1
            else:
                startup += p.isolate_cold_s
                res.cold_isolate_starts += 1
            inst.live_mem += need

        inst.live_invocations += 1
        inst.last_active = t
        latency = (t - orig_t) + startup + inv.duration_s
        res.latencies.append(latency)
        res.overheads.append(latency - inv.duration_s)
        heapq.heappush(events, (t + startup + inv.duration_s,
                                seq := seq + 1, "done", (nd, inst, inv)))
        heapq.heappush(events, (t + startup + inv.duration_s + p.keepalive_s,
                                seq := seq + 1, "expire", (nd, key)))

    return res


def simulate_partitioned(trace: list, n_nodes: int,
                         params: SimParams = SimParams(),
                         model: str = "hydra-pool") -> SimResult:
    """Baseline fleet WITHOUT a cluster layer: ``n_nodes`` independent
    single-node deployments with statically partitioned traffic (functions
    hashed across nodes) and a 1/n share of the fleet memory each. The
    merged result is directly comparable to a ``hydra-cluster`` run at the
    same node count — the delta is what cross-machine placement, spill,
    and snapshot transfer buy."""
    node_cap = params.node_cap or params.machine_cap // n_nodes
    single = replace(params, machine_cap=node_cap, n_nodes=1)
    merged = SimResult(model=f"{model}-static", n_nodes=n_nodes)
    mem: dict[float, int] = {}
    pmem: dict[float, int] = {}
    cnt: dict[float, int] = {}
    common_end = float("inf")     # nodes' sample grids end at different
    for i in range(n_nodes):      # times; sums past the shortest would
        sub = [inv for inv in trace  # cover only a subset of the fleet
               if inv.fid % n_nodes == i]
        r = simulate(sub, model, single)
        if r.mem_samples:
            common_end = min(common_end, r.mem_samples[-1][0])
        merged.latencies += r.latencies
        merged.overheads += r.overheads
        merged.cold_runtime_starts += r.cold_runtime_starts
        merged.cold_isolate_starts += r.cold_isolate_starts
        merged.warm_isolate_starts += r.warm_isolate_starts
        merged.evicted_runtimes += r.evicted_runtimes
        merged.dropped += r.dropped
        merged.pool_claims += r.pool_claims
        merged.transfers += r.transfers
        merged.peak_pool_mem += r.peak_pool_mem   # sum of per-node peaks
        for ts, m in r.mem_samples:
            mem[ts] = mem.get(ts, 0) + m
        for ts, m in r.pool_mem_samples:
            pmem[ts] = pmem.get(ts, 0) + m
        for ts, n in r.runtime_count_samples:
            cnt[ts] = cnt.get(ts, 0) + n
    merged.mem_samples = sorted((ts, m) for ts, m in mem.items()
                                if ts <= common_end)
    merged.pool_mem_samples = sorted((ts, m) for ts, m in pmem.items()
                                     if ts <= common_end)
    merged.runtime_count_samples = sorted((ts, n) for ts, n in cnt.items()
                                          if ts <= common_end)
    return merged


def compare(trace: list, params: SimParams = SimParams()) -> dict:
    return {m: simulate(trace, m, params).summary() for m in MODELS}


if __name__ == "__main__":
    import json
    summaries = compare(gen_trace())
    print(json.dumps(summaries, indent=2))
