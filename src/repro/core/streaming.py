"""Streaming, shardable Azure Functions trace pipeline.

``Trace.from_azure`` materializes every invocation up front — fine for
the thinned CI sample, impossible for a full dataset day (tens of
thousands of functions, millions of invocations). ``StreamingTrace``
replays the same CSV with bounded memory:

  * **Chunked/columnar ingestion** — the invocations CSV is read once in
    ``chunk_rows``-row chunks; each row is reduced to a compact columnar
    record (ids, memory, duration-sampler reference, and sparse
    per-minute counts as numpy arrays). Invocations are never stored —
    only the count cells that compress them.
  * **Lazy per-minute expansion** — iteration walks the minute labels in
    order, expands one minute's cells into arrival timestamps with
    vectorized draws (the clockwork ``azure_functions.py`` idiom:
    per-minute counts -> within-minute arrival times), sorts the bucket,
    yields it, and drops it. Peak resident invocations are bounded by
    the busiest minute, not the trace length (``peak_buffered``).
  * **Cell-keyed determinism** — every (function-row, minute) cell draws
    from its own ``SeedSequence((seed, row_key_crc, minute))`` stream,
    so the expansion is invariant to chunk size, minute windowing,
    tenant selection, and sharding: a windowed/sharded/top-K replay
    yields byte-identical invocations for the cells it keeps.
  * **Seeded thinning** — ``target_rps`` down-samples each cell with a
    seeded binomial at ``keep = target_rps / actual_rps`` (the in-memory
    loader's semantics), computed over the selected workload *before*
    sharding so shards of one workload agree on ``keep``.
  * **Top-K / stratified tenant selection** — ``top_k`` keeps the K
    busiest function rows (``select="top"``) or one row per
    popularity stratum (``select="stratified"``: head, torso, and tail
    all stay represented) for bounded-hardware replays of a full day.
  * **Tenant-partitioned sharding** — ``shard(n, i)`` returns a
    StreamingTrace filtered to tenants with ``tenant % n == i``; the n
    shards partition the workload exactly and each one only expands its
    own rows (sharded gateway replay workers each iterate their shard).

``Trace.from_azure`` delegates to this module (materializing the
stream), so the two loaders are byte-identical by construction — the
parity tests in ``tests/test_traces.py`` / ``tests/test_sim.py`` pin it.
"""
from __future__ import annotations

import csv
import zlib
from typing import NamedTuple, Optional

import numpy as np

from repro.core.traces import (DUR_CLIP_S, DUR_LOG_MEAN, DUR_SIGMA, MB,
                               MEM_CLIP_MB, MEM_LOG_MEAN, MEM_SIGMA,
                               Invocation)

_REQUIRED_INV_COLS = ("HashOwner", "HashApp", "HashFunction")
# domain tags keeping the per-cell, per-app, and per-stratum SeedSequence
# streams disjoint even when their other entropy words collide
_CELL_TAG = 0x1
_APP_MEM_TAG = 0x2
_STRATUM_TAG = 0x3

SELECT_MODES = ("top", "stratified")


class TraceFunction(NamedTuple):
    """One registrable function of the (selected, sharded) workload —
    everything the gateway needs to register it without expanding a
    single invocation."""
    fid: int
    tenant: int
    mem_bytes: int
    total_invocations: int


class _Row(NamedTuple):
    """Columnar record of one invocations-CSV row (one function)."""
    fid: int
    tenant: int
    key_crc: int                    # crc32(owner|app|function): cell seed
    mem_bytes: int
    dur_cdf: Optional[tuple]        # (qs, vs) percentile inverse-CDF
    dur_mean_s: Optional[float]
    minutes: np.ndarray             # nonzero minute labels (sorted)
    counts: np.ndarray              # invocations per nonzero minute
    total: int


def _crc(*parts: str) -> int:
    return zlib.crc32("|".join(parts).encode())


def _norm_ppf_vec(u: np.ndarray) -> np.ndarray:
    """Vectorized Acklam inverse normal CDF (same coefficients as
    ``repro.core.traces._norm_ppf``); valid on (0, 1)."""
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    u = np.asarray(u, np.float64)
    plow, phigh = 0.02425, 1 - 0.02425
    out = np.empty_like(u)

    lo = u < plow
    hi = u > phigh
    mid = ~(lo | hi)

    if lo.any():
        q = np.sqrt(-2 * np.log(u[lo]))
        out[lo] = ((((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4])
                    * q + c[5])
                   / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1))
    if hi.any():
        q = np.sqrt(-2 * np.log(1 - u[hi]))
        out[hi] = -((((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q
                      + c[4]) * q + c[5])
                    / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1))
    if mid.any():
        q = u[mid] - 0.5
        r = q * q
        out[mid] = ((((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r
                      + a[4]) * r + a[5]) * q
                    / (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r
                        + b[4]) * r + 1))
    return out


def _parse_count(val, path: str, row_no: int, col: str) -> int:
    """A malformed per-minute count is a schema error, never a silent
    skip: the dataset's columns are non-negative integers."""
    if val in (None, ""):
        return 0
    try:
        n = float(val)
    except ValueError:
        raise ValueError(
            f"azure trace {path}: row {row_no}, minute column {col!r}: "
            f"non-numeric invocation count {val!r}") from None
    if not np.isfinite(n) or n < 0 or n != int(n):
        raise ValueError(
            f"azure trace {path}: row {row_no}, minute column {col!r}: "
            f"invalid invocation count {val!r} (expected a non-negative "
            f"integer)")
    return int(n)


def _percentile_cdf(row: dict, prefix: str) -> Optional[tuple]:
    """(qs, vs) arrays for the ``<prefix><q>`` percentile columns of one
    durations-table row — the vectorizable form of
    ``traces._percentile_sampler``."""
    pts = []
    for col, val in row.items():
        if col.startswith(prefix) and val not in (None, ""):
            try:
                q = float(col[len(prefix):])
            except ValueError:
                continue
            pts.append((q, float(val)))
    pts.sort()
    if len(pts) < 2:
        return None
    qs = np.array([q for q, _ in pts]) / 100.0
    vs = np.array([v for _, v in pts])
    return qs, vs


def _app_mem_fallback(app: str, seed: int) -> int:
    """Apps the memory table doesn't cover get one seeded draw each,
    keyed by app identity (not row position) so every window/selection/
    shard of one trace agrees on the app's footprint."""
    rng = np.random.default_rng(
        np.random.SeedSequence((seed, _APP_MEM_TAG, _crc(app))))
    return int(np.clip(rng.lognormal(MEM_LOG_MEAN, MEM_SIGMA),
                       *MEM_CLIP_MB) * MB)


def _expand_cell(row: _Row, minute: int, keep: float, seed: int):
    """One (function-row, minute) cell -> (ts, fids, tenants, durs, mems)
    arrays, or None when thinning drops the whole cell. Deterministic per
    (seed, row identity, minute) — independent of every other cell."""
    rng = np.random.default_rng(
        np.random.SeedSequence((seed, _CELL_TAG, row.key_crc, minute)))
    n = int(row.counts[np.searchsorted(row.minutes, minute)])
    if keep < 1.0:
        n = int(rng.binomial(n, keep))
    if n <= 0:
        return None
    ts = 60.0 * (minute - 1) + np.sort(rng.uniform(0.0, 60.0, n))
    us = rng.uniform(0.001, 0.999, n)
    if row.dur_cdf is not None:
        qs, vs = row.dur_cdf
        durs = np.maximum(np.interp(us, qs, vs) / 1e3, 1e-3)
    elif row.dur_mean_s is not None:
        durs = np.full(n, max(row.dur_mean_s, 1e-3))
    else:
        durs = np.clip(np.exp(DUR_LOG_MEAN + DUR_SIGMA * _norm_ppf_vec(us)),
                       *DUR_CLIP_S)
    return ts, durs


class StreamingTrace:
    """A re-iterable, time-ordered stream of :class:`Invocation` expanded
    lazily from an Azure Functions 2019 invocations CSV.

    Construction performs the single chunked ingestion pass (schema
    validation, id assignment, selection, thinning-rate computation);
    each ``__iter__`` expands minute buckets on demand. See the module
    docstring for the memory model and determinism contract.
    """

    source = "azure-stream"

    def __init__(self, invocations_csv: str,
                 durations_csv: Optional[str] = None,
                 memory_csv: Optional[str] = None,
                 target_rps: Optional[float] = None,
                 max_minutes: Optional[int] = None,
                 minute_range: Optional[tuple] = None,
                 seed: int = 0,
                 chunk_rows: int = 4096,
                 top_k: Optional[int] = None,
                 select: str = "top",
                 n_shards: int = 1,
                 shard_index: Optional[int] = None):
        if chunk_rows < 1:
            raise ValueError(f"chunk_rows must be >= 1, got {chunk_rows}")
        if select not in SELECT_MODES:
            raise ValueError(f"select must be one of {SELECT_MODES}, "
                             f"got {select!r}")
        if top_k is not None and top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {top_k}")
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if shard_index is not None and not 0 <= shard_index < n_shards:
            raise ValueError(f"shard_index {shard_index} outside "
                             f"[0, {n_shards})")
        self.path = invocations_csv
        self.seed = seed
        self.target_rps = target_rps
        self.chunk_rows = chunk_rows
        self.top_k = top_k
        self.select = select
        self.n_shards = n_shards
        self.shard_index = shard_index
        self._kw = dict(durations_csv=durations_csv, memory_csv=memory_csv,
                        target_rps=target_rps, max_minutes=max_minutes,
                        minute_range=minute_range, seed=seed,
                        chunk_rows=chunk_rows, top_k=top_k, select=select)
        # iteration statistics (filled by ingestion / updated per pass)
        self.peak_buffered = 0         # max invocations resident at once
        self.last_count: Optional[int] = None   # invocations last pass

        dur_cdf, dur_mean = self._load_durations(durations_csv)
        mem_of = self._load_memory(memory_csv)
        rows = self._ingest(invocations_csv, dur_cdf, dur_mean, mem_of,
                            max_minutes, minute_range)
        rows = self._select(rows)

        total = sum(r.total for r in rows)
        if total == 0:
            raise ValueError(
                f"azure trace {invocations_csv}: selected window contains "
                f"zero invocations (minutes "
                f"{self._window[0]}..{self._window[-1]}, "
                f"top_k={top_k}, select={select!r})")
        # realized rate over the window's wall-clock span; matches the
        # in-memory loader's horizon semantics when the window starts at
        # minute 1
        window_s = 60.0 * (int(self._window[-1]) - (int(self._window[0]) - 1))
        actual_rps = total / window_s if window_s > 0 else 0.0
        self.keep = 1.0
        if target_rps is not None and actual_rps > target_rps > 0:
            self.keep = target_rps / actual_rps
        self.raw_invocations = total

        if shard_index is not None and n_shards > 1:
            rows = [r for r in rows if r.tenant % n_shards == shard_index]
        self._rows = rows
        # inverted per-minute index over the kept rows, in row order
        self._by_minute: dict = {}
        for idx, r in enumerate(rows):
            for m in r.minutes.tolist():
                self._by_minute.setdefault(m, []).append(idx)

    # -- ingestion ---------------------------------------------------------
    @staticmethod
    def _load_durations(durations_csv):
        dur_cdf: dict = {}
        dur_mean: dict = {}
        if not durations_csv:
            return dur_cdf, dur_mean
        with open(durations_csv, newline="") as f:
            reader = csv.DictReader(f)
            if reader.fieldnames is None:
                raise ValueError(f"azure durations {durations_csv}: "
                                 f"empty file (no header)")
            if "HashFunction" not in reader.fieldnames:
                raise ValueError(f"azure durations {durations_csv}: "
                                 f"missing HashFunction column")
            for r in reader:
                cdf = _percentile_cdf(r, "percentile_Average_")
                if cdf is not None:
                    dur_cdf[r["HashFunction"]] = cdf
                if r.get("Average") not in (None, ""):
                    dur_mean[r["HashFunction"]] = float(r["Average"]) / 1e3
        return dur_cdf, dur_mean

    @staticmethod
    def _load_memory(memory_csv):
        mem_of: dict = {}
        if not memory_csv:
            return mem_of
        with open(memory_csv, newline="") as f:
            reader = csv.DictReader(f)
            if reader.fieldnames is None:
                raise ValueError(f"azure memory {memory_csv}: empty file "
                                 f"(no header)")
            if "HashApp" not in reader.fieldnames \
                    or "AverageAllocatedMb" not in reader.fieldnames:
                raise ValueError(f"azure memory {memory_csv}: missing "
                                 f"HashApp/AverageAllocatedMb column(s)")
            for r in reader:
                mb = float(r["AverageAllocatedMb"])
                mem_of[r["HashApp"]] = int(np.clip(mb, 16, 1024) * MB)
        return mem_of

    def _ingest(self, path, dur_cdf, dur_mean, mem_of, max_minutes,
                minute_range) -> list:
        """One chunked pass over the invocations CSV: validate the
        schema, assign stable ids in file order, and reduce each row to
        a columnar :class:`_Row`. Only ``chunk_rows`` raw CSV rows are
        resident at a time."""
        fid_of: dict = {}
        tenant_of: dict = {}
        rows: list = []
        with open(path, newline="") as f:
            reader = csv.DictReader(f)
            header = reader.fieldnames
            if header is None:
                raise ValueError(f"azure trace {path}: empty file "
                                 f"(no header)")
            missing = [c for c in _REQUIRED_INV_COLS if c not in header]
            if missing:
                raise ValueError(
                    f"azure trace {path}: missing required column(s) "
                    f"{missing}; expected the Azure Functions 2019 "
                    f"invocations_per_function schema")
            minute_cols = sorted((c for c in header if c.isdigit()), key=int)
            if not minute_cols:
                raise ValueError(
                    f"azure trace {path}: no per-minute count columns "
                    f"(integer-named, e.g. '1'..'1440') found")
            if max_minutes is not None:
                minute_cols = [c for c in minute_cols
                               if int(c) <= max_minutes]
                if not minute_cols:
                    raise ValueError(
                        f"azure trace {path}: no minute columns within "
                        f"max_minutes={max_minutes}")
            if minute_range is not None:
                lo, hi = minute_range
                minute_cols = [c for c in minute_cols if lo <= int(c) <= hi]
                if not minute_cols:
                    raise ValueError(
                        f"azure trace {path}: no minute columns within "
                        f"minute_range={minute_range}")
            self._window = [int(c) for c in minute_cols]

            n_rows = 0
            chunk: list = []
            while True:
                row = next(reader, None)
                if row is not None:
                    chunk.append(row)
                if row is not None and len(chunk) < self.chunk_rows:
                    continue
                for r in chunk:
                    n_rows += 1
                    rows.append(self._reduce_row(
                        r, n_rows, path, minute_cols, fid_of, tenant_of,
                        dur_cdf, dur_mean, mem_of))
                chunk.clear()
                if row is None:
                    break
            if n_rows == 0:
                raise ValueError(f"azure trace {path}: no data rows")
        return [r for r in rows if r is not None]

    def _reduce_row(self, r, row_no, path, minute_cols, fid_of, tenant_of,
                    dur_cdf, dur_mean, mem_of) -> Optional[_Row]:
        fkey = r["HashFunction"]
        app = r["HashApp"]
        owner = r["HashOwner"]
        # stable integer ids in file order, assigned to EVERY row (even
        # all-zero ones) so ids never depend on windowing or selection
        fid = fid_of.setdefault(fkey, len(fid_of))
        tenant = tenant_of.setdefault(owner, len(tenant_of))
        minutes = []
        counts = []
        for col in minute_cols:
            n = _parse_count(r.get(col), path, row_no, col)
            if n > 0:
                minutes.append(int(col))
                counts.append(n)
        if not minutes:
            return None
        mem = mem_of.get(app)
        if mem is None:
            mem = _app_mem_fallback(app, self.seed)
        return _Row(fid=fid, tenant=tenant,
                    key_crc=_crc(owner, app, fkey), mem_bytes=mem,
                    dur_cdf=dur_cdf.get(fkey), dur_mean_s=dur_mean.get(fkey),
                    minutes=np.asarray(minutes, np.int32),
                    counts=np.asarray(counts, np.int64),
                    total=int(sum(counts)))

    def _select(self, rows: list) -> list:
        """Top-K / stratified selection over the windowed rows. ``top``
        keeps the K busiest function rows; ``stratified`` splits the
        popularity ranking into K strata and keeps one seeded pick per
        stratum, so a small budget still spans head, torso, and tail."""
        if self.top_k is None or self.top_k >= len(rows):
            return rows
        ranked = sorted(rows, key=lambda r: (-r.total, r.fid))
        if self.select == "top":
            kept = ranked[:self.top_k]
        else:
            strata = np.array_split(np.arange(len(ranked)), self.top_k)
            kept = []
            for i, stratum in enumerate(strata):
                if len(stratum) == 0:
                    continue
                rng = np.random.default_rng(
                    np.random.SeedSequence((self.seed, _STRATUM_TAG, i)))
                kept.append(ranked[int(rng.choice(stratum))])
        return sorted(kept, key=lambda r: r.fid)

    # -- streaming interface ----------------------------------------------
    def __iter__(self):
        count = 0
        for m in self._window:
            cell_rows = self._by_minute.get(m)
            if not cell_rows:
                continue
            ts_parts, dur_parts, fid_parts, ten_parts, mem_parts = \
                [], [], [], [], []
            for idx in cell_rows:
                row = self._rows[idx]
                cell = _expand_cell(row, m, self.keep, self.seed)
                if cell is None:
                    continue
                ts, durs = cell
                ts_parts.append(ts)
                dur_parts.append(durs)
                n = len(ts)
                fid_parts.append(np.full(n, row.fid, np.int64))
                ten_parts.append(np.full(n, row.tenant, np.int64))
                mem_parts.append(np.full(n, row.mem_bytes, np.int64))
            if not ts_parts:
                continue
            ts = np.concatenate(ts_parts)
            durs = np.concatenate(dur_parts)
            fids = np.concatenate(fid_parts)
            tenants = np.concatenate(ten_parts)
            mems = np.concatenate(mem_parts)
            # minute intervals are disjoint, so per-minute (t, fid) order
            # equals the in-memory loader's global sort
            order = np.lexsort((fids, ts))
            self.peak_buffered = max(self.peak_buffered, len(ts))
            count += len(ts)
            for i in order:
                yield Invocation(t=float(ts[i]), fid=int(fids[i]),
                                 tenant=int(tenants[i]),
                                 duration_s=float(durs[i]),
                                 mem_bytes=int(mems[i]))
        self.last_count = count

    def functions(self) -> list:
        """The registrable workload — one :class:`TraceFunction` per
        distinct fid of the kept rows — without expanding invocations."""
        by_fid: dict = {}
        for r in self._rows:
            f = by_fid.get(r.fid)
            if f is None:
                by_fid[r.fid] = TraceFunction(r.fid, r.tenant, r.mem_bytes,
                                              r.total)
            else:
                by_fid[r.fid] = f._replace(
                    total_invocations=f.total_invocations + r.total)
        return [by_fid[fid] for fid in sorted(by_fid)]

    def shard(self, n_shards: int, shard_index: int) -> "StreamingTrace":
        """The tenant-partitioned sub-trace ``tenant % n_shards ==
        shard_index``. Shards partition this trace exactly: selection
        and the thinning rate are fixed before the shard filter, so the
        union of all shards' invocations equals the unsharded stream."""
        return StreamingTrace(self.path, n_shards=n_shards,
                              shard_index=shard_index, **self._kw)

    def window(self, first_minute: int, last_minute: int) -> "StreamingTrace":
        """A minute-label window of the same trace (inclusive bounds)."""
        kw = dict(self._kw, minute_range=(first_minute, last_minute),
                  max_minutes=None)
        return StreamingTrace(self.path, n_shards=self.n_shards,
                              shard_index=self.shard_index, **kw)

    @property
    def meta(self) -> dict:
        return {"path": self.path, "target_rps": self.target_rps,
                "thinning_keep": self.keep,
                "raw_invocations": self.raw_invocations,
                "minutes": len(self._window), "seed": self.seed,
                "top_k": self.top_k, "select": self.select,
                "n_shards": self.n_shards, "shard_index": self.shard_index}

    @property
    def duration_s(self) -> float:
        return 60.0 * self._window[-1]

    def describe(self) -> dict:
        """Workload provenance without forcing an expansion pass:
        ``invocations`` is exact after one full iteration (the bench
        sweeps iterate before describing) and a thinning estimate
        before."""
        n = self.last_count if self.last_count is not None \
            else int(round(self.raw_invocations * self.keep))
        fns = self.functions()
        d = self.duration_s
        return {**self.meta, "source": self.source, "invocations": n,
                "functions": len(fns),
                "tenants": len({f.tenant for f in fns}),
                "duration_s": d,
                "mean_rps": n / d if d > 0 else 0.0}
