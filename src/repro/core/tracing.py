"""End-to-end request tracing: spans, phase attribution, flight recorder.

Every request the gateway admits can carry a ``RequestTrace`` context
object down the live stack — ``Gateway._serve`` → adapter →
``HydraPlatform.invoke``/``HydraCluster.invoke`` →
``HydraRuntime._do_invoke`` → ``ArenaPool.acquire`` — collecting one
span per request-path phase. The phase vocabulary is closed (``PHASES``
below is the single registry; hydralint HL008 rejects ad-hoc names) so
aggregated per-phase latency is comparable across PRs and attributable
against the simulator's cost model:

    admission      gateway front door: routing + token bucket + enqueue
    queue_wait     bounded per-tenant queue: enqueue -> worker pickup
    pool_claim     platform pool handover (or inline boot on pool miss)
    register       code install into the claimed runtime (fn_register_s)
    restore        snapshot restore of an evicted function (restore_s)
    arena_acquire  slab claim; ``kind`` attr = reuse | zeroed | cold
    dispatch       runtime work queue: enqueue -> worker dequeue
    compute        compiled executable dispatch + block_until_ready
    body           emulated function body (trace duration, compressed)

Phases are disjoint intervals inside the request window, so they admit
a conservation invariant: span lengths plus the uncovered gaps
(``unattributed``) equal end-to-end latency exactly, modulo measured
``overlap`` (expected ~0; asserted small by tests and the CI
trace-smoke check). Timestamps all come from ``trace_now``
(``time.perf_counter``) on every thread, so cross-thread spans share
one clock.

Three consumers:

  * **Chrome trace export** (``export_chrome``): one Perfetto-loadable
    JSON (trace-event "X" entries, one track per request) written by
    ``serve --gateway --trace-out``; ``python -m repro.core.tracing
    --check spans.json`` re-validates the schema and the conservation
    invariant (CI trace-smoke).
  * **Aggregation** (``summary``/``attribution``): bounded per-phase
    histograms feed the replay extras, the ``CalibrationProbe``
    payload, the ``BENCH_trace.json`` gateway leg, and ``validate
    --attribute`` (which phase drives the live-vs-sim p99/cold delta).
  * **Flight recorder** (``FlightRecorder``): a bounded ring of the
    last N finished traces, dumped as JSONL with a metrics snapshot
    when an anomaly fires (SLO violation, OOM give-up, migration
    requeue) — the triage artifact for "the gate failed, which phase?".

Sampling is head-based and deterministic: request index i is sampled
iff ``mix64(seed, i) / 2^64 < sample_rate``, so a fixed seed replays
the same sampling decisions. The disabled path is near-zero: an
unsampled request carries the shared ``NULL_TRACE`` singleton whose
span methods are no-ops (no allocation, no locking, no clock reads) —
``benchmarks/bench_hotpath.py`` measures and budget-gates exactly that.

This module must stay pure on the hot path (HL002): span bookkeeping
is clock reads + list appends; only the flight-recorder dump — an
anomaly-path action on a request that is already being dropped — does
file I/O, behind a scoped lint disable.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Optional

from repro.core.metrics import Histogram

# the span-name registry (hydralint HL008 validates every tracer.span()
# call site against this tuple; keep docs/observability.md in sync)
PHASES = (
    "admission",
    "queue_wait",
    "pool_claim",
    "register",
    "restore",
    "arena_acquire",
    "dispatch",
    "compute",
    "body",
)
# computed, never emitted by a span call: the uncovered remainder of the
# request window (and the arena_acquire claim-kind splits)
UNATTRIBUTED = "unattributed"
ARENA_KINDS = ("reuse", "zeroed", "cold")
# the fixed aggregation vocabulary (stable key set for BENCH_trace.json)
SUMMARY_KEYS = PHASES + tuple(f"arena_acquire.{k}" for k in ARENA_KINDS) \
    + (UNATTRIBUTED, "total")

CHROME_SCHEMA = "hydra-trace/v1"
FLIGHT_SCHEMA = "hydra-flight/v1"

# one clock for every span on every thread (perf_counter and monotonic
# are the same CLOCK_MONOTONIC on Linux, but mixing them is a latent
# cross-platform conservation bug — all tracing code must use this)
trace_now = time.perf_counter

_M64 = (1 << 64) - 1


def _mix64(seed: int, i: int) -> int:
    """splitmix64 finalizer over (seed, i): a stateless, seekable hash
    so sampling decisions are reproducible per request index."""
    x = ((i + 1) * 0x9E3779B97F4A7C15 + seed * 0xBF58476D1CE4E5B9) & _M64
    x ^= x >> 30
    x = (x * 0xBF58476D1CE4E5B9) & _M64
    x ^= x >> 27
    x = (x * 0x94D049BB133111EB) & _M64
    x ^= x >> 31
    return x


class _NullSpan:
    """Shared no-op span: the disabled path allocates nothing."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs) -> None:
        pass


class _NullTrace:
    """Shared no-op request context (the head-sampling 'no' branch and
    the tracer-less gateway both hand this out)."""
    __slots__ = ()
    sampled = False

    def span(self, name: str):
        return _NULL_SPAN

    def add_span(self, name: str, t0: float, t1: float, **attrs) -> None:
        pass

    def finish(self, status: str = "ok") -> None:
        pass


_NULL_SPAN = _NullSpan()
NULL_TRACE = _NullTrace()


class Span:
    """One timed phase inside a request; always used as a context
    manager (``with ctx.span("compute") as sp: ... sp.set(kind=...)``).
    Closing appends the record to the owning trace — an exception
    propagates, but the span is still recorded."""
    __slots__ = ("_trace", "name", "attrs", "t0", "t1")

    def __init__(self, trace: "RequestTrace", name: str):
        self._trace = trace
        self.name = name
        self.attrs: Optional[dict] = None
        self.t0 = 0.0
        self.t1 = 0.0

    def set(self, **attrs) -> None:
        if self.attrs is None:
            self.attrs = attrs
        else:
            self.attrs.update(attrs)

    def __enter__(self) -> "Span":
        self.t0 = trace_now()
        return self

    def __exit__(self, *exc):
        self.t1 = trace_now()
        self._trace._append(self.name, self.t0, self.t1, self.attrs)
        return False


@dataclass
class PhaseBreakdown:
    """Per-request phase decomposition with the conservation identity
    ``sum(phases) + unattributed == total + overlap`` (phases are the
    measured span lengths; unattributed is the uncovered remainder of
    the request window; overlap — expected ~0 — is span time counted
    twice by overlapping intervals)."""
    phases: dict                   # name -> seconds, incl. UNATTRIBUTED
    total_s: float
    overlap_s: float

    @classmethod
    def compute(cls, spans: list, total_s: float) -> "PhaseBreakdown":
        phases = {}
        measured = 0.0
        for name, t0, t1, _attrs in spans:
            d = max(0.0, t1 - t0)
            phases[name] = phases.get(name, 0.0) + d
            measured += d
        covered = sum(t1 - t0 for t0, t1 in
                      _interval_union([(t0, t1) for _n, t0, t1, _a in spans]))
        phases[UNATTRIBUTED] = max(0.0, total_s - covered)
        return cls(phases=phases, total_s=total_s,
                   overlap_s=max(0.0, measured - covered))

    def conservation_error_s(self) -> float:
        """|sum(phases) − total − overlap|: ~0 by construction; tests
        assert it stays below epsilon end to end through the export."""
        return abs(sum(self.phases.values()) - self.total_s
                   - self.overlap_s)


def _interval_union(intervals: list) -> list:
    """Disjoint, sorted union of (t0, t1) intervals."""
    out = []
    for t0, t1 in sorted(intervals):
        if t1 <= t0:
            continue
        if out and t0 <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], t1))
        else:
            out.append((t0, t1))
    return out


class RequestTrace:
    """Span collection for ONE sampled request.

    Threading contract: the request's control flow hands the object
    across threads sequentially (gateway worker → runtime queue →
    runtime worker → back through the Future), so span appends never
    race and need no lock; ``finish`` publishes the completed trace to
    the (locked) tracer exactly once.
    """
    __slots__ = ("tracer", "trace_id", "fid", "tenant", "t0", "spans",
                 "status", "total_s", "breakdown", "_finished")

    def __init__(self, tracer: "Tracer", trace_id: int, fid: str,
                 tenant: Optional[str]):
        self.tracer = tracer
        self.trace_id = trace_id
        self.fid = fid
        self.tenant = tenant
        self.t0 = trace_now()
        self.spans: list = []          # (name, t0, t1, attrs|None)
        self.status = "open"
        self.total_s = 0.0
        self.breakdown: Optional[PhaseBreakdown] = None
        self._finished = False

    sampled = True

    def span(self, name: str) -> Span:
        return Span(self, name)

    def add_span(self, name: str, t0: float, t1: float, **attrs) -> None:
        """Retroactive span from two already-taken timestamps (used for
        waits measured across threads: queue_wait, dispatch)."""
        self._append(name, t0, t1, attrs or None)

    def _append(self, name, t0, t1, attrs) -> None:
        self.spans.append((name, t0, t1, attrs))

    def finish(self, status: str = "ok") -> None:
        if self._finished:
            return
        self._finished = True
        self.status = status
        self.total_s = max(0.0, trace_now() - self.t0)
        self.breakdown = PhaseBreakdown.compute(self.spans, self.total_s)
        self.tracer._on_finish(self)

    def to_dict(self) -> dict:
        bd = self.breakdown
        return {
            "trace_id": self.trace_id,
            "fid": self.fid,
            "tenant": self.tenant,
            "t0": self.t0,
            "total_s": self.total_s,
            "status": self.status,
            "spans": [{"name": n, "t0": t0, "t1": t1,
                       **({"attrs": a} if a else {})}
                      for n, t0, t1, a in self.spans],
            "phases": dict(bd.phases) if bd else {},
            "overlap_s": bd.overlap_s if bd else 0.0,
        }


class FlightRecorder:
    """Bounded ring of the last ``ring`` finished traces, dumped as
    JSONL when an anomaly fires. Dumps are capped at ``max_dumps`` per
    replay so an anomaly storm (every request timing out) cannot turn
    the recorder into an unbounded disk writer."""

    def __init__(self, out_dir: str, *, ring: int = 256,
                 max_dumps: int = 8):
        self.out_dir = out_dir
        self.max_dumps = max_dumps
        self._ring: deque = deque(maxlen=ring)
        self._lock = threading.Lock()
        self.dumps = 0
        self.dropped = 0               # anomalies past the dump cap
        os.makedirs(out_dir, exist_ok=True)

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def record(self, trace_dict: dict) -> None:
        with self._lock:
            self._ring.append(trace_dict)

    # hydralint: disable=HL002 — anomaly-path file I/O by design: the
    # dump runs for a request that is already being dropped, bounded by
    # max_dumps, never on the steady-state serve path
    def dump(self, kind: str, extra: Optional[dict] = None) -> Optional[str]:
        with self._lock:
            if self.dumps >= self.max_dumps:
                self.dropped += 1
                return None
            self.dumps += 1
            seq = self.dumps
            traces = list(self._ring)
        path = os.path.join(self.out_dir, f"flight-{seq:03d}-{kind}.jsonl")
        header = {"schema": FLIGHT_SCHEMA, "anomaly": kind,
                  "wall_time": time.time(), "n_traces": len(traces),
                  **(extra or {})}
        with open(path, "w") as f:
            f.write(json.dumps(header, default=str) + "\n")
            for tr in traces:
                f.write(json.dumps(tr, default=str) + "\n")
        return path


class Tracer:
    """Thread-safe span collector with deterministic head sampling.

    ``start_request`` is the only hot-path entry: it either hands back
    the shared ``NULL_TRACE`` (unsampled) or a fresh ``RequestTrace``.
    Finished traces are aggregated into bounded per-phase histograms
    and a bounded ``traces`` deque (Chrome export reads the latter, so
    an unbounded replay cannot hold every span in memory — ``dropped``
    counts what the export window lost).
    """

    def __init__(self, sample_rate: float = 1.0, *, seed: int = 0,
                 max_traces: int = 4096,
                 flight: Optional[FlightRecorder] = None,
                 hist_max_samples: int = 8192):
        self.sample_rate = float(sample_rate)
        self.seed = int(seed)
        self.flight = flight
        self._lock = threading.Lock()
        self._index = 0
        self._sampled = 0
        self._finished = 0
        self._dropped = 0
        self._anomalies: dict = {}
        self._done: deque = deque(maxlen=max_traces)
        self._hist_max = hist_max_samples
        self._phase_hists: dict = {k: Histogram(max_samples=hist_max_samples)
                                   for k in SUMMARY_KEYS}
        self._overlap_peak_s = 0.0
        self._metrics_cb: Optional[Callable[[], dict]] = None

    # -- hot path ----------------------------------------------------------
    def start_request(self, fid: str, tenant: Optional[str] = None):
        """A ``RequestTrace`` when this request is head-sampled, else
        the shared no-op ``NULL_TRACE``."""
        if self.sample_rate <= 0.0:
            return NULL_TRACE
        with self._lock:
            i = self._index
            take = (self.sample_rate >= 1.0
                    or _mix64(self.seed, i) / 2.0**64 < self.sample_rate)
            self._index += 1
            if take:
                self._sampled += 1
        if not take:
            return NULL_TRACE
        return RequestTrace(self, i, fid, tenant)

    def would_sample(self, index: int) -> bool:
        """The (deterministic) sampling decision for request ``index``
        — exposed so tests can pin head-sampling reproducibility."""
        if self.sample_rate <= 0.0:
            return False
        return (self.sample_rate >= 1.0
                or _mix64(self.seed, index) / 2.0**64 < self.sample_rate)

    def _on_finish(self, trace: RequestTrace) -> None:
        d = trace.to_dict()
        bd = trace.breakdown
        with self._lock:
            self._finished += 1
            if len(self._done) == self._done.maxlen:
                self._dropped += 1
            self._done.append(d)
            self._overlap_peak_s = max(self._overlap_peak_s, bd.overlap_s)
            hists = self._phase_hists
            hists["total"].observe(trace.total_s)
            for name, secs in bd.phases.items():
                h = hists.get(name)
                if h is None:
                    h = hists[name] = Histogram(
                        max_samples=self._hist_max)
                h.observe(secs)
            for name, t0, t1, attrs in trace.spans:
                kind = (attrs or {}).get("kind")
                if name == "arena_acquire" and kind in ARENA_KINDS:
                    hists[f"arena_acquire.{kind}"].observe(max(0.0, t1 - t0))
        fl = self.flight
        if fl is not None:
            fl.record(d)

    # -- anomalies ---------------------------------------------------------
    def set_metrics_provider(self, cb: Callable[[], dict]) -> None:
        """Callback supplying the metrics snapshot embedded in flight
        dumps (the replay wires the adapter's fleet sample in)."""
        with self._lock:
            self._metrics_cb = cb

    def anomaly(self, kind: str, fid: Optional[str] = None,
                ctx=None) -> Optional[str]:
        """Count one anomaly and (when a flight recorder is attached)
        dump the ring + a metrics snapshot. Returns the dump path."""
        with self._lock:
            self._anomalies[kind] = self._anomalies.get(kind, 0) + 1
            cb = self._metrics_cb
        fl = self.flight
        if fl is None:
            return None
        extra: dict = {"fid": fid}
        if ctx is not None and getattr(ctx, "sampled", False):
            extra["trigger"] = ctx.to_dict()
        if cb is not None:
            try:
                extra["metrics"] = cb()
            except Exception as e:   # a racing shutdown must not lose the dump
                extra["metrics_error"] = f"{type(e).__name__}: {e}"
        return fl.dump(kind, extra)

    # -- aggregation -------------------------------------------------------
    def traces(self) -> list:
        with self._lock:
            return list(self._done)

    def summary(self) -> dict:
        """Fixed-vocabulary aggregate: counts plus per-phase wall-ms
        p50/p99/mean for every ``SUMMARY_KEYS`` entry (None when a
        phase never fired — the key set is stable for the
        ``BENCH_trace.json`` schema gate)."""
        with self._lock:
            hists = dict(self._phase_hists)
            out = {
                "requests": self._index,
                "sampled": self._sampled,
                "finished": self._finished,
                "export_window_dropped": self._dropped,
                "sample_rate": self.sample_rate,
                "anomalies": dict(self._anomalies),
                "overlap_peak_ms": self._overlap_peak_s * 1e3,
            }
        phases = {}
        for name in SUMMARY_KEYS:
            h = hists[name]
            if h.count:
                s = h.snapshot()
                phases[name] = {"count": s["count"],
                                "mean_ms": s["mean"] * 1e3,
                                "p50_ms": s["p50"] * 1e3,
                                "p99_ms": s["p99"] * 1e3}
            else:
                phases[name] = {"count": 0, "mean_ms": None,
                                "p50_ms": None, "p99_ms": None}
        out["phases"] = phases
        if self.flight is not None:
            out["flight"] = {"recorded": len(self.flight),
                             "dumps": self.flight.dumps,
                             "dump_cap_dropped": self.flight.dropped}
        return out

    def attribution(self, tail_q: float = 0.99) -> dict:
        """Which phase dominates the latency tail, and which dominates
        cold requests — the measured answer to "what drives the
        live-vs-sim p99/cold delta" (``validate --attribute``).

        ``body`` is excluded from dominance (the emulated duration is
        modeled identically by the sim; only overhead phases can
        explain a divergence). ``unattributed`` stays in: an untraced
        dominant cost is a finding, not noise.
        """
        traces = self.traces()
        out = {"requests": len(traces)}
        if not traces:
            out["p99"] = out["cold"] = None
            return out
        totals = sorted(t["total_s"] for t in traces)
        thresh = totals[min(len(totals) - 1,
                            int(math.ceil(tail_q * len(totals))) - 1)]
        tail = [t for t in traces if t["total_s"] >= thresh]
        cold = [t for t in traces if _is_cold(t)]
        out["p99"] = _attribute_group(tail, {"threshold_s": thresh})
        out["cold"] = _attribute_group(cold, {})
        return out


def _is_cold(trace_dict: dict) -> bool:
    """A request that paid any cold-path cost: a cold slab mint, a
    pool-miss inline boot, a code install, or a snapshot restore."""
    for sp in trace_dict["spans"]:
        name = sp["name"]
        attrs = sp.get("attrs") or {}
        if name == "arena_acquire" and attrs.get("kind") == "cold":
            return True
        if name == "pool_claim" and attrs.get("source") == "boot":
            return True
        if name in ("register", "restore"):
            return True
    return False


def _attribute_group(traces: list, base: dict) -> Optional[dict]:
    if not traces:
        return None
    sums: dict = {}
    for t in traces:
        for name, secs in t["phases"].items():
            sums[name] = sums.get(name, 0.0) + secs
    n = len(traces)
    means = {name: (s / n) * 1e3 for name, s in sums.items()}
    candidates = {k: v for k, v in means.items() if k != "body"}
    dominant = max(candidates, key=candidates.get) if candidates else None
    return {**base, "n": n, "phase_mean_ms": means, "dominant": dominant}


# ---------------------------------------------------------------------------
# Chrome trace-event export + validation (Perfetto-loadable)
# ---------------------------------------------------------------------------

def chrome_trace(traces: list, meta: Optional[dict] = None) -> dict:
    """Chrome trace-event JSON from ``Tracer.traces()`` output: one
    complete ("X") event per request on its own track (tid =
    trace_id), one per span, and explicit ``unattributed`` events for
    the uncovered gaps — so the events of a track sum to the request's
    end-to-end duration (the conservation invariant ``--check``
    re-verifies)."""
    events = []
    t_base = min((t["t0"] for t in traces), default=0.0)

    def us(t: float) -> float:
        return (t - t_base) * 1e6

    for t in traces:
        tid = t["trace_id"]
        events.append({
            "name": "request", "cat": "request", "ph": "X",
            "ts": us(t["t0"]), "dur": t["total_s"] * 1e6,
            "pid": 1, "tid": tid,
            "args": {"trace_id": tid, "fid": t["fid"],
                     "tenant": t["tenant"], "status": t["status"],
                     "overlap_ms": t["overlap_s"] * 1e3},
        })
        intervals = []
        for sp in t["spans"]:
            intervals.append((sp["t0"], sp["t1"]))
            events.append({
                "name": sp["name"], "cat": "phase", "ph": "X",
                "ts": us(sp["t0"]),
                "dur": max(0.0, sp["t1"] - sp["t0"]) * 1e6,
                "pid": 1, "tid": tid,
                "args": sp.get("attrs") or {},
            })
        cur = t["t0"]
        t_end = t["t0"] + t["total_s"]
        for s0, s1 in _interval_union(intervals):
            s0, s1 = max(s0, cur), min(s1, t_end)
            if s0 > cur:
                events.append({"name": UNATTRIBUTED, "cat": "phase",
                               "ph": "X", "ts": us(cur),
                               "dur": (s0 - cur) * 1e6,
                               "pid": 1, "tid": tid, "args": {}})
            cur = max(cur, s1)
        if t_end > cur:
            events.append({"name": UNATTRIBUTED, "cat": "phase", "ph": "X",
                           "ts": us(cur), "dur": (t_end - cur) * 1e6,
                           "pid": 1, "tid": tid, "args": {}})
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"schema": CHROME_SCHEMA, "phases": list(PHASES),
                      **(meta or {})},
    }


def export_chrome(tracer: Tracer, path: str,
                  meta: Optional[dict] = None) -> dict:
    doc = chrome_trace(tracer.traces(), meta=meta)
    with open(path, "w") as f:
        json.dump(doc, f)
        f.write("\n")
    return doc


def validate_chrome(doc: Any, epsilon_ms: float = 2.0) -> list:
    """Schema + conservation errors for an exported span file (empty
    list = valid). Checks the trace-event shape Perfetto requires and,
    per request track, that phase events sum to the request's duration
    within ``epsilon_ms`` plus 1% (clock-read jitter scales with the
    number of spans, never with the request length)."""
    errors: list = []
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        return ["not a trace-event document (traceEvents list missing)"]
    known = set(PHASES) | {UNATTRIBUTED, "request"}
    by_tid: dict = {}
    for i, ev in enumerate(doc["traceEvents"]):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        for field_name in ("name", "ph", "ts", "dur", "pid", "tid"):
            if field_name not in ev:
                errors.append(f"event {i}: missing {field_name!r}")
        if ev.get("ph") != "X":
            errors.append(f"event {i}: ph={ev.get('ph')!r} (expected "
                          f"complete 'X' events)")
            continue
        if not isinstance(ev.get("ts"), (int, float)) \
                or not isinstance(ev.get("dur"), (int, float)) \
                or ev.get("dur", 0) < 0 \
                or not math.isfinite(ev.get("ts", 0.0)) \
                or not math.isfinite(ev.get("dur", 0.0)):
            errors.append(f"event {i} ({ev.get('name')}): bad ts/dur")
            continue
        if ev.get("name") not in known:
            errors.append(f"event {i}: unknown span name "
                          f"{ev.get('name')!r} (registry: {sorted(known)})")
            continue
        by_tid.setdefault(ev.get("tid"), []).append(ev)
    for tid, evs in sorted(by_tid.items(), key=lambda kv: str(kv[0])):
        reqs = [e for e in evs if e["name"] == "request"]
        if len(reqs) != 1:
            errors.append(f"track {tid}: {len(reqs)} request events "
                          f"(expected exactly 1)")
            continue
        req = reqs[0]
        total_us = req["dur"]
        phase_us = sum(e["dur"] for e in evs if e["name"] != "request")
        eps_us = epsilon_ms * 1e3 + 0.01 * total_us
        if abs(phase_us - total_us) > eps_us:
            errors.append(
                f"track {tid}: conservation violated — phases sum to "
                f"{phase_us:.0f}us vs request {total_us:.0f}us "
                f"(epsilon {eps_us:.0f}us)")
        for e in evs:
            if e["name"] == "request":
                continue
            if e["ts"] < req["ts"] - eps_us \
                    or e["ts"] + e["dur"] > req["ts"] + total_us + eps_us:
                errors.append(f"track {tid}: span {e['name']} outside "
                              f"the request window")
    if not by_tid:
        errors.append("no request tracks (empty trace)")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Validate an exported Chrome trace-event span file "
                    "(serve --gateway --trace-out): Perfetto-loadable "
                    "schema plus the per-request phase-conservation "
                    "invariant. Exits 1 on any violation.")
    ap.add_argument("--check", metavar="PATH", required=True,
                    help="spans JSON to validate (Chrome trace-event "
                         "format as written by --trace-out)")
    ap.add_argument("--epsilon-ms", type=float, default=2.0,
                    help="absolute conservation tolerance per request "
                         "(plus 1%% of the request duration)")
    args = ap.parse_args(argv)
    try:
        with open(args.check) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"tracing: cannot read {args.check}: {e}", file=sys.stderr)
        return 2
    errors = validate_chrome(doc, epsilon_ms=args.epsilon_ms)
    for e in errors:
        print(f"# FAIL {e}", file=sys.stderr)
    if errors:
        return 1
    n = len({ev.get("tid") for ev in doc["traceEvents"]})
    print(f"tracing: {args.check} OK — {n} request tracks, "
          f"{len(doc['traceEvents'])} events, conservation within "
          f"{args.epsilon_ms:g}ms")
    return 0


if __name__ == "__main__":
    sys.exit(main())
