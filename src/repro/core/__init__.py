"""Hydra virtualized runtime — the paper's primary contribution in JAX.

One runtime process per pod slice hosts many registered functions (models)
with shared AOT-compiled executables, pooled memory arenas (isolates), and
byte-accurate budgets. See DESIGN.md for the paper-concept mapping.
"""
from repro.core.arena import Arena, ArenaPool, tree_bytes
from repro.core.budget import MemoryBudget
from repro.core.cluster import (AdaptivePoolPolicy, ArrivalRateEstimator,
                                ClusterParams, HydraCluster)
from repro.core.errors import (AdmissionError, FunctionNotRegisteredError,
                               HydraError, HydraOOMError)
from repro.core.executable_cache import ExecutableCache
from repro.core.platform import HydraPlatform, PlatformParams
from repro.core.registry import CallableSpec, Function, FunctionRegistry, LMSpec
from repro.core.runtime import HydraRuntime
from repro.core.scheduler import ContinuousBatcher, TokenBucket

__all__ = [
    "Arena", "ArenaPool", "tree_bytes", "MemoryBudget", "ExecutableCache",
    "CallableSpec", "Function", "FunctionRegistry", "LMSpec", "HydraRuntime",
    "HydraPlatform", "PlatformParams", "HydraCluster", "ClusterParams",
    "AdaptivePoolPolicy", "ArrivalRateEstimator",
    "ContinuousBatcher", "TokenBucket", "HydraError", "HydraOOMError",
    "FunctionNotRegisteredError", "AdmissionError",
]
