"""Model-agnostic discrete-event simulation engine.

The engine owns the *mechanics* every platform model shares: the event
heap, per-node memory accounting, metric sampling on a fixed grid,
queueing with retry/backoff and give-up, idle-runtime reclaim under
memory pressure, warm-pool refill, isolate-TTL eviction, keep-alive
expiry, and drain-to-pool of emptied runtimes. Every *policy* decision —
how invocations group into runtimes, where a new runtime boots, what a
startup costs, how warm pools resize — lives in a
:class:`~repro.core.sim.models.PlatformModel` subclass; the engine calls
its hooks and never branches on a model name.

``simulate`` / ``compare`` / ``simulate_partitioned`` (the public entry
points that resolve a model name through the ``MODELS`` registry) live in
``repro.core.sim`` (the package ``__init__``); ``repro.core.tracesim``
re-exports everything for back-compat.
"""
from __future__ import annotations

import heapq
from collections.abc import Sequence
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

MB = 1 << 20
GB = 1 << 30


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SimParams:
    # startup costs (seconds) — paper Fig 1/8 scale; override with values
    # measured on your host via ``repro.core.calibrate`` (bench_startup
    # --emit-calibration)
    runtime_cold_s: float = 0.150      # native runtime boot (cold start)
    hydra_runtime_cold_s: float = 0.046  # AOT-compiled runtime boot (2-3x faster)
    isolate_cold_s: float = 0.0005     # isolate/arena allocation (<500 us)
    isolate_warm_s: float = 0.00005    # pool hit
    fn_register_s: float = 0.010       # per-function code install (hydra)
    # memory model (bytes)
    runtime_base: int = 30 * MB        # native runtime RSS
    hydra_runtime_base: int = 46 * MB  # polyglot runtime RSS (paper Fig 5)
    isolate_base: int = 1 * MB         # pre-allocated isolate heap
    runtime_cap: int = 2 * GB          # per-runtime budget (hydra/photons)
    machine_cap: int = 16 * GB         # FLEET budget (paper: 16 GB segment)
    keepalive_s: float = 60.0          # worker keep-alive (openwhisk)
    isolate_ttl_s: float = 10.0        # isolate pool TTL
    vm_boot_s: float = 0.125           # Firecracker microVM boot
    retry_backoff_s: float = 0.05      # queue retry when machine is full
    max_wait_s: float = 30.0           # give up queueing after this
    # platform layer (hydra-pool / hydra-cluster models)
    pool_size: int = 4                 # pre-warmed instances (fixed policy)
    pool_claim_s: float = 0.002        # claim a warm instance from the pool
    pool_refill_s: float = 1.0         # background re-warm after a claim
    snapshot_restore_s: float = 0.004  # install a snapshotted fn (vs
                                       # fn_register_s for a first install)
    pool_drain_ttl_s: float = 10.0     # an idle (empty) platform runtime
                                       # drains back to the warm pool after
                                       # this, like HydraPlatform's
                                       # _return_runtime (0 disables)
    # multi-node fleet (hydra-cluster model only)
    n_nodes: int = 4                   # machines in the cluster
    node_cap: Optional[int] = None     # per-node memory; default splits
                                       # machine_cap evenly (fleet total
                                       # stays constant across node counts)
    transfer_gbps: float = 10.0        # cross-node snapshot bandwidth
    snapshot_bytes: int = 24 * MB      # serialized sandbox snapshot size
    adaptive_pool: bool = True         # EWMA-driven per-node pool sizing
    pool_min: int = 2                  # adaptive pool floor (per node)
    pool_max: Optional[int] = None     # adaptive ceiling; default pool_size
    ewma_alpha: float = 0.5            # arrival-rate EWMA smoothing
    pool_cover_s: float = 2.0          # arrivals one warm pool must absorb
                                       # (≈ one cold-boot + refill window)


# ---------------------------------------------------------------------------
@dataclass
class RuntimeInst:
    key: tuple                     # grouping key (fid | tenant, index)
    base_mem: int
    cap: int
    isolate_base: int = MB
    live_mem: int = 0
    live_invocations: int = 0
    last_active: float = 0.0
    ready_at: float = 0.0          # boot completes at this time
    warm_isolates: dict = field(default_factory=dict)  # mem -> (count, t)
    functions_loaded: set = field(default_factory=set)

    def mem(self) -> int:
        # pooled isolates hold only their pre-allocated heap (~1 MB, paper
        # Fig 3); an invocation's working memory is freed at completion
        pool = sum(c for c, _ in self.warm_isolates.values()) \
            * self.isolate_base
        return self.base_mem + self.live_mem + pool


@dataclass
class Node:
    """One machine: its runtime instances, warm pool, snapshot store, and
    (cluster model) EWMA arrival-rate state for adaptive pool sizing."""
    idx: int
    cap: int
    insts: dict = field(default_factory=dict)  # group key -> [RuntimeInst]
    pool_avail: int = 0
    pool_target: int = 0
    pool_pending: int = 0          # refills scheduled but not landed
    rate: float = 0.0              # EWMA arrivals/s
    last_arrival: float = float("-inf")
    snapshots: set = field(default_factory=set)  # fids snapshotted locally


@dataclass
class SimResult:
    model: str
    latencies: list = field(default_factory=list)
    overheads: list = field(default_factory=list)  # latency - pure duration
    mem_samples: list = field(default_factory=list)     # (t, bytes)
    pool_mem_samples: list = field(default_factory=list)  # (t, bytes)
    runtime_count_samples: list = field(default_factory=list)  # (t, n)
    cold_runtime_starts: int = 0
    cold_isolate_starts: int = 0
    warm_isolate_starts: int = 0
    evicted_runtimes: int = 0
    dropped: int = 0
    pool_claims: int = 0           # warm platform-pool instance claims
    transfers: int = 0             # cross-node snapshot transfers
    peak_pool_mem: int = 0         # max bytes held by warm pool slots
    n_nodes: int = 1

    def p(self, q) -> float:
        """Latency percentile; NaN (not a crash) on an empty trace."""
        return float(np.percentile(self.latencies, q)) \
            if self.latencies else float("nan")

    def mean_mem(self) -> float:
        if not self.mem_samples:
            return float("nan")
        return float(np.mean([m for _, m in self.mem_samples]))

    def mean_pool_mem(self) -> float:
        if not self.pool_mem_samples:
            return 0.0
        return float(np.mean([m for _, m in self.pool_mem_samples]))

    def mean_runtimes(self) -> float:
        if not self.runtime_count_samples:
            return float("nan")
        return float(np.mean([n for _, n in self.runtime_count_samples]))

    def ops_per_gb_s(self) -> float:
        """Density: completed invocations per GB-second of fleet footprint
        (the paper's headline 2.41x metric)."""
        if not self.mem_samples or not self.latencies:
            return float("nan")
        duration = self.mem_samples[-1][0]
        gb = self.mean_mem() / GB
        if duration <= 0 or gb <= 0 or not np.isfinite(gb):
            return float("nan")
        return len(self.latencies) / (gb * duration)

    def summary(self) -> dict:
        return {
            "model": self.model,
            "requests": len(self.latencies),
            "p50_s": self.p(50), "p99_s": self.p(99),
            "overhead_p99_ms": 1e3 * float(np.percentile(self.overheads, 99))
            if self.overheads else float("nan"),
            "mean_mem_mb": self.mean_mem() / MB,
            "peak_mem_mb": max(m for _, m in self.mem_samples) / MB
            if self.mem_samples else 0,
            "mean_runtimes": self.mean_runtimes(),
            "cold_runtime": self.cold_runtime_starts,
            "evicted_runtimes": self.evicted_runtimes,
            "cold_isolate": self.cold_isolate_starts,
            "warm_isolate": self.warm_isolate_starts,
            "dropped": self.dropped,
            "pool_claims": self.pool_claims,
            "transfers": self.transfers,
            "peak_pool_mem_mb": self.peak_pool_mem / MB,
            "mean_pool_mem_mb": self.mean_pool_mem() / MB,
            "ops_per_gb_s": self.ops_per_gb_s(),
            "n_nodes": self.n_nodes,
        }


# ---------------------------------------------------------------------------
def _time_sorted(seq) -> bool:
    prev = None
    for inv in seq:
        if prev is not None and inv.t < prev:
            return False
        prev = inv.t
    return True


# ---------------------------------------------------------------------------
class Engine:
    """One simulation run: an event heap plus shared mechanics, with all
    policy delegated to ``self.model`` (a ``PlatformModel``)."""

    def __init__(self, model, params: SimParams, sample_dt: float = 1.0):
        self.model = model
        self.p = params
        self.sample_dt = sample_dt
        self.res = SimResult(model=model.name, n_nodes=model.n_nodes)
        self.nodes = [Node(idx=i, cap=model.node_cap)
                      for i in range(model.n_nodes)]
        for nd in self.nodes:
            model.init_node(nd)
        # heap entries are (t, tier, seq, kind, payload). Tier 0 is trace
        # arrivals, tier 1 everything the engine pushes dynamically: in
        # the eager days every arrival was pushed before any dynamic
        # event, so at equal t the arrival's smaller seq won — the tier
        # keeps that ordering bit-exact now that arrivals stream in
        # lazily with *later* seqs.
        self.events: list = []
        self.seq = 0

    # -- event heap --------------------------------------------------------
    def push(self, t: float, kind: str, payload) -> None:
        self.seq += 1
        heapq.heappush(self.events, (t, 1, self.seq, kind, payload))

    def _push_arrival(self, inv) -> None:
        self.seq += 1
        heapq.heappush(self.events, (inv.t, 0, self.seq, "arrive",
                                     (inv, inv.t)))

    # -- accounting --------------------------------------------------------
    def node_mem(self, nd: Node) -> int:
        return sum(r.mem() for g in nd.insts.values() for r in g) \
            + nd.pool_avail * self.model.base_mem

    def fleet_mem(self) -> int:
        return sum(self.node_mem(nd) for nd in self.nodes)

    def fleet_pool_mem(self) -> int:
        return sum(nd.pool_avail for nd in self.nodes) * self.model.base_mem

    def n_runtimes(self) -> int:
        return sum(len(g) for nd in self.nodes for g in nd.insts.values()) \
            + sum(nd.pool_avail for nd in self.nodes)

    def note_pool_peak(self) -> None:
        self.res.peak_pool_mem = max(self.res.peak_pool_mem,
                                     self.fleet_pool_mem())

    # -- run ---------------------------------------------------------------
    def run(self, trace) -> SimResult:
        """``trace`` may be any iterable of :class:`Invocation`. A
        time-sorted input (every ``Trace``, every ``StreamingTrace``) is
        fed into the heap lazily — one pending arrival at a time — so a
        streamed trace never materializes; the heap holds only in-flight
        events. An unsorted ``Sequence`` falls back to the old eager
        push-everything path (identical results); an unsorted plain
        iterator cannot be simulated single-pass and raises."""
        p, res, model = self.p, self.res, self.model
        arrivals = iter(trace)
        nxt = next(arrivals, None)
        if isinstance(trace, Sequence) and not _time_sorted(trace):
            while nxt is not None:
                self._push_arrival(nxt)
                nxt = next(arrivals, None)

        res.peak_pool_mem = self.fleet_pool_mem()
        next_sample = 0.0
        while self.events or nxt is not None:
            while nxt is not None and (
                    not self.events
                    or (nxt.t, 0) <= (self.events[0][0], self.events[0][1])):
                self._push_arrival(nxt)
                prev_t = nxt.t
                nxt = next(arrivals, None)
                if nxt is not None and nxt.t < prev_t:
                    raise ValueError(
                        f"trace iterator is not time-sorted: arrival at "
                        f"t={nxt.t} after t={prev_t}; sort the trace or "
                        f"pass a Sequence")
            t, _, _, kind, payload = heapq.heappop(self.events)
            while next_sample <= t:
                res.mem_samples.append((next_sample, self.fleet_mem()))
                res.pool_mem_samples.append(
                    (next_sample, self.fleet_pool_mem()))
                res.runtime_count_samples.append(
                    (next_sample, self.n_runtimes()))
                self.note_pool_peak()
                next_sample += self.sample_dt

            if kind == "done":
                nd, inst, inv = payload
                inst.live_invocations -= 1
                inst.last_active = t
                model.on_idle(self, nd, inst, inv, t)
                continue

            if kind == "drain":
                # HydraPlatform._return_runtime: an emptied runtime that
                # stays idle past the TTL becomes a generic warm-pool slot
                # again (or shuts down when the pool is already at target)
                # — its loaded functions survive only as node-local
                # snapshots
                nd, inst = payload
                group = nd.insts.get(inst.key[:-1], [])
                if (inst in group and inst.live_invocations == 0
                        and t - inst.last_active
                        >= p.pool_drain_ttl_s - 1e-9):
                    group.remove(inst)
                    if nd.pool_avail < nd.pool_target:
                        nd.pool_avail += 1
                        self.note_pool_peak()
                continue

            if kind == "evict":
                inst, mem = payload
                cnt, last = inst.warm_isolates.get(mem, (0, t))
                if cnt > 0 and t - last >= p.isolate_ttl_s - 1e-9:
                    inst.warm_isolates[mem] = (0, last)
                continue

            if kind == "refill":
                # background re-warm of a claimed pool slot (off the
                # request path). No node headroom right now -> retry later
                # rather than dropping the slot, like a real re-warmer
                # would. An adaptively-shrunk target just drops the
                # now-surplus slot.
                nd = payload
                nd.pool_pending = max(0, nd.pool_pending - 1)
                if nd.pool_avail < nd.pool_target:
                    if self.node_mem(nd) + model.base_mem <= nd.cap:
                        nd.pool_avail += 1
                        self.note_pool_peak()
                    else:
                        nd.pool_pending += 1
                        self.push(t + p.pool_refill_s, "refill", nd)
                continue

            if kind == "expire":
                nd, key = payload
                group = nd.insts.get(key, [])
                keep = [r for r in group
                        if r.live_invocations > 0
                        or t - r.last_active < p.keepalive_s - 1e-9]
                nd.insts[key] = keep
                continue

            # ---- arrival (possibly a queued retry) ----
            inv, orig_t = payload
            startup = 0.0
            need = inv.mem_bytes + p.isolate_base
            key = model.group_key(inv)

            nd, inst, warm_worker = model.on_arrival(self, inv, need, key)

            if inst is None:
                # new runtime instance: the model picks the node and
                # whether to claim a pre-warmed pool slot; the engine then
                # applies shared admission mechanics — if the node has no
                # room, LRU-evict idle runtimes first (platforms reclaim
                # keep-alive workers); else queue with backoff / give up.
                # A pool claim adds no net base memory: the slot's RSS is
                # already counted in node_mem().
                nd, claim_pool = model.pick_node(self, inv, need)
                extra = need if claim_pool else model.base_mem + need
                if self.node_mem(nd) + extra > nd.cap:
                    idle = sorted((r for g in nd.insts.values() for r in g
                                   if r.live_invocations == 0),
                                  key=lambda r: r.last_active)
                    while idle and self.node_mem(nd) + extra > nd.cap:
                        victim = idle.pop(0)
                        nd.insts[victim.key[:-1]].remove(victim)
                        self.res.evicted_runtimes += 1
                if self.node_mem(nd) + extra > nd.cap:
                    if t - orig_t >= p.max_wait_s:
                        res.dropped += 1
                    else:
                        self.push(t + p.retry_backoff_s, "arrive",
                                  (inv, orig_t))
                    continue
                group = nd.insts.setdefault(key, [])
                inst = RuntimeInst(key=key + (len(group),),
                                   base_mem=model.base_mem,
                                   cap=model.runtime_cap(need),
                                   isolate_base=p.isolate_base)
                group.append(inst)
                model.on_boot(inst, inv)
                if claim_pool:
                    nd.pool_avail -= 1
                    startup += p.pool_claim_s
                    res.pool_claims += 1
                    nd.pool_pending += 1
                    self.push(t + p.pool_refill_s, "refill", nd)
                else:
                    startup += p.vm_boot_s + model.runtime_cold_s
                    res.cold_runtime_starts += 1
                inst.ready_at = t + startup
            else:
                # joining an instance that may still be booting: the
                # invocation waits for the remaining boot time (cold-start
                # amplification under bursts — a warm pool instance is
                # ready ~immediately)
                startup += max(0.0, inst.ready_at - t)

            # the serving node observed an arrival: the model may retarget
            # its warm pool (EWMA-adaptive sizing, cluster model)
            model.adapt_pool(self, nd, t)

            # per-runtime code install (policy: first install vs snapshot
            # restore vs cross-node snapshot transfer)
            startup += model.startup_cost(self, nd, inst, inv)

            # isolate acquire (policy: worker-resident vs pooled isolates)
            startup += model.acquire_isolate(self, inst, inv, warm_worker, t)

            inst.live_invocations += 1
            inst.last_active = t
            latency = (t - orig_t) + startup + inv.duration_s
            res.latencies.append(latency)
            res.overheads.append(latency - inv.duration_s)
            self.push(t + startup + inv.duration_s, "done", (nd, inst, inv))
            self.push(t + startup + inv.duration_s + p.keepalive_s,
                      "expire", (nd, key))

        return res
