"""Platform models: the policy layer of the simulator.

Each runtime model from the paper (plus this repo's platform/cluster
layers) is a :class:`PlatformModel` subclass that answers the engine's
policy questions:

  * ``group_key``       — how invocations group into runtime instances
  * ``on_arrival``      — which existing instance (if any) serves an
                          arrival, and on which node
  * ``pick_node``       — where a NEW instance boots, and whether it is
                          claimed from the pre-warmed pool
  * ``startup_cost``    — per-arrival install cost: first code install vs
                          snapshot restore vs cross-node snapshot transfer
  * ``acquire_isolate`` — isolate/worker acquisition cost + accounting
  * ``on_idle``         — what happens when an invocation completes
                          (release isolates, schedule drain-to-pool)
  * ``adapt_pool``      — warm-pool retargeting on each observed arrival

plus the structural constants (``base_mem``, ``runtime_cold_s``,
``n_nodes``, ``node_cap``, per-instance ``runtime_cap``). The engine
(:mod:`repro.core.sim.engine`) never branches on a model name; adding a
sixth model (e.g. a FaaSnap-style snapshot-restore baseline or a
TrEnv-X shared-environment variant) is one new subclass plus a
``MODELS`` registration.
"""
from __future__ import annotations

import math

from repro.core.sim.engine import Engine, Node, RuntimeInst, SimParams
from repro.core.traces import Invocation


class PlatformModel:
    """Base policy: one node, first-fit packing into the group's
    instances, per-function code install on first load, pooled isolates
    with TTL eviction (the ``photons`` semantics — subclasses override
    the decisions that differ)."""

    name: str = ""
    hydra_like: bool = False     # polyglot runtime constants (cold/base)
    pooled: bool = False         # pre-warmed platform pool + snapshots

    def __init__(self, params: SimParams):
        self.p = params

    # -- structure ---------------------------------------------------------
    @property
    def base_mem(self) -> int:
        return self.p.hydra_runtime_base if self.hydra_like \
            else self.p.runtime_base

    @property
    def runtime_cold_s(self) -> float:
        return self.p.hydra_runtime_cold_s if self.hydra_like \
            else self.p.runtime_cold_s

    @property
    def n_nodes(self) -> int:
        return 1

    @property
    def node_cap(self) -> int:
        return self.p.machine_cap

    def init_node(self, nd: Node) -> None:
        pass

    def runtime_cap(self, need: int) -> int:
        return self.p.runtime_cap

    # -- policy ------------------------------------------------------------
    def group_key(self, inv: Invocation) -> tuple:
        raise NotImplementedError

    def on_arrival(self, eng: Engine, inv: Invocation, need: int,
                   key: tuple):
        """Pick an existing instance for the arrival: first instance in
        the group with budget headroom. Returns (node, inst|None,
        warm_worker)."""
        nd = eng.nodes[0]
        for r in nd.insts.setdefault(key, []):
            if r.mem() + need <= r.cap:
                return nd, r, False
        return nd, None, False

    def pick_node(self, eng: Engine, inv: Invocation, need: int):
        """Place a new instance: (node, claim_from_pool)."""
        return eng.nodes[0], False

    def on_boot(self, inst: RuntimeInst, inv: Invocation) -> None:
        pass

    def startup_cost(self, eng: Engine, nd: Node, inst: RuntimeInst,
                     inv: Invocation) -> float:
        """First time this fid loads into this runtime: full code
        install; shared code caches amortize subsequent loads. The
        snapshot-store bookkeeping feeds the pooled models' restore
        path."""
        if inv.fid in inst.functions_loaded:
            return 0.0
        inst.functions_loaded.add(inv.fid)
        cost = self.install_cost(eng, nd, inv)
        nd.snapshots.add(inv.fid)
        return cost

    def install_cost(self, eng: Engine, nd: Node, inv: Invocation) -> float:
        return self.p.fn_register_s

    def acquire_isolate(self, eng: Engine, inst: RuntimeInst,
                        inv: Invocation, warm_worker: bool,
                        t: float) -> float:
        p = self.p
        cnt, _ = inst.warm_isolates.get(inv.mem_bytes, (0, 0.0))
        if cnt > 0:
            inst.warm_isolates[inv.mem_bytes] = (cnt - 1, t)
            cost = p.isolate_warm_s
            eng.res.warm_isolate_starts += 1
        else:
            cost = p.isolate_cold_s
            eng.res.cold_isolate_starts += 1
        inst.live_mem += inv.mem_bytes + p.isolate_base
        return cost

    def on_idle(self, eng: Engine, nd: Node, inst: RuntimeInst,
                inv: Invocation, t: float) -> None:
        """Invocation completed: free its working memory, return the
        isolate to the warm pool (evicted after TTL)."""
        p = self.p
        inst.live_mem -= inv.mem_bytes + p.isolate_base
        cnt, _ = inst.warm_isolates.get(inv.mem_bytes, (0, t))
        inst.warm_isolates[inv.mem_bytes] = (cnt + 1, t)
        eng.push(t + p.isolate_ttl_s, "evict", (inst, inv.mem_bytes))
        if (self.pooled and p.pool_drain_ttl_s > 0
                and inst.live_invocations == 0):
            eng.push(t + p.pool_drain_ttl_s, "drain", (nd, inst))

    def adapt_pool(self, eng: Engine, nd: Node, t: float) -> None:
        pass


# ---------------------------------------------------------------------------
class OpenWhiskModel(PlatformModel):
    """One runtime per function instance, ONE invocation at a time
    (classic FaaS worker); the worker stays resident — runtime plus
    function memory — until keep-alive expiry."""

    name = "openwhisk"

    def group_key(self, inv: Invocation) -> tuple:
        return (inv.fid,)

    def on_arrival(self, eng: Engine, inv: Invocation, need: int,
                   key: tuple):
        nd = eng.nodes[0]
        for r in nd.insts.setdefault(key, []):
            if r.live_invocations == 0:
                return nd, r, True
        return nd, None, False

    def runtime_cap(self, need: int) -> int:
        return self.base_mem + need

    def on_boot(self, inst: RuntimeInst, inv: Invocation) -> None:
        inst.live_mem = inv.mem_bytes    # worker-resident fn memory

    def startup_cost(self, eng: Engine, nd: Node, inst: RuntimeInst,
                     inv: Invocation) -> float:
        return 0.0                       # no per-invocation code install

    def acquire_isolate(self, eng: Engine, inst: RuntimeInst,
                        inv: Invocation, warm_worker: bool,
                        t: float) -> float:
        if warm_worker:
            eng.res.warm_isolate_starts += 1
        else:
            eng.res.cold_isolate_starts += 1
        return 0.0

    def on_idle(self, eng: Engine, nd: Node, inst: RuntimeInst,
                inv: Invocation, t: float) -> None:
        pass                             # worker memory stays resident


class PhotonsModel(PlatformModel):
    """One runtime per function, MANY concurrent invocations
    (virtualized single-function runtime)."""

    name = "photons"

    def group_key(self, inv: Invocation) -> tuple:
        return (inv.fid,)


class HydraModel(PlatformModel):
    """One runtime per TENANT hosting any of the tenant's functions,
    many concurrent invocations, shared code caches; a new instance when
    the per-runtime budget saturates (paper setup)."""

    name = "hydra"
    hydra_like = True

    def group_key(self, inv: Invocation) -> tuple:
        return (inv.tenant,)


class HydraPoolModel(HydraModel):
    """The HydraPlatform layer: colocation ACROSS tenants (any runtime
    hosts any owner's functions, packed until the budget saturates), a
    pre-warmed pool of generic instances claimed instead of cold-booting,
    and snapshot-based function install."""

    name = "hydra-pool"
    pooled = True

    def group_key(self, inv: Invocation) -> tuple:
        return ()                        # colocate across owners AND fns

    def init_node(self, nd: Node) -> None:
        nd.pool_avail = nd.pool_target = self.p.pool_size

    def pick_node(self, eng: Engine, inv: Invocation, need: int):
        nd = eng.nodes[0]
        return nd, nd.pool_avail > 0

    def install_cost(self, eng: Engine, nd: Node, inv: Invocation) -> float:
        if inv.fid in nd.snapshots:      # restore from local snapshot
            return self.p.snapshot_restore_s
        return self.p.fn_register_s


class HydraClusterModel(HydraPoolModel):
    """The HydraCluster layer: ``n_nodes`` machines, each a hydra-pool
    node. Placement packs into already-running instances fleet-wide and
    spills new instances to the least-loaded node; a function whose
    snapshot lives only on another node pays an explicit cross-node
    transfer cost; each node's pool is sized by an EWMA arrival-rate
    estimator."""

    name = "hydra-cluster"

    def __init__(self, params: SimParams):
        super().__init__(params)
        self.pool_max = params.pool_max if params.pool_max is not None \
            else params.pool_size
        self.transfer_s = params.snapshot_bytes \
            / (params.transfer_gbps * 1e9 / 8)

    @property
    def n_nodes(self) -> int:
        return max(1, self.p.n_nodes)

    @property
    def node_cap(self) -> int:
        return self.p.node_cap or self.p.machine_cap // self.n_nodes

    def init_node(self, nd: Node) -> None:
        nd.pool_avail = nd.pool_target = (
            self.p.pool_min if self.p.adaptive_pool else self.p.pool_size)

    def on_arrival(self, eng: Engine, inv: Invocation, need: int,
                   key: tuple):
        # fleet-wide packing: prefer the instance that already loaded
        # this fid (zero install), then a node holding its snapshot (no
        # transfer), then the fullest instance (pack-first keeps spare
        # capacity drainable)
        best = None
        for cand_nd in eng.nodes:
            for r in cand_nd.insts.get(key, []):
                if r.mem() + need > r.cap:
                    continue
                score = (inv.fid in r.functions_loaded,
                         inv.fid in cand_nd.snapshots, r.mem())
                if best is None or score > best[0]:
                    best = (score, cand_nd, r)
        if best is not None:
            return best[1], best[2], False
        return eng.nodes[0], None, False

    def pick_node(self, eng: Engine, inv: Invocation, need: int):
        # the cluster picks the node: a warm pool slot on the
        # least-loaded pooled node, else a cold boot on the least-loaded
        # node (this is the cross-machine spill). A node "fits" if
        # reclaiming its idle runtimes would make room — the engine's
        # eviction loop does the reclaiming.
        def reclaimable(x: Node) -> int:
            return sum(r.mem() for g in x.insts.values()
                       for r in g if r.live_invocations == 0)

        pool_fit = [x for x in eng.nodes if x.pool_avail > 0
                    and eng.node_mem(x) - reclaimable(x) + need <= x.cap]
        if pool_fit:
            return min(pool_fit, key=eng.node_mem), True
        cold_fit = [x for x in eng.nodes
                    if eng.node_mem(x) - reclaimable(x)
                    + self.base_mem + need <= x.cap]
        return min(cold_fit or eng.nodes, key=eng.node_mem), False

    def install_cost(self, eng: Engine, nd: Node, inv: Invocation) -> float:
        p = self.p
        if inv.fid in nd.snapshots:
            return p.snapshot_restore_s
        if any(inv.fid in x.snapshots for x in eng.nodes):
            # snapshot held only by ANOTHER node: fetch it first — the
            # explicit cross-machine transfer cost
            eng.res.transfers += 1
            return p.snapshot_restore_s + self.transfer_s
        return p.fn_register_s

    def adapt_pool(self, eng: Engine, nd: Node, t: float) -> None:
        """EWMA arrival-rate update + pool retarget: grow toward
        pool_max under bursts, shrink to pool_min when idle, and never
        let pooled slots outgrow the node's free memory."""
        p = self.p
        if not p.adaptive_pool:
            return
        eff = nd.rate
        if nd.last_arrival > float("-inf"):
            gap = max(t - nd.last_arrival, 1e-9)
            nd.rate = (1.0 - p.ewma_alpha) * nd.rate + p.ewma_alpha / gap
            # cap by the latest gap: a long-idle node collapses to the
            # floor immediately instead of riding its stale burst estimate
            eff = min(nd.rate, 1.0 / gap)
        nd.last_arrival = t
        want = min(self.pool_max,
                   max(p.pool_min, math.ceil(eff * p.pool_cover_s)))
        busy = eng.node_mem(nd) - nd.pool_avail * self.base_mem
        want = min(want, max(0, (nd.cap - busy) // self.base_mem))
        nd.pool_target = want
        if nd.pool_avail > want:         # shrink releases memory now
            nd.pool_avail = want
        # growth is urgent (the estimator says a burst is on): back-boot
        # a generic runtime rather than waiting a full re-warm period
        grow_s = p.vm_boot_s + self.runtime_cold_s
        while nd.pool_avail + nd.pool_pending < want:
            nd.pool_pending += 1
            eng.push(t + grow_s, "refill", nd)


# ---------------------------------------------------------------------------
# Registry: name -> model class. Iteration/membership keep the old
# tuple semantics (``for m in MODELS`` / ``model in MODELS``).
MODELS: dict = {
    cls.name: cls
    for cls in (OpenWhiskModel, PhotonsModel, HydraModel, HydraPoolModel,
                HydraClusterModel)
}


def register_model(cls) -> type:
    """Register a PlatformModel subclass (usable as a decorator) so
    ``simulate(trace, cls.name)`` resolves it."""
    if not cls.name:
        raise ValueError("PlatformModel subclass needs a non-empty .name")
    MODELS[cls.name] = cls
    return cls
