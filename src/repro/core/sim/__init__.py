"""Pluggable discrete-event simulator for the Azure-trace reproduction
(paper §4.4, Figures 9/10).

The package splits the old ``repro.core.tracesim`` monolith into:

  * :mod:`repro.core.sim.engine` — the model-agnostic event loop
    (heap, memory accounting, sampling, queue/retry/give-up) plus
    ``SimParams`` / ``SimResult``.
  * :mod:`repro.core.sim.models` — the :class:`PlatformModel` policy
    interface, one subclass per runtime model, and the ``MODELS``
    registry.
  * :mod:`repro.core.traces` — the ``Trace`` sources (synthetic
    generator + Azure Functions 2019 dataset loader).
  * :mod:`repro.core.calibrate` — measured-cost overrides for
    ``SimParams`` (bench_startup ``--emit-calibration``).

``repro.core.tracesim`` re-exports this package's API, so existing
imports keep working.
"""
from __future__ import annotations

from dataclasses import replace

from repro.core.sim.engine import (GB, MB, Engine, Node, RuntimeInst,
                                   SimParams, SimResult)
from repro.core.sim.models import (MODELS, HydraClusterModel, HydraModel,
                                   HydraPoolModel, OpenWhiskModel,
                                   PhotonsModel, PlatformModel,
                                   register_model)
from repro.core.traces import (Invocation, Trace, discover_azure_tables,
                               gen_trace, load_azure_trace)

__all__ = [
    "MB", "GB", "SimParams", "SimResult", "Invocation", "Engine", "Node",
    "RuntimeInst", "PlatformModel", "OpenWhiskModel", "PhotonsModel",
    "HydraModel", "HydraPoolModel", "HydraClusterModel", "MODELS",
    "register_model", "Trace", "gen_trace", "load_azure_trace",
    "discover_azure_tables", "simulate", "simulate_partitioned", "compare",
]


def simulate(trace, model: str, params: SimParams = SimParams(),
             sample_dt: float = 1.0) -> SimResult:
    """Replay ``trace`` under ``model`` in MODELS."""
    assert model in MODELS, model
    policy = MODELS[model](params)
    return Engine(policy, params, sample_dt=sample_dt).run(trace)


def simulate_partitioned(trace, n_nodes: int,
                         params: SimParams = SimParams(),
                         model: str = "hydra-pool") -> SimResult:
    """Baseline fleet WITHOUT a cluster layer: ``n_nodes`` independent
    single-node deployments with statically partitioned traffic (functions
    hashed across nodes) and a 1/n share of the fleet memory each. The
    merged result is directly comparable to a ``hydra-cluster`` run at the
    same node count — the delta is what cross-machine placement, spill,
    and snapshot transfer buy."""
    node_cap = params.node_cap or params.machine_cap // n_nodes
    single = replace(params, machine_cap=node_cap, n_nodes=1)
    merged = SimResult(model=f"{model}-static", n_nodes=n_nodes)
    mem: dict = {}
    pmem: dict = {}
    cnt: dict = {}
    common_end = float("inf")     # nodes' sample grids end at different
    for i in range(n_nodes):      # times; sums past the shortest would
        sub = [inv for inv in trace  # cover only a subset of the fleet
               if inv.fid % n_nodes == i]
        r = simulate(sub, model, single)
        if r.mem_samples:
            common_end = min(common_end, r.mem_samples[-1][0])
        merged.latencies += r.latencies
        merged.overheads += r.overheads
        merged.cold_runtime_starts += r.cold_runtime_starts
        merged.cold_isolate_starts += r.cold_isolate_starts
        merged.warm_isolate_starts += r.warm_isolate_starts
        merged.evicted_runtimes += r.evicted_runtimes
        merged.dropped += r.dropped
        merged.pool_claims += r.pool_claims
        merged.transfers += r.transfers
        merged.peak_pool_mem += r.peak_pool_mem   # sum of per-node peaks
        for ts, m in r.mem_samples:
            mem[ts] = mem.get(ts, 0) + m
        for ts, m in r.pool_mem_samples:
            pmem[ts] = pmem.get(ts, 0) + m
        for ts, n in r.runtime_count_samples:
            cnt[ts] = cnt.get(ts, 0) + n
    merged.mem_samples = sorted((ts, m) for ts, m in mem.items()
                                if ts <= common_end)
    merged.pool_mem_samples = sorted((ts, m) for ts, m in pmem.items()
                                     if ts <= common_end)
    merged.runtime_count_samples = sorted((ts, n) for ts, n in cnt.items()
                                          if ts <= common_end)
    return merged


def compare(trace, params: SimParams = SimParams(),
            models=None) -> dict:
    """Summaries for ``models`` (default: every registered model) on one
    trace."""
    if models is None:
        models = list(MODELS)
    unknown = [m for m in models if m not in MODELS]
    if unknown:
        raise ValueError(f"unknown model(s) {unknown}; "
                         f"registered: {list(MODELS)}")
    return {m: simulate(trace, m, params).summary() for m in models}
