"""Function registry: the paper's register/deregister surface (§3.1).

Two function kinds:
  * CallableSpec — an arbitrary jitted JAX function (the analog of the
    paper's SeBS/Photons benchmark functions and the trace's emulated
    functions).
  * LMSpec — a model-serving function (our domain adaptation): an assigned
    architecture served through prefill/decode programs.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.configs.base import ArchConfig
from repro.core.errors import FunctionNotRegisteredError

MB = 1 << 20
DEFAULT_ARENA_BYTES = 1 * MB   # paper: 1 MB pre-allocated isolate heap


@dataclass(frozen=True)
class CallableSpec:
    name: str                       # program identity (shared across fids)
    fn: Callable                    # (params, args) -> result
    example_args: Any               # pytree of arrays (defines shapes)
    params: Any = None
    arena_bytes: int = DEFAULT_ARENA_BYTES


@dataclass(frozen=True)
class LMSpec:
    cfg: ArchConfig
    params: Any                     # device weights (bf16 for serving)
    max_seq: int = 2048             # decode cache slots per request
    slots: int = 1                  # batched decode slots (continuous batching)

    @property
    def family_key(self) -> tuple:
        """Signature shared by every tenant serving this architecture —
        weights are arguments, so executables are shared (code-cache
        sharing across tenants)."""
        return ("lm", dataclasses.replace(self.cfg, name=""),
                self.max_seq, self.slots)


@dataclass
class Function:
    fid: str
    tenant: str
    spec: Any
    mem_budget: int
    entry: dict = field(default_factory=dict)   # name -> compiled executable
    arena_sig: tuple = ()
    arena_factory: Optional[Callable] = None
    registered_at: float = field(default_factory=time.monotonic)
    invocations: int = 0


class FunctionRegistry:
    def __init__(self):
        self._funcs: dict[str, Function] = {}
        self._lock = threading.Lock()

    def add(self, func: Function) -> bool:
        with self._lock:
            if func.fid in self._funcs:
                return False
            self._funcs[func.fid] = func
            return True

    def get(self, fid: str) -> Function:
        with self._lock:
            func = self._funcs.get(fid)
        if func is None:
            raise FunctionNotRegisteredError(fid)
        return func

    def remove(self, fid: str) -> bool:
        with self._lock:
            return self._funcs.pop(fid, None) is not None

    def list(self) -> list:
        with self._lock:
            return list(self._funcs)

    def __len__(self) -> int:
        with self._lock:                   # HL001: paired with register()
            return len(self._funcs)
