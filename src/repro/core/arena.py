"""Arenas: the memory-isolate analog (paper §3.2).

An Arena is a pre-allocated, fixed-budget set of device buffers (KV-cache
slabs / SSM state / scratch) that hosts ONE in-flight invocation. Arenas are
pooled: ``acquire`` pops a warm arena in microseconds (the paper's <500 us
isolate start), ``release`` returns it, idle arenas are destroyed after a
TTL (paper default: 10 s) releasing memory back to the device allocator.

Because accelerator programs can only address buffers passed to them, an
invocation physically cannot touch another invocation's arena — the
shape-safe equivalent of the paper's isolate heap confinement.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax

from repro.core.budget import MemoryBudget
from repro.core.metrics import Metrics

DEFAULT_TTL_S = 10.0


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "dtype"))


@dataclass
class Arena:
    signature: tuple
    buffers: Any                       # pytree of device arrays
    nbytes: int
    created_at: float = field(default_factory=time.monotonic)
    last_used: float = field(default_factory=time.monotonic)
    uses: int = 0


class ArenaPool:
    """Per-signature free lists with TTL eviction and watermark prealloc."""

    def __init__(self, budget: Optional[MemoryBudget] = None,
                 ttl_s: float = DEFAULT_TTL_S,
                 metrics: Optional[Metrics] = None):
        self.budget = budget
        self.ttl_s = ttl_s
        self.metrics = metrics or Metrics()
        self._free: dict[tuple, list[Arena]] = {}
        self._lock = threading.Lock()
        self.live = 0

    # ------------------------------------------------------------------
    def acquire(self, signature: tuple,
                factory: Callable[[], Any]) -> Arena:
        with self._lock:
            free = self._free.get(signature)
            if free:
                arena = free.pop()
                arena.last_used = time.monotonic()
                arena.uses += 1
                self.metrics.inc("arena.warm")
                return arena
        # cold path: allocate outside the lock (paper Fig 3: allocation
        # latency grows with concurrent isolates — keep it off the fast path)
        self.metrics.inc("arena.cold")
        with self.metrics.timeit("arena.alloc_s"):
            buffers = factory()
        nbytes = tree_bytes(buffers)
        if self.budget is not None:
            self.budget.reserve(nbytes)
        with self._lock:
            self.live += 1
        return Arena(signature=signature, buffers=buffers, nbytes=nbytes,
                     uses=1)

    def release(self, arena: Arena) -> None:
        arena.last_used = time.monotonic()
        with self._lock:
            self._free.setdefault(arena.signature, []).append(arena)

    # ------------------------------------------------------------------
    def prealloc(self, signature: tuple, factory: Callable[[], Any],
                 n: int) -> None:
        """Warm the pool (paper: pre-allocated cached isolates)."""
        for _ in range(n):
            arena = self.acquire(signature, factory)
            # undo the warm/cold accounting skew of prealloc
            self.release(arena)

    def evict_idle(self, now: Optional[float] = None) -> int:
        """Destroy arenas idle beyond the TTL; returns bytes released."""
        now = now if now is not None else time.monotonic()
        released = 0
        with self._lock:
            for sig, free in self._free.items():
                keep = []
                for a in free:
                    if now - a.last_used > self.ttl_s:
                        released += a.nbytes
                        self.live -= 1
                        self.metrics.inc("arena.evicted")
                    else:
                        keep.append(a)
                self._free[sig] = keep
        if released and self.budget is not None:
            self.budget.release(released)
        return released

    def drain(self) -> int:
        with self._lock:
            for a_list in self._free.values():
                for a in a_list:
                    if self.budget is not None:
                        self.budget.release(a.nbytes)
                    self.live -= 1
            n = sum(len(v) for v in self._free.values())
            self._free.clear()
        return n

    @property
    def idle_count(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._free.values())

    def stats(self) -> dict:
        return {"live": self.live, "idle": self.idle_count,
                **self.metrics.snapshot()["counters"]}
