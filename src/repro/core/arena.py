"""Arenas: the memory-isolate analog (paper §3.2), slab-allocated.

An Arena is a pre-allocated, fixed-budget set of device buffers (KV-cache
slabs / SSM state / scratch) that hosts ONE in-flight invocation. Arenas are
pooled: ``acquire`` pops a warm arena in microseconds (the paper's <500 us
isolate start), ``release`` returns it, idle arenas are destroyed after a
TTL (paper default: 10 s) releasing memory back to the device allocator.

The pool is a *slab allocator*: device memory for a signature is minted at
most once per slab (``register_signature`` / ``prealloc`` pre-touch slabs off
the clock), and the warm claim path never copies host memory. Two warm claim
flavors exist:

- **donated reuse** (``arena.reuse``): the claimant owns the slab's previous
  contents (same ``owner``, e.g. successive invocations of one function whose
  programs donate their cache back into the slab) — the slab is handed out
  as-is, zero work.
- **zeroed reuse** (``arena.zeroed``): the slab last belonged to a different
  owner; it is scrubbed on-device by a jitted donate-in-place fill compiled
  AOT at registration time. No ``device_put`` host→device copy occurs — the
  fill runs where the data lives.

Because accelerator programs can only address buffers passed to them, an
invocation physically cannot touch another invocation's arena — the
shape-safe equivalent of the paper's isolate heap confinement. The zeroed
handoff extends that guarantee across time: a reused slab is
indistinguishable from a freshly allocated one.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.budget import MemoryBudget
from repro.core.metrics import Metrics
from repro.core.tracing import NULL_TRACE

DEFAULT_TTL_S = 10.0


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "dtype"))


def _zero_tree(bufs):
    # traced under jit (donate_argnums=(0,)) — the zeros are materialized
    # on-device into the donated slab, never staged through the host
    return jax.tree.map(jnp.zeros_like, bufs)


@dataclass
class Arena:
    signature: tuple
    buffers: Any                       # pytree of device arrays
    nbytes: int
    owner: Optional[str] = None        # fid of the last claimant
    created_at: float = field(default_factory=time.monotonic)
    last_used: float = field(default_factory=time.monotonic)
    uses: int = 0


class ArenaPool:
    """Signature-keyed slab pool with TTL eviction and watermark prealloc.

    ``exe_cache`` (optional): route the per-signature zeroer compilation
    through the shared ``ExecutableCache`` so it is AOT-compiled once,
    shared fleet-wide, and persisted to disk with the other executables.
    """

    def __init__(self, budget: Optional[MemoryBudget] = None,
                 ttl_s: float = DEFAULT_TTL_S,
                 metrics: Optional[Metrics] = None,
                 exe_cache=None):
        self.budget = budget
        self.ttl_s = ttl_s
        self.metrics = metrics or Metrics()
        self.exe_cache = exe_cache
        self._free: dict[tuple, list[Arena]] = {}
        self._factories: dict[tuple, Callable[[], Any]] = {}
        self._zeroers: dict[tuple, Callable] = {}
        self._lock = threading.Lock()
        self.live = 0

    # ------------------------------------------------------------------
    # Registration-time work (off the request path)
    # ------------------------------------------------------------------
    def register_signature(self, signature: tuple,
                           factory: Callable[[], Any],
                           buffer_specs: Any = None) -> None:
        """Install the slab factory for ``signature`` and AOT-compile its
        donate-in-place zeroer. Called at function-registration time — the
        modeled ``fn_register_s`` cost — so ``acquire`` never compiles.

        ``buffer_specs``: pytree of ``jax.ShapeDtypeStruct`` matching what
        ``factory`` produces. When omitted, one slab is materialized to
        derive the specs; it stays in the free list (a pre-touched
        prealloc of 1), so no memory is minted twice.
        """
        with self._lock:
            self._factories.setdefault(signature, factory)
            have_zeroer = signature in self._zeroers
        if have_zeroer:
            return
        if buffer_specs is None:
            arena = self.acquire(signature, factory)
            try:
                buffer_specs = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                    arena.buffers)
            finally:
                self.release(arena)
        zeroer = self._compile_zeroer(signature, buffer_specs)
        with self._lock:
            self._zeroers.setdefault(signature, zeroer)

    def _compile_zeroer(self, signature: tuple, buffer_specs: Any):
        # hydralint: disable=HL002 — registration-time AOT compile (the
        # zeroer is part of the modeled fn_register_s cost); when an
        # unregistered signature first hits the scrub path this runs once
        # and is amortized like any cold compile, never per-claim
        def lower():
            return jax.jit(_zero_tree, donate_argnums=(0,)).lower(
                buffer_specs)
        if self.exe_cache is not None:
            key = ("arena-zeroer",) + tuple(signature)
            return self.exe_cache.get_or_compile(key, lower).compiled
        return lower().compile()

    def prealloc(self, signature: tuple, factory: Callable[[], Any],
                 n: int, owner: Optional[str] = None) -> None:
        """Pre-touch ``n`` slabs off the clock (paper: pre-allocated cached
        isolates). Also installs the factory + zeroer so later claims of
        this signature are pure pool operations. Pass ``owner`` to
        pre-assign the slabs (a factory-fresh slab is already in the
        zeroed state, so the owner's first claim skips even the scrub)."""
        self.register_signature(signature, factory)
        arenas = [self.acquire(signature, factory, owner=owner)
                  for _ in range(n)]
        for arena in arenas:
            self.release(arena)

    # ------------------------------------------------------------------
    # Request path
    # ------------------------------------------------------------------
    def acquire(self, signature: tuple,
                factory: Optional[Callable[[], Any]] = None,
                owner: Optional[str] = None, ctx=None) -> Arena:
        ctx = ctx or NULL_TRACE
        with ctx.span("arena_acquire") as sp:
            with self._lock:
                arena = None
                free = self._free.get(signature)
                if free:
                    if owner is not None:
                        # prefer a slab this owner donated back: its contents
                        # are the owner's own, so no scrub is needed
                        for i in range(len(free) - 1, -1, -1):
                            if free[i].owner == owner:
                                arena = free.pop(i)
                                break
                    if arena is None:
                        arena = free.pop()
                if arena is not None:
                    arena.last_used = time.monotonic()
                    arena.uses += 1
                    # ownership unchanged (incl. owner-less single-tenant
                    # users): the claimant owns the slab's contents already,
                    # so handing them back untouched leaks nothing
                    donated = arena.owner == owner
                    zeroer = self._zeroers.get(signature)
            if arena is not None:
                self.metrics.inc("arena.warm")
                if donated:
                    sp.set(kind="reuse")
                    self.metrics.inc("arena.reuse")
                else:
                    self._scrub(arena, zeroer)
                    sp.set(kind="zeroed")
                    self.metrics.inc("arena.zeroed")
                arena.owner = owner
                return arena
            sp.set(kind="cold")
            return self._acquire_cold(signature, factory, owner)

    def _scrub(self, arena: Arena, zeroer) -> None:
        """On-device donate-in-place zero fill: cross-owner isolation
        without a host round trip."""
        if zeroer is None:
            zeroer = self._lazy_zeroer(arena)
        arena.buffers = jax.block_until_ready(zeroer(arena.buffers))

    def _lazy_zeroer(self, arena: Arena):
        """One-time zeroer install for signatures used without
        ``register_signature`` (direct pool users); cached thereafter."""
        specs = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), arena.buffers)
        zeroer = self._compile_zeroer(arena.signature, specs)
        with self._lock:
            return self._zeroers.setdefault(arena.signature, zeroer)

    def _acquire_cold(self, signature: tuple,
                      factory: Optional[Callable[[], Any]],
                      # hydralint: disable=HL002 — the cold slab mint is
                      # the modeled isolate_cold_s cost (paper Fig 3);
                      # factory may device_put, and the slab is pre-touched
                      # (blocked on) before handout so later claims never
                      # fault host copies in
                      owner: Optional[str] = None) -> Arena:
        if factory is None:
            with self._lock:
                factory = self._factories.get(signature)
        if factory is None:
            raise KeyError(f"no factory for arena signature {signature!r}")
        # cold path: allocate outside the lock (paper Fig 3: allocation
        # latency grows with concurrent isolates — keep it off the fast path)
        self.metrics.inc("arena.cold")
        with self.metrics.timeit("arena.alloc_s"):
            buffers = jax.block_until_ready(factory())
        nbytes = tree_bytes(buffers)
        if self.budget is not None:
            self.budget.reserve(nbytes)
        with self._lock:
            self.live += 1
        return Arena(signature=signature, buffers=buffers, nbytes=nbytes,
                     owner=owner, uses=1)

    def release(self, arena: Arena) -> None:
        arena.last_used = time.monotonic()
        with self._lock:
            self._free.setdefault(arena.signature, []).append(arena)

    # ------------------------------------------------------------------
    def evict_idle(self, now: Optional[float] = None) -> int:
        """Destroy arenas idle beyond the TTL; returns bytes released."""
        now = now if now is not None else time.monotonic()
        released = 0
        with self._lock:
            for sig, free in self._free.items():
                keep = []
                for a in free:
                    if now - a.last_used > self.ttl_s:
                        released += a.nbytes
                        self.live -= 1
                        self.metrics.inc("arena.evicted")
                    else:
                        keep.append(a)
                self._free[sig] = keep
        if released and self.budget is not None:
            self.budget.release(released)
        return released

    def drain(self) -> int:
        with self._lock:
            for a_list in self._free.values():
                for a in a_list:
                    if self.budget is not None:
                        self.budget.release(a.nbytes)
                    self.live -= 1
            n = sum(len(v) for v in self._free.values())
            self._free.clear()
        return n

    @property
    def idle_count(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._free.values())

    def stats(self) -> dict:
        return {"live": self.live, "idle": self.idle_count,
                **self.metrics.snapshot()["counters"]}
