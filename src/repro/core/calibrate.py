"""Measured-cost calibration for the simulator.

``SimParams`` startup/memory constants default to the paper's
measurements. This module replaces them with values measured on *your*
host: ``benchmarks/bench_startup.py --emit-calibration out.json`` runs
the Fig-1 measurements and writes a calibration JSON; ``bench_trace
--calibration out.json`` (or :func:`apply_calibration` directly) then
replays traces with the measured constants, so simulated density/latency
deltas reflect this machine rather than the paper's testbed.
``repro.launch.serve --calibration`` emits the same schema from live
serving metrics.

Schema (``hydra-calibration/v1``)::

    {
      "schema": "hydra-calibration/v1",
      "meta": {"host": "...", "source": "bench_startup"},
      "measured": {"hydra_runtime_cold_s": 0.041, ...}
    }

``measured`` keys must be :data:`CALIBRATABLE_FIELDS` — the ``SimParams``
fields a measurement can override. Unknown keys or non-numeric values
are schema errors (raise ``ValueError``), so a stale file fails loudly
instead of silently mis-calibrating a replay.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Optional, Union

from repro.core.sim.engine import SimParams

SCHEMA = "hydra-calibration/v1"

# SimParams fields a measurement may override; int fields get rounded.
CALIBRATABLE_FIELDS: tuple = (
    "runtime_cold_s", "hydra_runtime_cold_s", "isolate_cold_s",
    "isolate_warm_s", "fn_register_s", "vm_boot_s", "pool_claim_s",
    "snapshot_restore_s", "runtime_base", "hydra_runtime_base",
    "isolate_base",
)
_INT_FIELDS = frozenset(("runtime_base", "hydra_runtime_base",
                         "isolate_base"))


def _validate(measured: dict) -> dict:
    if not isinstance(measured, dict) or not measured:
        raise ValueError("calibration 'measured' must be a non-empty dict")
    unknown = sorted(set(measured) - set(CALIBRATABLE_FIELDS))
    if unknown:
        raise ValueError(
            f"calibration has unknown field(s) {unknown}; calibratable "
            f"SimParams fields are {sorted(CALIBRATABLE_FIELDS)}")
    out = {}
    for k, v in measured.items():
        if not isinstance(v, (int, float)) or isinstance(v, bool) \
                or not math.isfinite(v) or v < 0:
            raise ValueError(f"calibration field {k!r} must be a finite "
                             f"non-negative number, got {v!r}")
        out[k] = int(round(v)) if k in _INT_FIELDS else float(v)
    return out


def write_calibration(path: str, measured: dict,
                      meta: Optional[dict] = None) -> dict:
    """Validate ``measured`` and write the calibration JSON; returns the
    document written."""
    doc = {"schema": SCHEMA, "meta": dict(meta or {}),
           "measured": _validate(measured)}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return doc


def load_calibration(path: str) -> dict:
    """Read + validate a calibration JSON; returns the ``measured`` dict
    (field -> value)."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or doc.get("schema") != SCHEMA:
        raise ValueError(f"{path}: not a {SCHEMA} document "
                         f"(schema={doc.get('schema') if isinstance(doc, dict) else None!r})")
    return _validate(doc.get("measured", {}))


def apply_calibration(params: SimParams,
                      calibration: Union[str, dict]) -> SimParams:
    """Return a copy of ``params`` with measured constants overriding the
    paper defaults. ``calibration`` is a path to a calibration JSON or an
    already-loaded ``measured`` dict."""
    measured = load_calibration(calibration) \
        if isinstance(calibration, str) else _validate(calibration)
    return dataclasses.replace(params, **measured)
