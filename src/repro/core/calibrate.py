"""Measured-cost calibration for the simulator.

``SimParams`` startup/memory constants default to the paper's
measurements. This module replaces them with values measured on *your*
host: ``benchmarks/bench_startup.py --emit-calibration out.json`` runs
the Fig-1 measurements and writes a calibration JSON; ``bench_trace
--calibration out.json`` (or :func:`apply_calibration` directly) then
replays traces with the measured constants, so simulated density/latency
deltas reflect this machine rather than the paper's testbed.
``repro.launch.serve --calibration`` emits the same schema from live
serving metrics, and :func:`calibration_from_replay` derives it from one
live gateway replay (the gateway -> calibration -> sim round trip that
``repro.gateway.validate --round-trip`` exercises).

Schema (``hydra-calibration/v1``)::

    {
      "schema": "hydra-calibration/v1",
      "meta": {"host": "...", "source": "bench_startup"},
      "measured": {"hydra_runtime_cold_s": 0.041, ...}
    }

``measured`` keys must be :data:`CALIBRATABLE_FIELDS` — the ``SimParams``
fields a measurement can override. Unknown keys or non-numeric values
are schema errors (raise ``ValueError``), so a stale file fails loudly
instead of silently mis-calibrating a replay.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Optional, Union

from repro.core.sim.engine import SimParams

SCHEMA = "hydra-calibration/v1"

# SimParams fields a measurement may override; int fields get rounded.
CALIBRATABLE_FIELDS: tuple = (
    "runtime_cold_s", "hydra_runtime_cold_s", "isolate_cold_s",
    "isolate_warm_s", "fn_register_s", "vm_boot_s", "pool_claim_s",
    "pool_refill_s", "snapshot_restore_s", "runtime_base",
    "hydra_runtime_base", "isolate_base",
)
_INT_FIELDS = frozenset(("runtime_base", "hydra_runtime_base",
                         "isolate_base"))


def _validate(measured: dict) -> dict:
    if not isinstance(measured, dict) or not measured:
        raise ValueError("calibration 'measured' must be a non-empty dict")
    unknown = sorted(set(measured) - set(CALIBRATABLE_FIELDS))
    if unknown:
        raise ValueError(
            f"calibration has unknown field(s) {unknown}; calibratable "
            f"SimParams fields are {sorted(CALIBRATABLE_FIELDS)}")
    out = {}
    for k, v in measured.items():
        if not isinstance(v, (int, float)) or isinstance(v, bool) \
                or not math.isfinite(v) or v < 0:
            raise ValueError(f"calibration field {k!r} must be a finite "
                             f"non-negative number, got {v!r}")
        out[k] = int(round(v)) if k in _INT_FIELDS else float(v)
    return out


def write_calibration(path: str, measured: dict,
                      meta: Optional[dict] = None) -> dict:
    """Validate ``measured`` and write the calibration JSON; returns the
    document written."""
    doc = {"schema": SCHEMA, "meta": dict(meta or {}),
           "measured": _validate(measured)}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return doc


def write_calibration_doc(path: str, doc: dict) -> dict:
    """Persist an already-built calibration document (e.g. from
    :func:`calibration_from_replay`) — one place for the
    extract-measured/meta-and-write step every CLI shares."""
    return write_calibration(path, doc["measured"], meta=doc.get("meta"))


def load_calibration(path: str) -> dict:
    """Read + validate a calibration JSON; returns the ``measured`` dict
    (field -> value)."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or doc.get("schema") != SCHEMA:
        raise ValueError(f"{path}: not a {SCHEMA} document "
                         f"(schema={doc.get('schema') if isinstance(doc, dict) else None!r})")
    return _validate(doc.get("measured", {}))


# live-replay wall-cost names (gateway CalibrationProbe) -> the SimParams
# field each one calibrates. Boot cost lands TWICE: a dry-pool cold start
# charges it inline (hydra_runtime_cold_s) and a claimed slot's background
# re-warm takes one boot as well (pool_refill_s).
_REPLAY_COST_FIELDS = {
    "runtime_boot_s": ("hydra_runtime_cold_s", "pool_refill_s"),
    "pool_claim_s": ("pool_claim_s",),
    "restore_s": ("snapshot_restore_s",),
    "register_s": ("fn_register_s",),
    "arena.alloc_s": ("isolate_cold_s",),
}


def calibration_from_replay(result, extras: dict,
                            meta: Optional[dict] = None,
                            include_memory: bool = False) -> dict:
    """Turn one live gateway replay into a ``hydra-calibration/v1``
    overlay for ``SimParams`` — the gateway -> calibration -> sim round
    trip (``gateway/validate.py --round-trip``).

    ``result`` is the replay's ``SimResult``; ``extras`` must carry the
    ``CalibrationProbe`` payload under ``"probe"`` (``replay_trace``
    records it whenever ``ReplayConfig.probe`` is on). Probe costs are
    measured in *wall* seconds, but live replays record latencies in
    *trace* seconds (wall x compress) — real startup does not compress
    with the replay clock — so every cost is scaled by the probe's
    ``compress`` factor: the calibrated simulator then predicts the
    trace-time behaviour the live stack actually exhibits at that
    compression. ``vm_boot_s`` is zeroed because the measured boot
    already covers the whole live cold-start path (there is no microVM
    under it).

    ``include_memory=True`` additionally maps the probe's measured
    per-runtime RSS onto ``hydra_runtime_base``. Off by default: live
    arenas are ``mem_scale``'d while process RSS is not, so a raw RSS
    figure distorts the simulator's packing ratios; the measurement is
    always reported in the returned ``meta`` either way.

    Returns the full calibration document (validated, same shape
    ``write_calibration`` produces); pass ``doc["measured"]`` to
    :func:`apply_calibration`.
    """
    probe = extras.get("probe")
    if not probe:
        raise ValueError(
            "replay carried no calibration probe (extras['probe'] is "
            "missing/empty); run replay_trace with ReplayConfig(probe=True)")
    compress = float(probe["compress"])
    if not math.isfinite(compress) or compress <= 0:
        raise ValueError(f"probe compress must be positive, got {compress!r}")
    measured: dict = {}
    for cost_name, fields in _REPLAY_COST_FIELDS.items():
        sample = probe.get("wall_costs", {}).get(cost_name)
        if not sample or not sample.get("count"):
            continue
        for f in fields:
            measured[f] = float(sample["mean"]) * compress
    if "hydra_runtime_cold_s" in measured:
        # the measured boot IS the whole live cold start; don't let the
        # paper's Firecracker constant double-charge it
        measured["vm_boot_s"] = 0.0
    rss_per_runtime = probe.get("rss", {}).get("per_runtime_bytes")
    if include_memory and rss_per_runtime:
        measured["hydra_runtime_base"] = int(round(rss_per_runtime))
    if not measured:
        raise ValueError("calibration probe measured no startup costs "
                         "(no boots, claims, restores, or installs "
                         "happened during the replay window)")
    doc_meta = {"source": "gateway-replay", "model": result.model,
                "compress": compress,
                "requests": len(result.latencies),
                "rss_per_runtime_bytes": rss_per_runtime,
                # compile-cache provenance: with the persistent caches
                # warm, register_s excludes XLA time, so the overlay's
                # fn_register_s reflects a deploy against a warm code
                # cache — record the counters so a calibration file says
                # WHICH regime it measured
                "exe_cache": extras.get("exe_cache"),
                "request_overhead_ms": extras.get("request_overhead_ms")}
    doc_meta.update(meta or {})
    return {"schema": SCHEMA, "meta": doc_meta,
            "measured": _validate(measured)}


def apply_calibration(params: SimParams,
                      calibration: Union[str, dict]) -> SimParams:
    """Return a copy of ``params`` with measured constants overriding the
    paper defaults. ``calibration`` is a path to a calibration JSON or an
    already-loaded ``measured`` dict."""
    measured = load_calibration(calibration) \
        if isinstance(calibration, str) else _validate(calibration)
    return dataclasses.replace(params, **measured)
