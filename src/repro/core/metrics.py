"""Lightweight counters/histograms for runtime accounting."""
from __future__ import annotations

import threading
import time
from collections import defaultdict

import numpy as np


class Histogram:
    def __init__(self):
        self._vals: list[float] = []
        self._lock = threading.Lock()

    def observe(self, v: float):
        with self._lock:
            self._vals.append(float(v))

    def percentile(self, q) -> float:
        with self._lock:
            if not self._vals:
                return float("nan")
            return float(np.percentile(self._vals, q))

    @property
    def count(self) -> int:
        return len(self._vals)

    @property
    def mean(self) -> float:
        with self._lock:
            return float(np.mean(self._vals)) if self._vals else float("nan")

    def snapshot(self) -> dict:
        return {"count": self.count, "mean": self.mean,
                "p50": self.percentile(50), "p99": self.percentile(99)}


class Metrics:
    def __init__(self):
        self.counters = defaultdict(int)
        self.hists: dict[str, Histogram] = defaultdict(Histogram)
        self._lock = threading.Lock()

    def inc(self, name: str, n: int = 1):
        with self._lock:
            self.counters[name] += n

    def observe(self, name: str, v: float):
        self.hists[name].observe(v)

    def timeit(self, name: str):
        metrics = self

        class _Timer:
            def __enter__(self):
                self.t0 = time.perf_counter()
                return self

            def __exit__(self, *exc):
                self.elapsed = time.perf_counter() - self.t0
                metrics.observe(name, self.elapsed)
        return _Timer()

    def snapshot(self) -> dict:
        return {"counters": dict(self.counters),
                "hists": {k: h.snapshot() for k, h in self.hists.items()}}
