"""Lightweight counters/histograms for runtime accounting.

Thread-safety contract: every public operation — ``inc``, ``observe``,
``timeit``, ``snapshot``, and the ``Histogram`` accessors — may be called
from any number of threads concurrently (gateway workers, platform refill
threads, runtime workers, the janitor). Counters live behind the
``Metrics`` lock; each ``Histogram`` has its own lock; histogram
*creation* is serialized under the ``Metrics`` lock so two racing
``observe`` calls on a brand-new name can never each create a histogram
and drop one of the observations (the old ``defaultdict`` pattern did
exactly that). ``snapshot`` copies the maps under the lock before
rendering, so it never iterates a dict another thread is growing.

Memory contract: a ``Histogram`` is exact while it holds fewer than
``max_samples`` observations and switches to reservoir sampling
(Algorithm R, seeded) above that, so a full-day streaming replay (PR 7)
observing per-request latencies millions of times stays O(max_samples)
per histogram instead of one float per observation forever. ``count``,
``sum``, ``mean``, and the ``count_sum()`` window-edge pair stay EXACT
in reservoir mode (running totals, not reservoir estimates) — the
CalibrationProbe's window deltas depend on that; only the percentile
shape (``percentile``/``snapshot`` p50/p99) becomes a uniform-sample
estimate. ``max_samples=None`` keeps the historical unbounded-exact
behavior; the gateway path constructs its stacks with
``DEFAULT_RESERVOIR``.
"""
from __future__ import annotations

import random
import threading
import time
from collections import defaultdict
from typing import Optional

import numpy as np

# bound used by the live request path (gateway → platform/cluster →
# runtime metrics): big enough that p99 of a replay window is stable
# (~1% resolution needs ~10k samples), small enough that a full-day
# replay's histograms stay a few hundred KB total
DEFAULT_RESERVOIR = 8192


class Histogram:
    def __init__(self, max_samples: Optional[int] = None, seed: int = 0):
        if max_samples is not None and max_samples <= 0:
            raise ValueError(f"max_samples must be positive, got {max_samples}")
        self._vals: list[float] = []
        self._count = 0
        self._sum = 0.0
        self._max_samples = max_samples
        # per-histogram seeded stream: reservoir contents are reproducible
        # for a given observation sequence, independent of global random
        self._rng = random.Random(seed) if max_samples is not None else None
        self._lock = threading.Lock()

    def observe(self, v: float):
        v = float(v)
        with self._lock:
            self._count += 1
            self._sum += v
            m = self._max_samples
            if m is None or len(self._vals) < m:
                self._vals.append(v)
            else:
                # Algorithm R: keep each of the _count observations in
                # the reservoir with equal probability m/_count
                j = self._rng.randrange(self._count)
                if j < m:
                    self._vals[j] = v

    def _copy(self) -> list:
        with self._lock:
            return list(self._vals)

    def percentile(self, q) -> float:
        vals = self._copy()
        if not vals:
            return float("nan")
        return float(np.percentile(vals, q))

    @property
    def count(self) -> int:
        """Exact observation count (not the reservoir size)."""
        with self._lock:
            return self._count

    @property
    def mean(self) -> float:
        """Exact running mean (sum/count), even in reservoir mode."""
        with self._lock:
            return self._sum / self._count if self._count else float("nan")

    @property
    def sum(self) -> float:
        """Exact total of all observations (0.0 when empty)."""
        with self._lock:
            return self._sum

    def count_sum(self) -> tuple:
        """One consistent ``(count, sum)`` pair under a single lock
        hold. This is the window-edge primitive: snapshotting the pair
        at two points in time yields the exact mean of the observations
        between them even while writers keep appending — the gateway's
        CalibrationProbe measures replay-window startup costs this way
        (reading ``count`` and ``sum`` as two separate calls could
        straddle a concurrent observe and tear the pair). Both members
        stay exact in reservoir mode."""
        with self._lock:
            return self._count, self._sum

    def snapshot(self) -> dict:
        # one consistent view: count/mean are the exact running totals,
        # percentiles come from the same locked copy of the sample set
        # (the full history below max_samples, a uniform reservoir above)
        with self._lock:
            vals = list(self._vals)
            count, total = self._count, self._sum
        if not vals:
            return {"count": 0, "mean": float("nan"),
                    "p50": float("nan"), "p99": float("nan")}
        arr = np.asarray(vals)
        return {"count": count, "mean": total / count,
                "p50": float(np.percentile(arr, 50)),
                "p99": float(np.percentile(arr, 99))}


class Metrics:
    def __init__(self, hist_max_samples: Optional[int] = None):
        # counters stays a defaultdict so read-side code can probe
        # metrics.counters["name"] without guards; all WRITES go through
        # inc() under the lock
        self.counters = defaultdict(int)
        self.hists: dict[str, Histogram] = {}
        self._hist_max = hist_max_samples
        self._lock = threading.Lock()

    def inc(self, name: str, n: int = 1):
        with self._lock:
            self.counters[name] += n

    def hist(self, name: str) -> Histogram:
        """The named histogram, created atomically on first use."""
        with self._lock:
            h = self.hists.get(name)
            if h is None:
                h = self.hists[name] = Histogram(
                    max_samples=self._hist_max)
            return h

    def observe(self, name: str, v: float):
        self.hist(name).observe(v)

    def timeit(self, name: str):
        metrics = self

        class _Timer:
            def __enter__(self):
                self.t0 = time.perf_counter()
                return self

            def __exit__(self, *exc):
                self.elapsed = time.perf_counter() - self.t0
                metrics.observe(name, self.elapsed)
        return _Timer()

    def snapshot(self) -> dict:
        with self._lock:
            counters = dict(self.counters)
            hists = dict(self.hists)
        return {"counters": counters,
                "hists": {k: h.snapshot() for k, h in hists.items()}}
