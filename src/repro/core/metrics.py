"""Lightweight counters/histograms for runtime accounting.

Thread-safety contract: every public operation — ``inc``, ``observe``,
``timeit``, ``snapshot``, and the ``Histogram`` accessors — may be called
from any number of threads concurrently (gateway workers, platform refill
threads, runtime workers, the janitor). Counters live behind the
``Metrics`` lock; each ``Histogram`` has its own lock; histogram
*creation* is serialized under the ``Metrics`` lock so two racing
``observe`` calls on a brand-new name can never each create a histogram
and drop one of the observations (the old ``defaultdict`` pattern did
exactly that). ``snapshot`` copies the maps under the lock before
rendering, so it never iterates a dict another thread is growing.
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict

import numpy as np


class Histogram:
    def __init__(self):
        self._vals: list[float] = []
        self._lock = threading.Lock()

    def observe(self, v: float):
        with self._lock:
            self._vals.append(float(v))

    def _copy(self) -> list:
        with self._lock:
            return list(self._vals)

    def percentile(self, q) -> float:
        vals = self._copy()
        if not vals:
            return float("nan")
        return float(np.percentile(vals, q))

    @property
    def count(self) -> int:
        with self._lock:
            return len(self._vals)

    @property
    def mean(self) -> float:
        vals = self._copy()
        return float(np.mean(vals)) if vals else float("nan")

    @property
    def sum(self) -> float:
        """Total of all observations (0.0 when empty)."""
        vals = self._copy()
        return float(np.sum(vals)) if vals else 0.0

    def count_sum(self) -> tuple:
        """One consistent ``(count, sum)`` pair under a single lock
        hold. This is the window-edge primitive: snapshotting the pair
        at two points in time yields the exact mean of the observations
        between them even while writers keep appending — the gateway's
        CalibrationProbe measures replay-window startup costs this way
        (reading ``count`` and ``sum`` as two separate calls could
        straddle a concurrent observe and tear the pair)."""
        with self._lock:
            return len(self._vals), float(sum(self._vals))

    def snapshot(self) -> dict:
        # one consistent copy: count/mean/percentiles all describe the
        # same set of observations even while writers keep appending
        vals = self._copy()
        if not vals:
            return {"count": 0, "mean": float("nan"),
                    "p50": float("nan"), "p99": float("nan")}
        arr = np.asarray(vals)
        return {"count": len(vals), "mean": float(arr.mean()),
                "p50": float(np.percentile(arr, 50)),
                "p99": float(np.percentile(arr, 99))}


class Metrics:
    def __init__(self):
        # counters stays a defaultdict so read-side code can probe
        # metrics.counters["name"] without guards; all WRITES go through
        # inc() under the lock
        self.counters = defaultdict(int)
        self.hists: dict[str, Histogram] = {}
        self._lock = threading.Lock()

    def inc(self, name: str, n: int = 1):
        with self._lock:
            self.counters[name] += n

    def hist(self, name: str) -> Histogram:
        """The named histogram, created atomically on first use."""
        with self._lock:
            h = self.hists.get(name)
            if h is None:
                h = self.hists[name] = Histogram()
            return h

    def observe(self, name: str, v: float):
        self.hist(name).observe(v)

    def timeit(self, name: str):
        metrics = self

        class _Timer:
            def __enter__(self):
                self.t0 = time.perf_counter()
                return self

            def __exit__(self, *exc):
                self.elapsed = time.perf_counter() - self.t0
                metrics.observe(name, self.elapsed)
        return _Timer()

    def snapshot(self) -> dict:
        with self._lock:
            counters = dict(self.counters)
            hists = dict(self.hists)
        return {"counters": counters,
                "hists": {k: h.snapshot() for k, h in hists.items()}}
