"""Architecture config registry: ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, MoEConfig
from repro.configs.shapes import SHAPES, ShapeConfig, applicable_shapes

_ARCH_MODULES = {
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "musicgen-large": "repro.configs.musicgen_large",
    "internvl2-76b": "repro.configs.internvl2_76b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "mamba2-780m": "repro.configs.mamba2_780m",
}


def list_archs() -> list:
    return sorted(_ARCH_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    return importlib.import_module(_ARCH_MODULES[name]).CONFIG


__all__ = [
    "ArchConfig",
    "MoEConfig",
    "SHAPES",
    "ShapeConfig",
    "applicable_shapes",
    "get_config",
    "list_archs",
]
