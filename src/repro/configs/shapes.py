"""Assigned input-shape sets.

LM transformer shapes are seq_len x global_batch. ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token against a KV cache of seq_len), NOT
``train_step``. ``long_500k`` requires sub-quadratic attention and only runs
for SSM / hybrid / sliding-window archs (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg) -> list:
    """Shapes that are well-defined for this architecture."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.supports_long_context:
        out.append(SHAPES["long_500k"])
    return out
