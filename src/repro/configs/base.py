"""Architecture configuration dataclasses.

Every assigned architecture is expressed as an ``ArchConfig``. The full
configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation); smoke tests use ``reduced()`` variants of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    activation: str = "silu"                # silu | relu2 | gelu
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- sliding-window / local:global pattern (gemma3) ---
    sliding_window: Optional[int] = None    # window size for local layers
    global_every: Optional[int] = None      # every k-th layer is global
    # --- MoE ---
    moe: Optional[MoEConfig] = None
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: Optional[int] = None         # per-head state size N
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256                    # SSD chunk length
    # --- hybrid (zamba2-style shared attention) ---
    hybrid_attn_every: Optional[int] = None  # shared attn block every k layers
    # --- modality frontend stub ---
    frontend: Optional[str] = None          # vision | audio
    frontend_tokens: int = 0                # prefix embedding positions (vlm)
    # --- numerics / serving ---
    dtype: str = "bfloat16"
    serve_param_sharding: str = "tp"        # tp | fsdp (big models need fsdp)
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if the arch can serve ``long_500k`` (sub-quadratic attention
        state: SSM, hybrid, or sliding-window local attention)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        per_layer = 0
        attn = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
        if self.moe is not None:
            ffn = self.moe.num_experts * (3 * d * self.d_ff) + d * self.moe.num_experts
        else:
            ffn = 3 * d * self.d_ff if self.activation == "silu" else 2 * d * self.d_ff
        if self.family == "ssm":
            # mamba2 block: in_proj (2*d_inner + 2*groups*N + heads), out_proj
            din, N, H = self.d_inner, self.ssm_state or 128, self.ssm_heads
            per_layer = d * (2 * din + 2 * N + H) + din * d + 2 * d
        elif self.family == "hybrid":
            din, N, H = self.d_inner, self.ssm_state or 64, self.ssm_heads
            mamba = d * (2 * din + 2 * N + H) + din * d + 2 * d
            per_layer = mamba
            shared = attn + 3 * d * self.d_ff  # one shared attn+mlp block total
            return emb + head + self.n_layers * per_layer + shared
        else:
            per_layer = attn + ffn + 2 * d
        return emb + head + self.n_layers * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        dense_ffn_total = self.n_layers * self.moe.num_experts * (3 * d * self.d_ff)
        active_ffn_total = self.n_layers * self.moe.top_k * (3 * d * self.d_ff)
        return self.param_count() - dense_ffn_total + active_ffn_total

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 4 if self.family == "hybrid" else 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            frontend_tokens=4 if self.frontend == "vision" else 0,
            ssm_head_dim=16,
            ssm_chunk=8,
        )
        if self.moe is not None:
            # high capacity factor: no token drops, so reduced-config tests
            # are exactly composition-invariant (full configs keep 1.25)
            kw["moe"] = MoEConfig(num_experts=4, top_k=2,
                                  capacity_factor=4.0)
        if self.ssm_state is not None:
            kw["ssm_state"] = 16
        if self.sliding_window is not None:
            kw["sliding_window"] = 8
        if self.hybrid_attn_every is not None:
            kw["hybrid_attn_every"] = 2
        return dataclasses.replace(self, **kw)
