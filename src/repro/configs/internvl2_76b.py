"""internvl2-76b [vlm] — InternViT + InternLM2 backbone; the vision tower is
a stub (input_specs provides precomputed patch embeddings).
[arXiv:2404.16821; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    activation="silu",
    frontend="vision",
    frontend_tokens=256,
    serve_param_sharding="fsdp",   # 152GB bf16 params: TP-16 alone is too tight
    source="arXiv:2404.16821; unverified",
)
