"""gemma3-1b [dense] — 5:1 local:global sliding-window, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab_size=262144,
    head_dim=256,
    activation="gelu",
    rope_theta=1000000.0,
    tie_embeddings=True,
    sliding_window=512,
    global_every=6,   # 5 local : 1 global
    source="hf:google/gemma-3-1b-pt; unverified",
)
