"""dbrx-132b [moe] — 16 experts, top-4, fine-grained.
[hf:databricks/dbrx-base; unverified]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,                    # per-expert FFN width
    vocab_size=100352,
    head_dim=128,
    activation="silu",
    moe=MoEConfig(num_experts=16, top_k=4),
    serve_param_sharding="fsdp",   # 264GB bf16 params: must shard over data too
    source="hf:databricks/dbrx-base; unverified",
)
