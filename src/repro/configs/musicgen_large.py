"""musicgen-large [audio] — decoder-only over EnCodec tokens; frontend is a
stub (input_specs provides precomputed frame embeddings).
[arXiv:2306.05284; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    head_dim=64,
    activation="gelu",
    frontend="audio",
    source="arXiv:2306.05284; hf",
)
