"""Roofline analysis from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), all in seconds (per device):

  compute    = HLO_FLOPs_per_device / PEAK_BF16_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = wire_bytes_per_device / ICI_BW

``cost_analysis()`` on an SPMD executable reports PER-DEVICE flops/bytes
(verified empirically — a (4,2)-sharded matmul reports total/8). Collective
bytes are NOT in cost_analysis: we parse the compiled HLO text and apply
ring-collective wire formulas per op:

  all-gather        out_bytes * (g-1)/g
  reduce-scatter    out_bytes * (g-1)          (input = out*g)
  all-reduce        2 * out_bytes * (g-1)/g    (reduce-scatter + all-gather)
  all-to-all        out_bytes * (g-1)/g
  collective-permute out_bytes

where g is the replica-group size parsed from the instruction.

NOTE: scan bodies are costed ONCE by XLA cost analysis — the dry-run
therefore lowers with ``unroll=True`` so the counts are exact.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_BF16_FLOPS

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e5m2": 1, "f8e4m3fn": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

# `%name = TYPE[dims]{layout} collective-op(...)` — possibly tuple-typed
_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],\s{}/#*]+?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|f8e5m2|f8e4m3fn|c64|c128)\[([\d,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _LIST_GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # unknown: conservative minimum that moves data


def collective_bytes(hlo_text: str) -> dict:
    """Per-op-kind wire bytes (per device) summed over the module."""
    out: dict = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
                 "all-to-all": 0.0, "collective-permute": 0.0, "count": 0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        # async pairs: count -start, skip -done
        if "-done(" in line:
            continue
        type_str, op = m.group(1), m.group(2).lower()
        nbytes = _shape_bytes(type_str)
        g = _group_size(line)
        if op == "all-gather":
            wire = nbytes * (g - 1) / g
        elif op == "all-reduce":
            wire = 2 * nbytes * (g - 1) / g
        elif op == "reduce-scatter":
            wire = nbytes * (g - 1)
        elif op == "all-to-all":
            wire = nbytes * (g - 1) / g
        else:  # collective-permute
            wire = nbytes
        out[op] += wire
        out["count"] += 1
    out["total"] = sum(v for k, v in out.items()
                       if k not in ("count", "total"))
    return out


# Top-level instruction: `%name = TYPE[dims]{layout} op(%operand0, ...)`
_INSTR_RE = re.compile(
    r"%?([\w.-]+)\s*=\s*([a-z0-9]+\[[\d,]*\])[^\s]*\s+"
    r"([\w-]+)\(%?([\w.-]+)(?:,\s*%?([\w.-]+))?")
_DEF_RE = re.compile(r"%?([\w.-]+)\s*=\s*((?:pred|[suf]\d+|bf16)\[[\d,]*\])")

_BIG = 64 << 20  # only correct ops moving >64 MB


def cpu_artifact_correction(hlo_text: str) -> dict:
    """Bytes cost_analysis charges on the CPU dry-run host that do not exist
    in TPU execution:

    * ``convert``/``copy`` of large buffers — the CPU backend legalizes bf16
      scatter/DUS by converting whole operands to f32 and donation copies
      are materialized; TPU HLO runs native bf16 and aliases donated
      buffers. Correction: read(in) + write(out) per big top-level op.
    * ``dynamic-update-slice``/``scatter`` with small updates — charged as
      read(dst)+read(upd)+write(out); on TPU these update donated/carried
      buffers in place: true cost ~ 2*update_bytes.
      Correction: 2*out_bytes - update_bytes.

    Returns {"bytes": total_overcount, "n_ops": count}. Callers floor the
    corrected total at the ideal traffic (arguments+outputs read/written
    once) so the correction can never undershoot physical minimum traffic.
    """
    defs = {}
    for m in _DEF_RE.finditer(hlo_text):
        defs[m.group(1)] = _shape_bytes(m.group(2))
    over = 0.0
    temp_over = 0.0
    n = 0
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        name, out_type, op, op0, op1 = m.groups()
        out_b = _shape_bytes(out_type)
        if out_b < _BIG:
            continue
        if op in ("convert", "copy"):
            # write side only: conservative vs fusion double-counting
            over += out_b
            temp_over += out_b
            n += 1
        elif op in ("dynamic-update-slice", "scatter"):
            upd_b = defs.get(op1, 0) if op1 else 0
            if out_b > 4 * max(upd_b, 1):
                over += max(0.0, out_b - upd_b)
                n += 1
    return {"bytes": over, "n_ops": n, "temp_bytes": temp_over}


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    collectives: dict
    n_devices: int
    raw_bytes_per_device: float = 0.0
    ideal_bytes_per_device: float = 0.0
    corrected_ops: int = 0

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_BF16_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline-model step time (no overlap assumption = max term)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def summary(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "raw_bytes_per_device": self.raw_bytes_per_device,
            "ideal_bytes_per_device": self.ideal_bytes_per_device,
            "cpu_artifact_ops_corrected": self.corrected_ops,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "n_collectives": self.collectives.get("count", 0),
            "collectives": {k: v for k, v in self.collectives.items()
                            if k not in ("count", "total")},
        }


def analyze(compiled, n_devices: int, *, scale: float = 1.0) -> Roofline:
    """Build roofline terms from a compiled executable.

    ``scale`` multiplies all three terms (used to scale one lowered
    microbatch step to the full gradient-accumulation step).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):       # JAX 0.4.x: one dict per device
        ca = ca[0] if ca else {}
    text = compiled.as_text()
    flops = float(ca.get("flops", 0.0)) * scale
    raw_bytes = float(ca.get("bytes accessed", 0.0))
    corr = cpu_artifact_correction(text)
    ma = compiled.memory_analysis()
    # physical floor: non-aliased outputs must be written once. (Arguments
    # are NOT all necessarily read — donated KV caches are touched only in
    # the attended window — so args are left to the corrected measurement.)
    ideal = float(max(ma.output_size_in_bytes - ma.alias_size_in_bytes, 0))
    corrected = max(raw_bytes - corr["bytes"], ideal)
    nbytes = corrected * scale
    colls = collective_bytes(text)
    wire = colls["total"] * scale
    return Roofline(flops_per_device=flops, bytes_per_device=nbytes,
                    wire_bytes_per_device=wire, collectives=colls,
                    n_devices=n_devices, raw_bytes_per_device=raw_bytes * scale,
                    ideal_bytes_per_device=ideal * scale,
                    corrected_ops=corr["n_ops"])
