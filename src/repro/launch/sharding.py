"""Logical-axis sharding policy (MaxText-style rules).

Models annotate activations with ``shard(x, "batch", "seq", None)`` using
*logical* axis names; a thread-local ``AxisRules`` maps logical names to
mesh axes. Parameter PartitionSpecs are derived from pytree paths by
``param_specs``.

Mesh axes: ``("pod", "data", "model")`` multi-pod, ``("data", "model")``
single pod. Logical axes:

  batch    -> (pod, data)            DP
  kv_seq   -> data (long-context SP) or None
  heads/ff/vocab/experts -> model    TP / EP
  fsdp     -> data (param+optimizer sharding for training / big-model serve)
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AxisRules:
    mesh: Optional[Mesh] = None
    batch: tuple = ("data",)          # ("pod","data") on multi-pod meshes
    seq: Optional[str] = None         # activation seq sharding (rare)
    kv_seq: object = None             # KV-cache seq sharding (axis or tuple)
    kv_heads: Optional[str] = None    # KV-cache head sharding (GQA-divisible)
    heads: Optional[str] = "model"
    ff: Optional[str] = "model"
    vocab: Optional[str] = "model"
    experts: Optional[str] = "model"
    fsdp: Optional[str] = None        # extra param-shard axis ("data")
    moe_ff: Optional[str] = None      # 2D EP: expert FFN dim axis (e.g. "data")

    def resolve(self, name):
        if name is None:
            return None
        return getattr(self, name)


_tls = threading.local()


def current_rules() -> Optional[AxisRules]:
    return getattr(_tls, "rules", None)


@contextlib.contextmanager
def use_rules(rules: AxisRules):
    prev = current_rules()
    _tls.rules = rules
    try:
        yield rules
    finally:
        _tls.rules = prev


def default_rules(mesh: Mesh, *, fsdp: bool = False, kv_seq: bool = False) -> AxisRules:
    axes = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in axes)
    if kv_seq:
        # sequence parallelism claims the data axis; batch keeps only pod
        # (long-context cells have global_batch=1 anyway)
        batch = tuple(a for a in batch if a != "data")
    return AxisRules(
        mesh=mesh,
        batch=batch or (None,),
        kv_seq="data" if (kv_seq and "data" in axes) else None,
        heads="model" if "model" in axes else None,
        ff="model" if "model" in axes else None,
        vocab="model" if "model" in axes else None,
        experts="model" if "model" in axes else None,
        fsdp="data" if (fsdp and "data" in axes) else None,
    )


def logical_spec(*logical_axes) -> P:
    """Resolve logical axis names to a PartitionSpec under current rules."""
    rules = current_rules()
    if rules is None:
        return P()
    out = []
    for ax in logical_axes:
        if ax is None:
            out.append(None)
        elif ax == "batch":
            b = tuple(a for a in rules.batch if a)
            out.append(b if b else None)
        else:
            out.append(rules.resolve(ax))
    return P(*out)


def shard(x: jax.Array, *logical_axes) -> jax.Array:
    """Apply a with_sharding_constraint using logical axis names (no-op when
    no rules/mesh are active — keeps single-device tests mesh-free)."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = logical_spec(*logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding rules: pytree-path regex -> logical axes per dim.
# Paths are "/"-joined dict keys; stacked layer params have a leading L dim
# which is never sharded.
# ---------------------------------------------------------------------------
# (regex, logical axes for each dim — matched against the *trailing* dims,
#  leading unmatched dims get None)
_PARAM_RULES = [
    (r"embed/tok$",            ("vocab", "fsdp")),
    (r"lm_head$",              ("fsdp", "vocab")),
    (r"attn/wq$",              ("fsdp", "heads")),
    (r"attn/wk$",              ("fsdp", "kv_heads")),   # resolved specially
    (r"attn/wv$",              ("fsdp", "kv_heads")),
    (r"attn/wo$",              ("heads", "fsdp")),
    (r"attn/bq$",              ("heads",)),
    (r"attn/bk$",              ("kv_heads",)),
    (r"attn/bv$",              ("kv_heads",)),
    (r"mlp/w_gate$",           ("fsdp", "ff")),
    (r"mlp/w_up$",             ("fsdp", "ff")),
    (r"mlp/w_down$",           ("ff", "fsdp")),
    (r"moe/router$",           ("fsdp", None)),
    # expert parallelism owns the model axis. Default: shard D over fsdp.
    # With rules.moe_ff set (2D EP), the per-expert FFN dim F is sharded
    # instead — contraction stays local, avoiding per-step weight gathers.
    (r"moe/w_gate$",           ("experts", "moe_d", "moe_f")),
    (r"moe/w_up$",             ("experts", "moe_d", "moe_f")),
    (r"moe/w_down$",           ("experts", "moe_f", "moe_d")),
    (r"ssm/in_proj$",          ("fsdp", "ff")),
    (r"ssm/out_proj$",         ("ff", "fsdp")),
    (r"ssm/(conv_w|conv_b|A_log|D|dt_bias|norm)$", (None,)),
    (r"(ln1|ln2|ln|final_norm|q_norm|k_norm)$", (None,)),
]


def _spec_for_path(path: str, shape: tuple, rules: AxisRules,
                   kv_shardable: bool) -> P:
    for pat, logical in _PARAM_RULES:
        if re.search(pat, path):
            dims = []
            for ax in logical:
                if ax == "kv_heads":
                    ax = "heads" if kv_shardable else None
                elif ax == "moe_f":
                    ax = "moe_ff" if rules.moe_ff else None
                elif ax == "moe_d":
                    ax = None if rules.moe_ff else "fsdp"
                if ax is None:
                    dims.append(None)
                else:
                    dims.append(rules.resolve(ax))
            # pad leading dims (stacked layer axis etc.) with None
            lead = len(shape) - len(dims)
            spec = [None] * lead + dims
            # drop illegal shardings (dim not divisible by axis size)
            mesh = rules.mesh
            clean = []
            for size, ax in zip(shape, spec):
                if ax is None:
                    clean.append(None)
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a in axes:
                    n *= mesh.shape[a]
                clean.append(ax if size % n == 0 else None)
            return P(*clean)
    return P()  # replicate by default


def param_specs(params, rules: AxisRules, cfg=None):
    """PartitionSpec pytree matching ``params`` (dict-of-dict of arrays)."""
    tp = rules.mesh.shape.get("model", 1) if rules.mesh else 1
    kv_shardable = bool(cfg is None or cfg.n_kv_heads == 0
                        or (cfg.n_kv_heads * cfg.resolved_head_dim) % max(
                            1, tp * cfg.resolved_head_dim) == 0)
    # KV projections are sharded over heads only when every shard gets whole
    # heads; otherwise replicate (standard GQA TP practice).
    if cfg is not None and cfg.n_kv_heads:
        kv_shardable = cfg.n_kv_heads % tp == 0

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for keypath, leaf in flat:
        path = "/".join(str(getattr(k, "key", k)) for k in keypath)
        specs.append(_spec_for_path(path, leaf.shape, rules, kv_shardable))
    return jax.tree_util.tree_unflatten(treedef, specs)


def named_sharding_tree(params, rules: AxisRules, cfg=None):
    specs = param_specs(params, rules, cfg)
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
