"""End-to-end training driver with checkpoint/restart, failure injection,
straggler detection, elastic restore and optional gradient compression.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \\
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ck
  # node-failure drill: inject a failure, watch restore+resume
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-780m --reduced \\
      --steps 30 --fail-at 12 --ckpt-dir /tmp/ck2
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataConfig, Prefetcher
from repro.ft import checkpoint as ckpt
from repro.ft.compression import ErrorFeedbackCompression
from repro.ft.failures import (FailureInjector, HeartbeatMonitor,
                               InjectedFailure)
from repro.launch.mesh import make_local_mesh
from repro.launch.sharding import default_rules, named_sharding_tree, use_rules
from repro.models.programs import ModelProgram
from repro.optim import AdamW, warmup_cosine


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, name=cfg.name)
    prog = ModelProgram(cfg, remat=args.remat)
    opt = AdamW(lr=warmup_cosine(args.lr, args.warmup, args.steps))
    if args.compress:
        opt = ErrorFeedbackCompression(opt)
    return cfg, prog, opt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b",
                    help="model architecture to train")
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the reduced config (default)")
    ap.add_argument("--full", dest="reduced", action="store_false",
                    help="use the full-size config instead of --reduced")
    ap.add_argument("--steps", type=int, default=50,
                    help="optimizer steps to run")
    ap.add_argument("--batch", type=int, default=8,
                    help="global batch size")
    ap.add_argument("--seq", type=int, default=128,
                    help="sequence length")
    ap.add_argument("--n-micro", type=int, default=1,
                    help="microbatches per step (gradient accumulation)")
    ap.add_argument("--lr", type=float, default=3e-4,
                    help="peak learning rate")
    ap.add_argument("--warmup", type=int, default=10,
                    help="linear warmup steps")
    ap.add_argument("--ckpt-dir", default=None,
                    help="write checkpoints under this directory")
    ap.add_argument("--ckpt-every", type=int, default=10,
                    help="checkpoint interval in steps")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (restart drill)")
    ap.add_argument("--compress", action="store_true",
                    help="wrap the optimizer in error-feedback compression")
    ap.add_argument("--remat", action="store_true",
                    help="enable rematerialization (activation ckpting)")
    ap.add_argument("--data-model", default="1,1",
                    help="local mesh shape data,model")
    args = ap.parse_args(argv)

    cfg, prog, opt = build(args)
    dm = [int(x) for x in args.data_model.split(",")]
    mesh = make_local_mesh(dm[0], dm[1])
    rules = default_rules(mesh, fsdp=True)

    monitor = HeartbeatMonitor()
    injector = FailureInjector(
        fail_at_steps=(args.fail_at,) if args.fail_at else ())

    with use_rules(rules):
        params = prog.init(jax.random.PRNGKey(0))
        pshard = named_sharding_tree(params, rules, cfg)
        params = jax.tree.map(jax.device_put, params, pshard)
        opt_state = opt.init(params)
        step_fn = jax.jit(prog.make_train_step(opt, n_micro=args.n_micro),
                          donate_argnums=(0, 1))

        start_step = 0
        writer = None
        if args.ckpt_dir:
            writer = ckpt.AsyncCheckpointer(args.ckpt_dir)
            last = ckpt.latest_step(args.ckpt_dir)
            if last is not None:
                state = ckpt.restore(args.ckpt_dir, last,
                                     {"params": params, "opt": opt_state})
                params, opt_state = state["params"], state["opt"]
                start_step = last + 1
                print(f"[train] resumed from step {last}")

        data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                              batch_size=args.batch)
        pf = Prefetcher(data_cfg, start_step=start_step)
        losses = []
        t_start = time.perf_counter()
        step = start_step
        try:
            while step < args.steps:
                dstep, batch = pf.next()
                assert dstep == step, (dstep, step)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                if cfg.family == "audio":
                    rng = jax.random.PRNGKey(step)
                    batch = {
                        "embeds": jax.random.normal(
                            rng, (args.batch, args.seq, cfg.d_model),
                            jnp.float32).astype(jnp.dtype(cfg.dtype)),
                        "labels": batch["labels"] % cfg.vocab_size,
                    }
                elif cfg.family == "vlm":
                    ft_n = cfg.frontend_tokens
                    rng = jax.random.PRNGKey(step)
                    batch = {
                        "embeds": jax.random.normal(
                            rng, (args.batch, ft_n, cfg.d_model),
                            jnp.float32).astype(jnp.dtype(cfg.dtype)),
                        "tokens": batch["tokens"][:, :args.seq - ft_n],
                        "labels": batch["labels"],
                    }
                try:
                    injector.check(step)
                    params, opt_state, mets = step_fn(params, opt_state,
                                                      batch)
                except InjectedFailure as e:
                    print(f"[train] FAILURE: {e}")
                    if not args.ckpt_dir:
                        raise
                    if writer:
                        writer.wait()
                    last = ckpt.latest_step(args.ckpt_dir)
                    assert last is not None, "no checkpoint to restore"
                    # elastic restore onto the (possibly new) mesh
                    params = prog.init(jax.random.PRNGKey(0))
                    params = jax.tree.map(jax.device_put, params, pshard)
                    opt_state = opt.init(params)
                    state = ckpt.restore(args.ckpt_dir, last,
                                         {"params": params, "opt": opt_state})
                    params, opt_state = state["params"], state["opt"]
                    pf.close()
                    step = last + 1
                    pf = Prefetcher(data_cfg, start_step=step)
                    print(f"[train] restored step {last}, resuming at {step}")
                    continue
                monitor.beat("worker0")
                loss = float(mets["loss"])
                losses.append(loss)
                if step % 5 == 0 or step == args.steps - 1:
                    dt = time.perf_counter() - t_start
                    print(f"[train] step {step:4d} loss {loss:7.4f} "
                          f"gnorm {float(mets.get('grad_norm', 0)):6.3f} "
                          f"({dt:5.1f}s)", flush=True)
                if writer and step % args.ckpt_every == 0:
                    writer.save_async(step, {"params": params,
                                             "opt": opt_state})
                step += 1
        finally:
            pf.close()
            if writer:
                writer.wait()
        print(f"[train] done: first loss {losses[0]:.4f} "
              f"last loss {losses[-1]:.4f}")
        return losses


if __name__ == "__main__":
    main()
