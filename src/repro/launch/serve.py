"""Multi-tenant serving driver: HydraCluster/HydraPlatform/HydraRuntime +
continuous batching, plus the live trace-replay gateway.

Two modes:

**Closed-loop LM serving** (default): registers N tenant functions
(optionally different architectures) and replays a synthetic request
stream through continuous batchers, reporting density metrics:
cold/warm starts, executable-cache sharing, arena-pool behaviour,
latency.

**Open-loop gateway replay** (``--gateway``): replays a trace — an
Azure Functions 2019 CSV via ``--trace-file``, or the synthetic
generator — in wall-clock time against the selected live stack through
``repro.gateway``: per-tenant bounded queues, admission control, SLO
timeouts, background pool autoscaling, and a ``SimResult``-schema
summary directly comparable with ``repro.core.sim`` output.
``--compress`` sets how many trace seconds replay per wall second.

Serving stack is selected by flags (both modes):

  * ``--nodes K`` (K >= 2) — a ``HydraCluster`` of K single-machine
    platforms: colocation-aware cross-node placement, snapshot migration,
    and EWMA-adaptive per-node pre-warmed pools.
  * ``--pool N`` (default 2, with ``--nodes`` < 2) — one ``HydraPlatform``:
    a pre-warmed instance pool of N generic runtimes with colocation-aware
    placement and snapshot/restore.
  * ``--pool 0`` — a single raw ``HydraRuntime`` (no platform layer).

Other knobs: ``--runtime-budget-gb`` caps each runtime's memory budget,
``--node-memory-gb`` caps each cluster node's placement budget, and
``--snapshot-dir`` enables sandbox snapshot/evict/restore (and is required
for cluster migration).

  PYTHONPATH=src python -m repro.launch.serve --archs qwen2.5-3b,mamba2-780m \\
      --tenants 4 --requests 32 --slots 4 --pool 2

  PYTHONPATH=src python -m repro.launch.serve --tenants 4 --requests 16 \\
      --nodes 2 --pool 1

  PYTHONPATH=src python -m repro.launch.serve --gateway \\
      --trace-file benchmarks/data/azure_sample.csv --compress 60
"""
from __future__ import annotations

import argparse
import glob
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (ClusterParams, HydraCluster, HydraPlatform,
                        HydraRuntime, LMSpec, PlatformParams)
from repro.core.scheduler import ContinuousBatcher
from repro.models.programs import ModelProgram


def find_tcmalloc() -> str:
    """Locate a tcmalloc shared library, or ''. Checked glob-first (the
    common Debian/Ubuntu multiarch paths), then the linker cache."""
    for pat in ("/usr/lib/*/libtcmalloc.so*",
                "/usr/lib/*/libtcmalloc_minimal.so*",
                "/usr/lib64/libtcmalloc*.so*",
                "/usr/lib/libtcmalloc*.so*"):
        hits = sorted(glob.glob(pat))
        if hits:
            return hits[0]
    try:
        import ctypes.util
        return (ctypes.util.find_library("tcmalloc")
                or ctypes.util.find_library("tcmalloc_minimal") or "")
    except Exception:
        return ""


def maybe_reexec_tcmalloc(argv) -> None:
    """Re-exec this process with tcmalloc LD_PRELOADed (the arena-heavy
    allocation pattern — many same-sized slab mints and frees across
    threads — is tcmalloc's thread-cache sweet spot; glibc malloc
    serializes it on arena locks). A no-op when tcmalloc is already
    preloaded (the guard that terminates the exec loop) or when no
    library is installed. The large-alloc report threshold is raised so
    multi-GB slab reservations don't spam stderr — same idiom as the
    launcher scripts shipped with large jax training runs."""
    if "tcmalloc" in os.environ.get("LD_PRELOAD", ""):
        return
    lib = find_tcmalloc()
    if not lib:
        print("[serve] --tcmalloc: no libtcmalloc found; continuing "
              "with the default allocator", file=sys.stderr)
        return
    env = dict(os.environ)
    env["LD_PRELOAD"] = f"{lib} {env.get('LD_PRELOAD', '')}".strip()
    env.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD", "60000000000")
    os.execve(sys.executable,
              [sys.executable, "-m", "repro.launch.serve", *argv], env)


def make_params(cfg, seed: int = 0):
    prog = ModelProgram(cfg)
    params = prog.init(jax.random.PRNGKey(seed))
    return jax.tree.map(
        lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x,
        params)


def build_target(args, arena_ttl_s=None):
    """The serving stack selected by --nodes/--pool — one construction
    path shared by the closed-loop driver and gateway mode, so the same
    flags always mean the same deployment. ``arena_ttl_s`` overrides
    the isolate keep-alive (gateway mode compresses it); None keeps the
    stack defaults."""
    budget = int(args.runtime_budget_gb * (1 << 30))
    ttl = {} if arena_ttl_s is None else {"arena_ttl_s": arena_ttl_s}
    if args.nodes >= 2:
        return HydraCluster(ClusterParams(
            n_nodes=args.nodes,
            node_memory_bytes=int(args.node_memory_gb * (1 << 30)),
            snapshot_dir=args.snapshot_dir,
            platform=PlatformParams(pool_size=max(args.pool, 1),
                                    runtime_budget_bytes=budget, **ttl)))
    if args.pool > 0:
        return HydraPlatform(PlatformParams(
            pool_size=args.pool, runtime_budget_bytes=budget,
            snapshot_dir=args.snapshot_dir, **ttl))
    return HydraRuntime(memory_budget_bytes=budget, **ttl)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default="qwen2.5-3b",
                    help="comma-separated model architectures to serve "
                         "(closed-loop LM driver)")
    ap.add_argument("--tenants", type=int, default=2,
                    help="tenants per architecture (each gets its own "
                         "registered function)")
    ap.add_argument("--requests", type=int, default=16,
                    help="closed-loop requests to issue per tenant")
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous-batching slots per LM runtime")
    ap.add_argument("--max-seq", type=int, default=128,
                    help="KV-cache sequence capacity per slot")
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="synthetic prompt length in tokens")
    ap.add_argument("--max-new", type=int, default=16,
                    help="tokens to generate per request")
    ap.add_argument("--pool", type=int, default=2,
                    help="pre-warmed platform pool size (0 = raw runtime)")
    ap.add_argument("--nodes", type=int, default=0,
                    help="serve through a HydraCluster of this many nodes "
                         "(< 2 = single-node platform/runtime)")
    ap.add_argument("--runtime-budget-gb", type=float, default=8.0,
                    help="per-runtime memory budget in GiB (registration "
                         "admission + arena capacity)")
    ap.add_argument("--node-memory-gb", type=float, default=16.0,
                    help="per-node placement budget (cluster mode)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="enable sandbox snapshot/restore under this dir")
    ap.add_argument("--calibration", default=None,
                    help="after serving, write measured costs (runtime "
                         "boot, register, restore) as a "
                         "hydra-calibration/v1 JSON for the trace "
                         "simulator (see bench_trace --calibration); in "
                         "gateway mode the costs come from the replay's "
                         "CalibrationProbe")
    # ---- gateway mode: open-loop wall-clock trace replay ----
    ap.add_argument("--gateway", action="store_true",
                    help="replay a trace open-loop in wall-clock time "
                         "through the serving gateway (repro.gateway) "
                         "instead of the closed-loop LM driver")
    ap.add_argument("--trace-file", default=None,
                    help="Azure Functions 2019-format invocations CSV "
                         "(gateway mode; default: a synthetic trace)")
    ap.add_argument("--compress", type=float, default=60.0,
                    help="trace seconds replayed per wall second "
                         "(gateway mode)")
    ap.add_argument("--target-rps", type=float, default=None,
                    help="deterministically thin the trace to this mean "
                         "rps (gateway mode)")
    ap.add_argument("--max-minutes", type=int, default=None,
                    help="replay only the first N trace minutes "
                         "(gateway mode)")
    ap.add_argument("--seed", type=int, default=0,
                    help="RNG seed for synthetic traces and payloads")
    ap.add_argument("--mem-scale", type=float, default=1.0 / 64,
                    help="trace function memory -> live arena scale "
                         "(gateway mode)")
    ap.add_argument("--gw-workers", type=int, default=16,
                    help="gateway worker threads (gateway mode)")
    ap.add_argument("--queue-depth", type=int, default=256,
                    help="per-tenant gateway queue bound (gateway mode)")
    ap.add_argument("--slo-timeout", type=float, default=None,
                    help="drop requests older than this many TRACE "
                         "seconds instead of serving them late "
                         "(gateway mode)")
    ap.add_argument("--tenant-rate", type=float, default=None,
                    help="per-tenant token-bucket rate in trace req/s "
                         "(gateway mode)")
    ap.add_argument("--tcmalloc", action="store_true",
                    help="re-exec with tcmalloc LD_PRELOADed when the "
                         "library is installed (thread-cached malloc "
                         "suits the arena-heavy allocation pattern); "
                         "silently keeps the default allocator when "
                         "libtcmalloc is absent")
    ap.add_argument("--round-trip", action="store_true",
                    help="gateway mode: close the gateway -> calibration "
                         "-> sim loop — replay live, derive a "
                         "calibration from that run, re-simulate with "
                         "it, and report whether the calibrated sim "
                         "tracks live at least as tightly as the "
                         "uncalibrated sim (repro.gateway.validate; "
                         "always validates the single-node platform "
                         "stack, so --nodes is ignored)")
    ap.add_argument("--attribute", action="store_true",
                    help="with --round-trip: trace the live leg and "
                         "report which request phase dominates the "
                         "live-vs-sim cold and p99 deltas "
                         "(repro.core.tracing attribution)")
    # ---- request tracing (gateway mode; repro.core.tracing) ----
    ap.add_argument("--trace-out", default=None,
                    help="write sampled request spans as Chrome "
                         "trace-event JSON to this path after the "
                         "replay (load in Perfetto / chrome://tracing; "
                         "gateway mode)")
    ap.add_argument("--trace-sample", type=float, default=None,
                    help="head-sampling rate for request tracing in "
                         "[0,1] (gateway mode; default 1.0 when "
                         "--trace-out/--flight-recorder is given, else "
                         "tracing stays off)")
    ap.add_argument("--flight-recorder", default=None, dest="flight_dir",
                    metavar="DIR",
                    help="keep a ring of recent request traces and dump "
                         "them with a fleet snapshot as JSONL under DIR "
                         "on each anomaly (SLO drop, OOM give-up, "
                         "migration requeue; gateway mode)")
    args = ap.parse_args(argv)

    if args.tcmalloc:
        # returns only when tcmalloc is already active or unavailable
        maybe_reexec_tcmalloc(sys.argv[1:] if argv is None else argv)

    if not args.gateway:
        # HL007 sweep: gateway-only flags silently did nothing without
        # --gateway; reject the combos instead (parser.error exits 2)
        gateway_only = [("--trace-file", args.trace_file is not None),
                        ("--round-trip", args.round_trip),
                        ("--target-rps", args.target_rps is not None),
                        ("--max-minutes", args.max_minutes is not None),
                        ("--slo-timeout", args.slo_timeout is not None),
                        ("--tenant-rate", args.tenant_rate is not None),
                        ("--attribute", args.attribute),
                        ("--trace-out", args.trace_out is not None),
                        ("--trace-sample", args.trace_sample is not None),
                        ("--flight-recorder", args.flight_dir is not None)]
        used = [flag for flag, on in gateway_only if on]
        if used:
            ap.error(f"{', '.join(used)} require(s) --gateway "
                     f"(open-loop trace replay mode)")

    if args.round_trip:
        # the validation loop owns its own tracer (--attribute); the raw
        # span-export flags only make sense on a plain gateway replay
        trace_flags = [("--trace-out", args.trace_out is not None),
                       ("--trace-sample", args.trace_sample is not None),
                       ("--flight-recorder", args.flight_dir is not None)]
        used = [flag for flag, on in trace_flags if on]
        if used:
            ap.error(f"{', '.join(used)} cannot be combined with "
                     f"--round-trip (use --attribute for phase "
                     f"attribution of the validation deltas)")
    elif args.attribute:
        ap.error("--attribute requires --round-trip (it attributes the "
                 "live-vs-sim validation deltas)")

    if args.gateway:
        return run_gateway(args)

    target = build_target(args)
    if isinstance(target, (HydraCluster, HydraPlatform)):
        platform = target
        # eager: place + AOT-compile at registration so t_reg measures the
        # real install cost and no request pays a cold start
        register = lambda fid, spec, tenant: platform.register_function(
            fid, spec, tenant=tenant, eager=True)
        runtime_for = platform.runtime_for
    else:
        platform, rt = None, target
        register = rt.register_function
        runtime_for = lambda fid: rt

    archs = args.archs.split(",")
    rng = np.random.default_rng(0)

    # one set of weights per arch; every tenant of an arch shares compiled
    # executables (code-cache sharing) but registers its own function
    t0 = time.perf_counter()
    fids = []
    for t in range(args.tenants):
        arch = archs[t % len(archs)]
        cfg = get_config(arch).reduced()
        spec = LMSpec(cfg=cfg, params=make_params(cfg, seed=t),
                      max_seq=args.max_seq, slots=args.slots)
        fid = f"tenant{t}/{arch}"
        register(fid, spec, tenant=f"tenant{t}")
        fids.append(fid)
    t_reg = time.perf_counter() - t0

    batchers = {fid: ContinuousBatcher(runtime_for(fid), fid)
                for fid in fids}
    exe_stats = (platform or batchers[fids[0]].rt).exe_cache.stats()
    print(f"[serve] registered {len(fids)} functions in {t_reg:.1f}s "
          f"(exe cache: {exe_stats})")

    futs = []
    t0 = time.perf_counter()
    for i in range(args.requests):
        fid = fids[int(rng.integers(len(fids)))]
        prompt = rng.integers(2, 100, args.prompt_len).tolist()
        if isinstance(platform, HydraCluster):
            # batchers talk to runtimes directly; tell the cluster about
            # the arrival so adaptive pool sizing sees the load
            platform.observe_arrival(fid)
        futs.append((time.perf_counter(),
                     batchers[fid].submit(prompt, args.max_new)))
        # interleave stepping: every submit, run a couple of ticks on all
        for b in batchers.values():
            if b.active or b.pending:
                b.step()
    # drain
    for b in batchers.values():
        b.run_until_done()
    toks = sum(len(f.result()) for _, f in futs)
    dt = time.perf_counter() - t0
    for b in batchers.values():
        b.close()

    print(f"[serve] {args.requests} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s)")
    if isinstance(platform, HydraCluster):
        s = platform.stats()
        for i, ns in enumerate(s["nodes"]):
            print(f"[serve] node{i}: {ns['runtimes_active']} active, "
                  f"{ns['runtimes_pooled']} pooled (target "
                  f"{ns['pool_target']}), committed "
                  f"{ns['committed_bytes']/2**20:.1f} MB")
        print(f"[serve] cluster placement: {platform.placement()}")
        print(f"[serve] cluster metrics: {s['metrics']['counters']}")
        print(f"[serve] exe cache: {s['exe_cache']}")
        platform.shutdown()
    elif platform is not None:
        s = platform.stats()
        print(f"[serve] platform: {s['runtimes_active']} active runtimes, "
              f"{s['runtimes_pooled']} pooled, placement {platform.placement()}")
        print(f"[serve] platform metrics: {s['metrics']['counters']}")
        print(f"[serve] exe cache: {s['exe_cache']}")
        print(f"[serve] budget used {s['budget_used']/2**20:.0f} MB")
        platform.shutdown()
    else:
        s = rt.stats()
        print(f"[serve] arena stats: {rt.arena_pool.stats()}")
        print(f"[serve] exe cache: {rt.exe_cache.stats()}")
        print(f"[serve] budget used {s['budget_used']/2**20:.0f} MB "
              f"(peak {s['budget_peak']/2**20:.0f} MB)")
        rt.shutdown()
    if args.calibration:
        # dedupe by identity: colocated fids share a runtime, and a
        # duplicated runtime would bias the averaged costs toward it
        rts = list({id(b.rt): b.rt for b in batchers.values()}.values())
        emit_calibration(args.calibration, platform, rts)
    return s


def run_gateway(args) -> dict:
    """Open-loop wall-clock trace replay through ``repro.gateway``
    against the stack selected by --nodes/--pool. Prints the live
    result in the simulator's SimResult summary schema and returns it.
    ``--round-trip`` instead runs the full gateway -> calibration -> sim
    validation loop and prints its delta report."""
    import json

    from repro.core.sim import SimParams
    from repro.gateway import ReplayConfig, load_trace, replay_trace

    trace = load_trace(args.trace_file, target_rps=args.target_rps,
                       max_minutes=args.max_minutes, seed=args.seed)
    d = trace.describe()
    print(f"[gateway] trace: {d['invocations']} invocations, "
          f"{d['functions']} fns, {d['tenants']} tenants over "
          f"{d['duration_s']:.0f}s trace time "
          f"(~{d['duration_s'] / args.compress:.1f}s wall at "
          f"{args.compress:g}x)")

    if args.round_trip:
        from repro.gateway import format_report, run_validation
        report = run_validation(trace, compress=args.compress,
                                pool_size=max(args.pool, 1),
                                mem_scale=args.mem_scale,
                                n_workers=args.gw_workers,
                                round_trip=True,
                                attribute=args.attribute)
        print(format_report(report))
        if args.calibration and "calibration" in report:
            from repro.core.calibrate import write_calibration_doc
            write_calibration_doc(args.calibration, report["calibration"])
            print(f"[gateway] wrote calibration {args.calibration}")
        if not report["ok"]:
            # same contract as repro.gateway.validate: a failed gate is
            # a non-zero exit, not a printed FAIL line with exit 0
            raise SystemExit(1)
        return report

    # trace-time TTL semantics must follow the replay clock: the sim's
    # isolate keep-alive, however fast the trace replays (same mapping
    # as gateway/validate.py, so both entry points stay comparable)
    target = build_target(
        args, arena_ttl_s=SimParams().isolate_ttl_s / args.compress)

    tracer = None
    if (args.trace_out is not None or args.trace_sample is not None
            or args.flight_dir is not None):
        from repro.core.tracing import FlightRecorder, Tracer
        flight = FlightRecorder(args.flight_dir) \
            if args.flight_dir is not None else None
        rate = 1.0 if args.trace_sample is None else args.trace_sample
        tracer = Tracer(rate, seed=args.seed, flight=flight)

    cfg = ReplayConfig(compress=args.compress, mem_scale=args.mem_scale,
                       n_workers=args.gw_workers,
                       queue_depth=args.queue_depth,
                       slo_timeout_s=args.slo_timeout,
                       tenant_rate=args.tenant_rate)
    try:
        res, extras = replay_trace(trace, target, cfg, tracer=tracer)
    finally:
        target.shutdown()

    summary = res.summary()
    served = summary["requests"]
    print(f"[gateway] served {served}/{extras['submitted']} requests in "
          f"{extras['wall_s']:.1f}s wall ({extras['registered']} functions "
          f"registered, {extras['late_arrivals']} late submits, "
          f"max lag {extras['max_lag_s'] * 1e3:.0f}ms)")
    print(f"[gateway] drops: {extras['drops']} retries: "
          f"{extras['retries']} autoscaler resizes: "
          f"{extras['autoscaler_resizes']}")
    if "balancer" in extras:
        b = extras["balancer"]
        print(f"[gateway] balancer: armed={b['armed']} "
              f"rebalances={b['rebalances']} moves={b['moves']} "
              f"migrations={b['migrations']} "
              f"transfer={b['transfer_bytes'] / 2**20:.1f}MB/"
              f"{b['transfer_s']:.3f}s")
    if extras["errors"]:
        print(f"[gateway] errors (sample): {extras['errors'][:3]}")
    if tracer is not None:
        from repro.core.tracing import export_chrome
        ts = tracer.summary()
        print(f"[gateway] tracing: sampled {ts['sampled']}/"
              f"{ts['requests']} requests, "
              f"{sum(ts['anomalies'].values())} anomalies")
        if args.trace_out is not None:
            doc = export_chrome(tracer, args.trace_out,
                                meta={"trace_file": args.trace_file,
                                      "compress": args.compress})
            print(f"[gateway] wrote {len(doc['traceEvents'])} trace "
                  f"events to {args.trace_out} (load in Perfetto or "
                  f"chrome://tracing)")
        if args.flight_dir is not None and "flight" in ts:
            print(f"[gateway] flight recorder: {ts['flight']['dumps']} "
                  f"dump(s) under {args.flight_dir}")
    if args.calibration:
        from repro.core.calibrate import (calibration_from_replay,
                                          write_calibration_doc)
        try:
            write_calibration_doc(args.calibration,
                                  calibration_from_replay(res, extras))
            print(f"[gateway] wrote calibration {args.calibration}")
        except ValueError as e:
            # nothing measurable this replay (e.g. every request dropped
            # at the door): report it, don't crash the summary output
            print(f"[gateway] no calibration written: {e}")
    print(json.dumps(summary, indent=1, sort_keys=True, default=str))
    return summary


def emit_calibration(path, platform, runtimes) -> dict:
    """Map live serving metrics onto the simulator's calibratable
    ``SimParams`` fields and write a hydra-calibration/v1 JSON. Only
    costs this run actually measured are emitted; the simulator keeps
    its paper defaults for the rest."""
    from repro.core.calibrate import write_calibration

    def mean_of(hists, name):
        vals = [h[name].mean for h in hists
                if name in h and h[name].count > 0]
        return float(np.mean(vals)) if vals else None

    plat_hists = []
    if platform is not None:
        plat_hists.append(platform.metrics.hists)
        # a cluster records boot/restore timings on each NODE's platform
        # metrics, not on the cluster-level metrics object
        for node in getattr(platform, "nodes", []):
            plat_hists.append(node.platform.metrics.hists)
    rt_hists = [rt.metrics.hists for rt in runtimes]
    measured = {}
    # arena.alloc_s is NOT mapped onto isolate_cold_s: a short serve run
    # averages the first allocation's one-time jnp JIT into that
    # histogram, inflating the per-event cost 10-100x — bench_startup
    # measures the steady-state cold alloc instead
    for field, value in (
            ("hydra_runtime_cold_s", mean_of(plat_hists, "runtime_boot_s")),
            ("fn_register_s", mean_of(rt_hists, "register_s")),
            ("snapshot_restore_s", mean_of(plat_hists, "restore_s"))):
        if value is not None:
            measured[field] = value
    if not measured:
        print(f"[serve] no measurable costs this run; {path} not written")
        return {}
    doc = write_calibration(path, measured,
                            meta={"source": "serve"})
    print(f"[serve] wrote calibration {path}: {sorted(doc['measured'])}")
    return doc


if __name__ == "__main__":
    main()
