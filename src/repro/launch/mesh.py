"""Production mesh definitions (functions, never module-level constants —
importing this module must not touch jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n // model) or 1
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto, jax.sharding.AxisType.Auto))


# TPU v5e-like hardware model (per chip) for the roofline analysis
PEAK_BF16_FLOPS = 197e12     # FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (we assume 1 link per path)
