"""Production mesh definitions (functions, never module-level constants —
importing this module must not touch jax device state)."""
from __future__ import annotations

import jax

# jax.sharding.AxisType (and make_mesh's axis_types kwarg) only exist in
# newer JAX releases; the pinned 0.4.x has neither. All axes default to
# Auto there anyway, so omitting the kwarg is semantically identical.
AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the kwarg exists."""
    if AXIS_TYPE is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(AXIS_TYPE.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def make_abstract_mesh(axis_shapes, axis_names):
    """``jax.sharding.AbstractMesh`` across the signature change: newer JAX
    takes ``(shape, names)``; 0.4.x takes one ``((name, size), ...)`` tuple."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_shapes),
                                         tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_shapes)))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n // model) or 1
    return make_mesh((data, model), ("data", "model"))


# TPU v5e-like hardware model (per chip) for the roofline analysis
PEAK_BF16_FLOPS = 197e12     # FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (we assume 1 link per path)
