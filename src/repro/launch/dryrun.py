import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. derives parameter/cache/input shardings from the sharding policy,
  3. ``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` — no allocation,
  4. records memory_analysis + cost_analysis + the collective schedule,
  5. emits the roofline terms (benchmarks/roofline.py) to a JSON file.

Train cells lower TWO programs: one gradient-accumulation microbatch
(fwd+bwd, scaled x n_micro in the roofline) and the optimizer apply step —
scan bodies are costed once by XLA cost analysis, so the dry-run lowers with
``unroll=True`` for exact counts.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, applicable_shapes, get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (default_rules, named_sharding_tree,
                                   use_rules)
from repro.models.programs import ModelProgram
from repro.optim import AdamW, constant


def n_micro_for(cfg, shape) -> int:
    """Gradient-accumulation depth: keep per-device microbatch ~1-4 seqs."""
    if shape.kind != "train":
        return 1
    if cfg.d_model >= 4096:
        return 16
    if cfg.d_model >= 2048:
        return 4
    return 1


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _batch_sharding(mesh, specs_tree):
    ba = batch_axes(mesh)

    def leaf(s):
        if len(s.shape) == 0:
            return NamedSharding(mesh, P())
        b = s.shape[0]
        n = 1
        for a in ba:
            n *= mesh.shape[a]
        ax = ba if (b % n == 0 and b >= n) else None
        return NamedSharding(mesh, P(ax, *([None] * (len(s.shape) - 1))))
    return jax.tree.map(leaf, specs_tree)


def _cache_sharding(mesh, cache_specs, rules):
    """KV caches follow the same logical rules the model constraints use:
    batch on (pod,data); seq on rules.kv_seq; heads on rules.kv_heads."""
    ba = tuple(a for a in rules.batch if a)
    nb = 1
    for a in ba:
        nb *= mesh.shape[a]

    def _axes_size(ax):
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n

    def leaf_path(path, s):
        name = str(path[-1].key) if path else ""
        if name == "length":
            return NamedSharding(mesh, P())
        # stacked caches: (L, B, ...) — shard batch if divisible
        dims = [None] * len(s.shape)
        if (len(s.shape) >= 2 and ba and s.shape[1] % nb == 0
                and s.shape[1] >= nb):
            dims[1] = ba
        if name in ("k", "v") and len(s.shape) == 5:
            if rules.kv_seq and s.shape[2] % _axes_size(rules.kv_seq) == 0:
                dims[2] = rules.kv_seq
            if rules.kv_heads and s.shape[3] % _axes_size(rules.kv_heads) == 0:
                dims[3] = rules.kv_heads
        return NamedSharding(mesh, P(*dims))
    return jax.tree_util.tree_map_with_path(leaf_path, cache_specs)


def _abstract_params(prog: ModelProgram, dtype=None):
    params = jax.eval_shape(lambda: prog.init(jax.random.PRNGKey(0)))
    if dtype is not None:
        params = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, dtype if s.dtype == jnp.float32 else s.dtype),
            params)
    return params


def model_flops(cfg, shape) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: 1 token/seq


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             out_dir: str = "experiments/dryrun", verbose: bool = True,
             rules_override=None, tag: str = "", unroll=None,
             n_micro_override=None, cast_bf16: bool = False,
             grads_bf16: bool = False, remat_dots: bool = False,
             ce_onehot: bool = False) -> dict:
    from repro.launch.roofline import analyze

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    if unroll is None:
        # single-pod cells power the roofline table and need exact
        # cost_analysis (scan bodies are costed once); the multi-pod pass
        # only proves the pod axis shards/compiles — scan keeps HLO small.
        unroll = not multi_pod
    prog = ModelProgram(cfg, remat=(shape.kind == "train"), unroll=unroll,
                        ce_mode="onehot" if ce_onehot else "gather")

    fsdp = shape.kind == "train" or cfg.serve_param_sharding == "fsdp"
    kv_seq = shape_name == "long_500k"
    rules = rules_override or default_rules(mesh, fsdp=fsdp, kv_seq=kv_seq)
    rules = dataclasses.replace(rules, mesh=mesh)
    if rules_override is None and shape.kind != "train":
        # KV-cache layout: shard heads over model when GQA heads divide the
        # TP degree; otherwise shard the cache SEQUENCE over model
        # (flash-decode style) — replicated caches do not fit HBM for
        # kv%16 != 0 archs at these shapes.
        tp = mesh.shape.get("model", 1)
        kv_heads = "model" if (cfg.n_kv_heads and cfg.n_kv_heads % tp == 0) \
            else None
        nd = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                nd *= mesh.shape[a]
        cache_gb_per_shard = prog.cache_bytes(
            shape.global_batch, shape.seq_len) / max(nd, 1) / 2**30
        # replicated-over-model caches are FINE when small (and required
        # for windowed local reads); shard S over model only to fit HBM
        need_seq = kv_heads is None and cache_gb_per_shard > 4.0
        seq_axes = tuple((["data"] if kv_seq else [])
                         + (["model"] if need_seq else []))
        rules = dataclasses.replace(
            rules, kv_heads=kv_heads,
            kv_seq=(seq_axes if seq_axes else None))

    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "n_devices": n_dev,
        "roofline_exact": bool(unroll),
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "model_flops": model_flops(cfg, shape),
        "tag": tag,
    }
    t0 = time.perf_counter()
    with use_rules(rules):
        if shape.kind == "train":
            if remat_dots:
                prog.remat = "dots"
            record.update(_run_train(prog, cfg, shape, mesh, rules,
                                     n_micro_override, cast_bf16,
                                     grads_bf16))
        else:
            record.update(_run_serve(prog, cfg, shape, mesh, rules, kv_seq))
    record["compile_s"] = time.perf_counter() - t0

    r = record["roofline"]
    total_hlo_flops = r["flops_per_device"] * n_dev
    record["useful_flops_frac"] = (record["model_flops"] / total_hlo_flops
                                   if total_hlo_flops else 0.0)
    record["roofline_frac"] = (
        (record["model_flops"] / n_dev / 197e12) / r["t_bound"]
        if r["t_bound"] else 0.0)

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        sfx = f"__{tag}" if tag else ""
        path = os.path.join(
            out_dir, f"{record['mesh']}__{arch}__{shape_name}{sfx}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=1, default=str)
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {record['mesh']}: "
              f"bottleneck={r['bottleneck']} "
              f"t=(c {r['t_compute_s']:.4f}, m {r['t_memory_s']:.4f}, "
              f"n {r['t_collective_s']:.4f})s "
              f"useful={record['useful_flops_frac']:.2f} "
              f"roofline={record['roofline_frac']:.2f} "
              f"compile={record['compile_s']:.0f}s", flush=True)
    return record


def _mem_stats(compiled) -> dict:
    from repro.launch.roofline import cpu_artifact_correction
    ma = compiled.memory_analysis()
    out = {k: getattr(ma, k) for k in
           ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")}
    corr = cpu_artifact_correction(compiled.as_text())
    # temp buffers created only by CPU bf16-legalization converts/copies
    out["temp_corrected_bytes"] = max(
        0, out["temp_size_in_bytes"] - int(corr["temp_bytes"]))
    return out


def _run_train(prog, cfg, shape, mesh, rules, n_micro_override=None,
               cast_bf16: bool = False, grads_bf16: bool = False) -> dict:
    from repro.launch.roofline import analyze
    n_micro = n_micro_override or n_micro_for(cfg, shape)
    micro_b = shape.global_batch // n_micro
    micro_shape = dataclasses.replace(shape, global_batch=micro_b)

    params_abs = _abstract_params(prog)                 # fp32 masters
    pspecs = named_sharding_tree(params_abs, rules, cfg)
    batch_abs = prog.input_specs(micro_shape)
    bspecs = _batch_sharding(mesh, batch_abs)

    def micro_step(params, batch):
        def cast(p):
            if not cast_bf16:
                return p
            # cast fp32 masters to bf16 while still SHARDED, so FSDP
            # all-gathers move bf16 (half the wire bytes)
            return jax.tree.map(
                lambda x: x.astype(jnp.bfloat16)
                if x.dtype == jnp.float32 else x, p)

        if grads_bf16:
            # differentiate wrt the bf16 copies: gradient reduce-scatters
            # move bf16 on the wire; fp32 accumulation happens outside
            pb = cast(params)
            (loss, _), grads = jax.value_and_grad(
                prog.loss_fn, has_aux=True)(pb, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            def lossf(p, b):
                return prog.loss_fn(cast(p), b)
            (loss, _), grads = jax.value_and_grad(
                lossf, has_aux=True)(params, batch)
        return loss, grads

    lowered = jax.jit(micro_step, in_shardings=(pspecs, bspecs)).lower(
        params_abs, batch_abs)
    compiled = lowered.compile()
    micro_mem = _mem_stats(compiled)
    roof_micro = analyze(compiled, mesh.size, scale=n_micro)

    # optimizer apply (runs once per step)
    opt = AdamW(lr=constant(3e-4))
    opt_abs = jax.eval_shape(opt.init, params_abs)
    ospecs = {"m": pspecs, "v": pspecs,
              "step": NamedSharding(mesh, P())}

    def apply_step(grads, opt_state, params):
        return opt.update(grads, opt_state, params)

    lowered_a = jax.jit(apply_step,
                        in_shardings=(pspecs, ospecs, pspecs)).lower(
        params_abs, opt_abs, params_abs)
    compiled_a = lowered_a.compile()
    apply_mem = _mem_stats(compiled_a)
    roof_apply = analyze(compiled_a, mesh.size)

    combined = dataclasses.replace(
        roof_micro,
        flops_per_device=roof_micro.flops_per_device
        + roof_apply.flops_per_device,
        bytes_per_device=roof_micro.bytes_per_device
        + roof_apply.bytes_per_device,
        wire_bytes_per_device=roof_micro.wire_bytes_per_device
        + roof_apply.wire_bytes_per_device,
    )
    summary = combined.summary()
    summary["t_bound"] = combined.t_bound
    return {
        "n_micro": n_micro,
        "memory": {"micro_step": micro_mem, "apply_step": apply_mem},
        "hbm_fit_bytes": micro_mem["argument_size_in_bytes"]
        + micro_mem["temp_corrected_bytes"]
        + apply_mem["argument_size_in_bytes"]
        - _tree_sz(params_abs, mesh),    # params counted twice
        "roofline": summary,
    }


def _tree_sz(tree, mesh) -> int:
    return sum(s.size * s.dtype.itemsize for s in jax.tree.leaves(tree)) \
        // mesh.size


def _run_serve(prog, cfg, shape, mesh, rules, kv_seq=None) -> dict:
    from repro.launch.roofline import analyze
    dt = jnp.dtype(cfg.dtype)
    params_abs = _abstract_params(prog, dtype=dt)       # bf16 serving weights
    pspecs = named_sharding_tree(params_abs, rules, cfg)
    batch_abs = prog.input_specs(shape)
    bspecs = _batch_sharding(mesh, batch_abs)

    if shape.kind == "prefill":
        lowered = jax.jit(prog.prefill,
                          in_shardings=(pspecs, bspecs)).lower(
            params_abs, batch_abs)
    else:
        cache_abs = prog.cache_specs(shape.global_batch, shape.seq_len)
        cspecs = _cache_sharding(mesh, cache_abs, rules)
        lowered = jax.jit(
            prog.decode_step, donate_argnums=(1,),
            in_shardings=(pspecs, cspecs, bspecs)).lower(
            params_abs, cache_abs, batch_abs)
    compiled = lowered.compile()
    mem = _mem_stats(compiled)
    roof = analyze(compiled, mesh.size)
    summary = roof.summary()
    summary["t_bound"] = roof.t_bound
    return {
        "memory": {"step": mem},
        "hbm_fit_bytes": mem["argument_size_in_bytes"]
        + mem["temp_corrected_bytes"]
        + mem["output_size_in_bytes"] - mem["alias_size_in_bytes"],
        "roofline": summary,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="model architecture to dry-run (see --all)")
    ap.add_argument("--shape", default=None,
                    help="mesh shape name to dry-run against")
    ap.add_argument("--all", action="store_true",
                    help="dry-run every arch x applicable mesh shape")
    ap.add_argument("--multi-pod", action="store_true",
                    help="include multi-pod mesh variants")
    ap.add_argument("--both-meshes", action="store_true",
                    help="emit both 1D and 2D mesh layouts per cell")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose output JSON already exists")
    ap.add_argument("--out", default="experiments/dryrun",
                    help="output directory for per-cell JSON reports")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in list_archs():
            for shape in applicable_shapes(get_config(arch)):
                cells.append((arch, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for multi_pod in meshes:
        for arch, shape in cells:
            mesh_tag = "2x16x16" if multi_pod else "16x16"
            path = os.path.join(args.out, f"{mesh_tag}__{arch}__{shape}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[dryrun] skip {arch} x {shape} x {mesh_tag}")
                continue
            try:
                run_cell(arch, shape, multi_pod=multi_pod, out_dir=args.out)
            except Exception as e:
                failures.append((arch, shape, mesh_tag, repr(e)))
                print(f"[dryrun] FAIL {arch} x {shape} x {mesh_tag}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUN CELLS PASSED")


if __name__ == "__main__":
    main()
