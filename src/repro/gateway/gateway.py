"""The serving front door: routing, bounded per-tenant queues, admission
control, SLO timeouts, and worker threads that drive the real stack.

Request path (the live analog of the sim engine's arrival handling)::

    LoadGenerator (open loop, wall clock)
        └─> Gateway.submit(inv)
              ├─ routing: trace fid -> registered function name
              ├─ admission: per-tenant TokenBucket (cgroup CPU-share
              │  analog, reused from core/scheduler.py) — over-rate
              │  tenants are throttled, not queued
              ├─ bounded per-tenant queue (depth = queue_depth;
              │  overflow is rejected, protecting every other tenant)
              └─ notify workers
    worker threads (n_workers)
        ├─ round-robin across tenants (no tenant starves another)
        ├─ SLO gate: a request that waited past slo_timeout_s (in
        │  trace seconds) is dropped, not served late
        ├─ adapter.invoke(...)  — the REAL path: registry lookup,
        │  placement/pool claim, arena acquire, compiled executable
        └─ sleep(duration / compress) — the emulated function body

All times cross between two clocks: *wall* seconds (what
``time.monotonic`` measures while the replay runs) and *trace* seconds
(the timeline the trace was recorded on). ``compress`` trace seconds
pass per wall second, so a trace minute replays in one second at
``compress=60``. Latencies are recorded in trace seconds
(``wall * compress``) so live results are directly comparable with
``core/sim`` output — with the caveat (see docs/benchmarks.md) that
real startup costs do not compress, so they appear amplified by
``compress`` in trace-time units.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Optional

from repro.core.cluster import AdaptivePoolPolicy, ArrivalRateEstimator
from repro.core.errors import FunctionNotRegisteredError, HydraOOMError
from repro.core.scheduler import TokenBucket
from repro.core.tracing import NULL_TRACE, trace_now


@dataclass
class GatewayParams:
    n_workers: int = 16
    queue_depth: int = 256             # per-tenant bound
    slo_timeout_s: Optional[float] = None   # trace seconds; None disables
    max_wait_s: float = 30.0           # trace seconds before an OOM-retried
                                       # request gives up (sim: max_wait_s)
    retry_backoff_s: float = 0.02      # wall seconds between OOM retries
    tenant_rate: Optional[float] = None     # trace req/s; None disables
    tenant_burst: float = 16.0         # token bucket burst (requests)
    compress: float = 60.0             # trace seconds per wall second


@dataclass
class _Request:
    inv: object                        # the trace Invocation
    name: str                          # registered function name
    sched_wall: float                  # intended (open-loop) arrival
    retries: int = 0
    ctx: object = NULL_TRACE           # RequestTrace when head-sampled
    t_enq: float = 0.0                 # trace_now() at (re-)enqueue


class Gateway:
    """Multi-threaded front door over an adapted serving stack."""

    def __init__(self, adapter, workload, params: GatewayParams,
                 recorder, autoscaler: Optional["Autoscaler"] = None,
                 tracer=None):
        self.adapter = adapter
        self.workload = workload
        self.params = params
        self.recorder = recorder
        self.autoscaler = autoscaler
        # core.tracing.Tracer or None; None keeps the request path on the
        # zero-cost NULL_TRACE everywhere (the measured disabled path)
        self.tracer = tracer
        self._queues: dict[str, deque] = {}
        self._rr: list[str] = []       # tenant round-robin order
        self._rr_next = 0
        self._cv = threading.Condition()
        self._buckets: dict[str, TokenBucket] = {}
        self._in_flight = 0
        self._stop = False
        self._workers: list[threading.Thread] = []

    # ------------------------------------------------------------------
    def start(self) -> None:
        for i in range(self.params.n_workers):
            t = threading.Thread(target=self._worker_loop, daemon=True,
                                 name=f"gateway-w{i}")
            t.start()
            self._workers.append(t)

    def submit(self, inv, sched_wall: Optional[float] = None) -> bool:
        """Admit one trace invocation. Returns False when the request is
        dropped at the door (unknown function, throttled tenant, or a
        full queue) — every False is recorded with its reason."""
        now = time.monotonic()
        sched_wall = now if sched_wall is None else sched_wall
        name = self.workload.name_for(inv)
        if name is None:
            self.recorder.drop("unknown")
            return False
        tenant = self.workload.tenant_name(inv.tenant)
        # head-sampling decision is made here, once per admitted request;
        # an unsampled request carries the shared no-op NULL_TRACE
        ctx = (self.tracer.start_request(name, tenant)
               if self.tracer is not None else NULL_TRACE)
        # platform adaptive pool sizing sees every arrival, accepted or
        # not: load shed at the door is still load the pool should
        # absorb (cluster targets feed their own per-node estimators
        # inside HydraCluster.invoke instead)
        if self.autoscaler is not None:
            self.autoscaler.observe(now)
        p = self.params
        if p.tenant_rate is not None:
            bucket = self._buckets.get(tenant)
            if bucket is None:
                # trace req/s -> wall req/s at the compression factor
                bucket = self._buckets.setdefault(
                    tenant, TokenBucket(rate=p.tenant_rate * p.compress,
                                        burst=p.tenant_burst))
            if not bucket.try_take():
                self.recorder.drop("throttled")
                ctx.finish("throttled")
                return False
        t_enq = 0.0
        if ctx.sampled:
            # admission covers routing + token bucket up to the enqueue;
            # queue_wait starts from the SAME timestamp so the two spans
            # cannot overlap (conservation invariant)
            t_enq = trace_now()
            ctx.add_span("admission", ctx.t0, t_enq)
        with self._cv:
            q = self._queues.get(tenant)
            if q is None:
                q = self._queues[tenant] = deque()
                self._rr.append(tenant)
            if len(q) >= p.queue_depth:
                self.recorder.drop("rejected")
                ctx.finish("rejected")
                return False
            q.append(_Request(inv=inv, name=name, sched_wall=sched_wall,
                              ctx=ctx, t_enq=t_enq))
            self._cv.notify()
        return True

    # ------------------------------------------------------------------
    def _next_request(self) -> Optional[_Request]:
        """Round-robin pop across tenants; caller holds the lock."""
        n = len(self._rr)
        for off in range(n):
            tenant = self._rr[(self._rr_next + off) % n]
            q = self._queues[tenant]
            if q:
                self._rr_next = (self._rr_next + off + 1) % n
                return q.popleft()
        return None

    def _worker_loop(self) -> None:
        while True:
            with self._cv:
                req = self._next_request()
                while req is None and not self._stop:
                    self._cv.wait(timeout=0.1)
                    req = self._next_request()
                if req is None:        # stopping and drained
                    return
                self._in_flight += 1
            try:
                self._serve(req)
            finally:
                with self._cv:
                    self._in_flight -= 1
                    self._cv.notify_all()

    def _anomaly(self, kind: str, req: _Request) -> None:
        """Count one anomaly and trigger the flight-recorder dump (the
        last-N sampled traces + a metrics snapshot, JSONL on disk)."""
        if self.tracer is not None:
            self.tracer.anomaly(kind, fid=req.name, ctx=req.ctx)

    def _serve(self, req: _Request) -> None:
        p = self.params
        now = time.monotonic()
        ctx = req.ctx
        if ctx.sampled:
            ctx.add_span("queue_wait", req.t_enq, trace_now())
        waited_trace = (now - req.sched_wall) * p.compress
        if p.slo_timeout_s is not None and waited_trace > p.slo_timeout_s:
            self.recorder.drop("slo_timeout")
            self._anomaly("slo_violation", req)
            ctx.finish("slo_timeout")
            return
        inv = req.inv
        try:
            self.adapter.invoke(req.name, self.workload.args_for(inv),
                                ctx=ctx)
        except (HydraOOMError, FunctionNotRegisteredError) as e:
            # HydraOOM: the fleet is momentarily full (arena budgets
            # saturated by the burst) — back off and requeue, like the
            # sim engine's retry path, until max_wait/SLO expires.
            # FunctionNotRegistered can only be transient here (submit
            # filters unknown fids): the balancer is migrating the
            # function between nodes and the request raced the
            # export->import window — requeue it the same way instead
            # of failing a known function mid-migration.
            if waited_trace > p.max_wait_s:
                self.recorder.drop("gave_up")
                self._anomaly("oom_give_up", req)
                ctx.finish("gave_up")
                return
            req.retries += 1
            self.recorder.retried()
            if isinstance(e, FunctionNotRegisteredError):
                self._anomaly("migration_requeue", req)
            # hydralint: disable=HL002 — deliberate OOM retry backoff on a
            # worker thread, mirrors the sim engine's retry_backoff_s
            time.sleep(p.retry_backoff_s)
            tenant = self.workload.tenant_name(inv.tenant)
            with self._cv:
                if not self._stop:
                    if ctx.sampled:
                        # a fresh queue_wait leg starts at the requeue
                        # (the backoff above stays unattributed)
                        req.t_enq = trace_now()
                    self._queues[tenant].appendleft(req)
                    self._cv.notify()
                else:
                    self.recorder.error(e)
                    ctx.finish("error")
            return
        except Exception as e:
            self.recorder.error(e)
            ctx.finish("error")
            return
        # emulated function body: the trace's duration at compressed
        # wall time (the invoke above covered only the platform path)
        if inv.duration_s > 0:
            with ctx.span("body"):
                # hydralint: disable=HL002 — the emulated function body IS
                # the workload: the trace duration at compressed wall time
                time.sleep(inv.duration_s / p.compress)
        latency_trace = (time.monotonic() - req.sched_wall) * p.compress
        self.recorder.record(latency_trace, inv.duration_s)
        ctx.finish("ok")

    # ------------------------------------------------------------------
    def drain(self, timeout_s: float = 60.0) -> bool:
        """Block until every queued + in-flight request is finished (or
        the timeout passes). Returns True when fully drained."""
        deadline = time.monotonic() + timeout_s
        with self._cv:
            while (any(self._queues.values()) or self._in_flight > 0):
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return False
                self._cv.wait(timeout=min(remaining, 0.25))
        return True

    def stop(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for t in self._workers:
            t.join(timeout=5.0)

    def depth(self) -> int:
        with self._cv:
            return sum(len(q) for q in self._queues.values()) \
                + self._in_flight


# ---------------------------------------------------------------------------
class Autoscaler:
    """Background pool sizing for a single-node ``HydraPlatform``.

    The cluster stack has adaptive pools built in (per-node EWMA
    estimators fed by ``observe_arrival``); a bare platform does not —
    this thread closes that gap with the SAME policy objects
    (``ArrivalRateEstimator`` + ``AdaptivePoolPolicy``): the gateway
    feeds arrivals in wall time, and every ``interval_s`` the estimated
    rate maps to a pool target which drives ``resize_pool``. ``cover_s``
    is in *wall* seconds — runtime boots do not compress, so the pool
    must absorb the arrivals of one real boot window, however fast the
    trace is being replayed.
    """

    def __init__(self, platform, *, pool_min: int = 1, pool_max: int = 8,
                 cover_s: float = 1.0, interval_s: float = 0.25,
                 alpha: float = 0.5):
        self.platform = platform
        self.estimator = ArrivalRateEstimator(alpha=alpha)
        self.policy = AdaptivePoolPolicy(
            pool_min=pool_min, pool_max=pool_max, cover_s=cover_s,
            runtime_bytes=platform.params.runtime_budget_bytes)
        self.interval_s = interval_s
        self.resizes = 0
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def observe(self, now: Optional[float] = None) -> None:
        with self._lock:
            self.estimator.observe(time.monotonic() if now is None else now)

    def tick(self, now: Optional[float] = None) -> int:
        """One sizing decision; returns the chosen pool target."""
        now = time.monotonic() if now is None else now
        with self._lock:
            rate = self.estimator.rate(now)
        target = self.policy.target(rate)
        if target != self.platform.params.pool_size:
            self.platform.resize_pool(target)
            with self._lock:               # HL001: tick() races manual calls
                self.resizes += 1
        return target

    def start(self) -> None:
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="gateway-autoscaler")
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.tick()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)


# ---------------------------------------------------------------------------
class ClusterBalancer:
    """Burst-time rebalancing for a ``HydraCluster`` target — the cluster
    analog of the platform ``Autoscaler``.

    The cluster already sizes its per-node pools adaptively (EWMA
    estimators inside ``HydraCluster.invoke``), but nothing moves
    *functions* while a replay is running: a tenant-skewed trace packs
    one node solid (colocation) and every burst lands there while the
    other nodes idle. This thread closes that gap: every ``interval_s``
    it reads per-node **committed memory** (placement-estimate bytes, the
    same accounting ``HydraCluster._pick_node`` packs by) and the
    gateway's **queue depth** (the live burst signal), and when the
    commit spread exceeds ``imbalance`` of the per-node budget while
    requests are actually queueing, it triggers
    ``HydraCluster.rebalance()`` — snapshot-migrating the hot node's
    smallest functions onto the coldest node mid-burst.

    Migration needs the snapshot path, so the balancer only arms itself
    when the cluster has a ``snapshot_dir`` (``armed`` reports which).
    Move counts and transfer seconds are read back by the replay
    orchestrator into ``SimResult`` extras, so a live cluster replay and
    the ``hydra-cluster`` sim model diff on migration accounting too.
    """

    def __init__(self, cluster, gateway: Optional[Gateway] = None, *,
                 interval_s: float = 0.25, imbalance: float = 0.25,
                 min_queue: int = 1, max_moves: int = 4):
        self.cluster = cluster
        self.gateway = gateway
        self.interval_s = interval_s
        self.imbalance = imbalance
        self.min_queue = min_queue
        self.max_moves = max_moves
        self.armed = bool(cluster.params.snapshot_dir)
        self.ticks = 0
        self.rebalances = 0            # rebalance() calls that moved >= 1 fn
        self.moves = 0                 # functions migrated
        self.errors = 0
        # HL001: counters are written by the balancer thread and read by
        # the replay orchestrator for SimResult extras
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _spread(self) -> int:
        committed = [n.committed for n in self.cluster.nodes]
        return max(committed) - min(committed) if committed else 0

    def should_rebalance(self) -> bool:
        if not self.armed:
            return False
        if self._spread() <= self.imbalance \
                * self.cluster.params.node_memory_bytes:
            return False
        # only act while the burst is live: an imbalanced-but-idle fleet
        # is a placement-time concern, not worth paying transfer cost for
        if self.gateway is not None \
                and self.gateway.depth() < self.min_queue:
            return False
        return True

    def tick(self) -> int:
        """One balancing decision; returns functions moved this tick."""
        with self._lock:
            self.ticks += 1
        if not self.should_rebalance():
            return 0
        try:
            moved = len(self.cluster.rebalance(max_moves=self.max_moves))
        except Exception:
            # a racing eviction/shutdown must not kill the balancer for
            # the rest of the replay
            with self._lock:
                self.errors += 1
            return 0
        if moved:
            with self._lock:
                self.rebalances += 1
                self.moves += moved
        return moved

    def start(self) -> None:
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="gateway-balancer")
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.tick()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
