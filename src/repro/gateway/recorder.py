"""Live-replay recording in the simulator's result schema.

The whole point of the gateway is a closed loop with ``core/sim``: a
live replay must come back in the exact shape a simulated replay does,
so the two are diffable metric-by-metric (``gateway/validate.py``).
``Recorder.finish`` therefore returns a real
:class:`repro.core.sim.engine.SimResult` — not a look-alike — with:

  * ``latencies``/``overheads`` recorded per served request in *trace*
    seconds (wall seconds x the compression factor);
  * ``mem_samples``/``pool_mem_samples``/``runtime_count_samples``
    gathered by a background sampler thread on a fixed wall-clock grid
    (timestamps converted to trace time), using the adapters' budget +
    per-runtime-base accounting;
  * cold/warm/pool/evicted counters read from the live platform metrics
    through the adapter at finish time.

Everything the sim has no vocabulary for — drop reasons, invoke
errors, wall-clock duration — is returned separately by ``extras()``.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

from repro.core.sim.engine import SimResult


class Recorder:
    def __init__(self, adapter, *, compress: float,
                 sample_dt_s: float = 0.25):
        self.adapter = adapter
        self.compress = compress
        self.sample_dt_s = sample_dt_s
        self._lock = threading.Lock()
        self._latencies: list = []
        self._overheads: list = []
        self._drops: dict[str, int] = {}
        self._retries = 0
        self._sample_failures = 0
        self._errors: list = []
        self._mem: list = []
        self._pool: list = []
        self._counts: list = []
        self._peak_pool = 0
        # isolate counters can shrink when a drained runtime shuts down
        # (its Metrics object goes with it); keep the max observed
        self._iso_peak = (0, 0)
        self._t0: Optional[float] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- request accounting -------------------------------------------------
    def record(self, latency_trace_s: float, duration_s: float) -> None:
        with self._lock:
            self._latencies.append(latency_trace_s)
            self._overheads.append(latency_trace_s - duration_s)

    def drop(self, reason: str) -> None:
        with self._lock:
            self._drops[reason] = self._drops.get(reason, 0) + 1

    def retried(self) -> None:
        with self._lock:
            self._retries += 1

    def error(self, exc: Exception) -> None:
        with self._lock:
            if len(self._errors) < 32:       # keep a bounded sample
                self._errors.append(f"{type(exc).__name__}: {exc}")
            self._drops["error"] = self._drops.get("error", 0) + 1

    # -- fleet sampling -----------------------------------------------------
    def start(self, t0_wall: float) -> None:
        self._t0 = t0_wall
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="gateway-recorder")
        self._thread.start()

    def _sample_once(self) -> None:
        s = self.adapter.sample()
        t_trace = (time.monotonic() - self._t0) * self.compress
        iso = self.adapter._isolate_counts()
        with self._lock:
            self._mem.append((t_trace, s["mem_bytes"]))
            self._pool.append((t_trace, s["pool_bytes"]))
            self._counts.append((t_trace, s["runtimes"]))
            self._peak_pool = max(self._peak_pool, s["pool_bytes"])
            self._iso_peak = (max(self._iso_peak[0], iso[0]),
                              max(self._iso_peak[1], iso[1]))

    def _loop(self) -> None:
        failures = 0
        while not self._stop.wait(self.sample_dt_s):
            try:
                self._sample_once()
                failures = 0
            except Exception:
                # a transient race (e.g. an autoscaler resize shutting a
                # runtime down mid-sample) must not kill sampling for the
                # rest of the replay — and is NOT a request-level drop;
                # only persistent failure stops the thread
                with self._lock:
                    self._sample_failures += 1
                failures += 1
                if failures >= 5:
                    return

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        try:
            self._sample_once()               # closing sample
        except Exception:
            pass

    # -- result -------------------------------------------------------------
    def finish(self, n_nodes: int = 1) -> SimResult:
        c = self.adapter.counters()
        iso_cold = max(self._iso_peak[0], c["cold_isolate"])
        iso_warm = max(self._iso_peak[1], c["warm_isolate"])
        with self._lock:
            res = SimResult(
                model=f"live-{self.adapter.kind}",
                latencies=list(self._latencies),
                overheads=list(self._overheads),
                mem_samples=list(self._mem),
                pool_mem_samples=list(self._pool),
                runtime_count_samples=list(self._counts),
                cold_runtime_starts=c["cold_runtime"],
                cold_isolate_starts=iso_cold,
                warm_isolate_starts=iso_warm,
                evicted_runtimes=c["evicted_runtimes"],
                dropped=sum(self._drops.values()),
                pool_claims=c["pool_claims"],
                transfers=c["transfers"],
                peak_pool_mem=self._peak_pool,
                n_nodes=n_nodes,
            )
        return res

    def extras(self) -> dict:
        with self._lock:
            return {"drops": dict(self._drops),
                    "retries": self._retries,
                    "sample_failures": self._sample_failures,
                    "errors": list(self._errors)}
