"""Live-replay recording in the simulator's result schema.

The whole point of the gateway is a closed loop with ``core/sim``: a
live replay must come back in the exact shape a simulated replay does,
so the two are diffable metric-by-metric (``gateway/validate.py``).
``Recorder.finish`` therefore returns a real
:class:`repro.core.sim.engine.SimResult` — not a look-alike — with:

  * ``latencies``/``overheads`` recorded per served request in *trace*
    seconds (wall seconds x the compression factor);
  * ``mem_samples``/``pool_mem_samples``/``runtime_count_samples``
    gathered by a background sampler thread on a fixed wall-clock grid
    (timestamps converted to trace time), using the adapters' budget +
    per-runtime-base accounting;
  * cold/warm/pool/evicted counters read from the live platform metrics
    through the adapter at finish time.

Everything the sim has no vocabulary for — drop reasons, invoke
errors, wall-clock duration — is returned separately by ``extras()``.

``CalibrationProbe`` rides the same sampler: it baselines the stack's
startup-cost histograms when the replay clock starts, samples process
RSS and per-node memory on the recorder's grid, and at finish reports
replay-window wall-second means for every cost the simulator can be
calibrated with (``core.calibrate.calibration_from_replay`` turns that
payload into a ``hydra-calibration/v1`` overlay).
"""
from __future__ import annotations

import os
import threading
import time
from typing import Optional

from repro.core.sim.engine import SimResult


def _process_rss_bytes() -> Optional[int]:
    """Resident set size of this process, or None when unmeasurable.
    The getrusage fallback reports *peak* RSS (the best a non-/proc
    platform offers — a monotone upper bound, not a series); ru_maxrss
    is kilobytes everywhere except Darwin, where it is already bytes."""
    try:
        with open("/proc/self/statm") as f:
            pages = int(f.read().split()[1])
        return pages * os.sysconf("SC_PAGE_SIZE")
    except (OSError, ValueError, IndexError, AttributeError):
        pass
    try:
        import resource
        import sys
        peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        return peak if sys.platform == "darwin" else peak * 1024
    except Exception:
        return None


class CalibrationProbe:
    """Measure what one live replay can teach the simulator.

    Three measurement families, all scoped to the replay window (costs
    incurred while *building* the stack — prewarm boots, up-front
    registrations — are baselined out):

      * **startup/warm/restore costs** — window deltas of the stack's
        own timing histograms: ``runtime_boot_s`` (cold boots + pool
        re-warms), ``pool_claim_s`` (warm handovers), ``restore_s``
        (snapshot restores) on each node's platform metrics, and
        ``register_s`` (request-path code installs) plus
        ``arena.alloc_s`` (cold isolate/arena allocations) on
        per-runtime metrics. Means are in wall seconds; the calibration
        layer scales them by ``compress`` into trace time. Window
        scoping matters doubly for ``arena.alloc_s``: pre-replay
        allocations (and their one-time warmup) are baselined out, so
        the mean is the steady-state cold-acquire cost the sim's
        ``isolate_cold_s`` models.
      * **process RSS** — sampled on the recorder grid; the *marginal*
        per-runtime figure (window RSS growth over window runtime-count
        growth) is reported, and only applied to the sim's
        ``hydra_runtime_base`` when explicitly requested (see
        ``calibration_from_replay``).
      * **per-node memory** — the adapter's per-node committed-byte
        series, so a cluster replay exposes each node's footprint, not
        just the fleet sum.

    Per-runtime metrics objects die with their runtime (drained
    runtimes shut down); their in-window observations are lost, which
    under-samples but never skews the surviving means.
    """

    PLATFORM_COSTS = ("runtime_boot_s", "pool_claim_s", "restore_s")
    RUNTIME_COSTS = ("register_s", "arena.alloc_s")

    def __init__(self, adapter, *, compress: float, tracer=None):
        self.adapter = adapter
        self.compress = compress
        # optional core.tracing.Tracer: its per-phase aggregates ride in
        # the probe payload so calibration reports carry the span-level
        # decomposition next to the histogram-window costs
        self.tracer = tracer
        self._lock = threading.Lock()
        # keyed by the Metrics OBJECT (strong ref, identity hash): an
        # id()-keyed map would let a dead runtime's address be reused by
        # a new Metrics object and its stale baseline corrupt the window
        self._baseline: dict = {}       # Metrics -> {name: (count, sum)}
        self._rss0: Optional[int] = None
        self._runtimes0 = 0             # fleet runtime count at begin()
        self._rss: list = []            # (t_trace, rss_bytes)
        self._per_runtime: list = []    # rss growth / runtime growth
        self._node_peaks: list = []     # per-node committed peak

    def _hist_state(self, metrics, names) -> dict:
        out = {}
        for name in names:
            h = metrics.hists.get(name)
            if h is not None:
                out[name] = h.count_sum()     # one atomic pair
        return out

    def begin(self) -> None:
        """Snapshot histogram state at replay start; window deltas are
        measured against this.  The snapshot is taken into locals first
        so a failing adapter probe cannot leave the baseline half
        written (HL010)."""
        baseline = {}
        for m in self.adapter.platform_metrics():
            baseline[m] = self._hist_state(m, self.PLATFORM_COSTS)
        for m in self.adapter.runtime_metrics():
            baseline[m] = self._hist_state(m, self.RUNTIME_COSTS)
        rss0 = _process_rss_bytes()
        runtimes0 = self.adapter.sample().get("runtimes", 0)
        n_nodes = self.adapter.n_nodes
        with self._lock:
            self._baseline.clear()
            self._baseline.update(baseline)
            self._rss0 = rss0
            self._runtimes0 = runtimes0
            self._rss.clear()
            self._per_runtime.clear()
            self._node_peaks = [0] * n_nodes

    def sample(self, t_trace: float, fleet: dict) -> None:
        """One grid sample (called from the recorder's sampler thread
        with the fleet sample it already took — the per-node series
        rides in it, so nothing is recomputed on the hot path)."""
        rss = _process_rss_bytes()
        node_mem = fleet.get("node_mem_bytes") or self.adapter.node_mem()
        with self._lock:
            if rss is not None:
                self._rss.append((t_trace, rss))
                # marginal RSS per runtime: the replay window's RSS
                # growth over its runtime-count growth — dividing by the
                # TOTAL count would let baseline (prewarmed) runtimes
                # dilute the estimate toward zero
                grown = fleet.get("runtimes", 0) - self._runtimes0
                if self._rss0 is not None and grown > 0:
                    self._per_runtime.append(
                        max(0, rss - self._rss0) / grown)
            if len(node_mem) != len(self._node_peaks):
                self._node_peaks = [0] * len(node_mem)
            for i, m in enumerate(node_mem):
                self._node_peaks[i] = max(self._node_peaks[i], m)

    def _window_costs(self) -> dict:
        """Replay-window (count, sum) per cost name, across all live
        metrics objects; objects born during the replay have no baseline
        and count in full."""
        totals: dict = {}
        for metrics, names in (
                [(m, self.PLATFORM_COSTS)
                 for m in self.adapter.platform_metrics()]
                + [(m, self.RUNTIME_COSTS)
                   for m in self.adapter.runtime_metrics()]):
            base = self._baseline.get(metrics, {})
            for name in names:
                h = metrics.hists.get(name)
                if h is None:
                    continue
                b_count, b_sum = base.get(name, (0, 0.0))
                n_count, n_sum = h.count_sum()
                d_count = n_count - b_count
                d_sum = n_sum - b_sum
                if d_count > 0 and d_sum >= 0:
                    c, s = totals.get(name, (0, 0.0))
                    totals[name] = (c + d_count, s + d_sum)
        return totals

    def finish(self) -> dict:
        """The probe payload ``calibration_from_replay`` consumes
        (recorded under ``extras['probe']`` by ``replay_trace``)."""
        with self._lock:
            rss = list(self._rss)
            per_runtime = list(self._per_runtime)
            peaks = list(self._node_peaks)
            rss0 = self._rss0
            # HL001: _window_costs reads the _baseline snapshot that
            # begin() populates under this lock
            costs = {name: {"count": c, "sum": s, "mean": s / c}
                     for name, (c, s) in self._window_costs().items()}
        rss_vals = [b for _, b in rss]
        out = {
            "compress": self.compress,
            "wall_costs": costs,
            "rss": {
                "start_bytes": rss0,
                "peak_bytes": max(rss_vals) if rss_vals else None,
                "mean_bytes": (sum(rss_vals) / len(rss_vals)
                               if rss_vals else None),
                "per_runtime_bytes": (sum(per_runtime) / len(per_runtime)
                                      if per_runtime else None),
                "samples": len(rss),
            },
            "node_mem_peak_bytes": peaks,
        }
        if self.tracer is not None:
            # span-level wall-ms decomposition alongside the
            # histogram-window costs (consumed by validate --attribute)
            out["phases"] = self.tracer.summary()["phases"]
        return out


class Recorder:
    def __init__(self, adapter, *, compress: float,
                 sample_dt_s: float = 0.25,
                 probe: Optional[CalibrationProbe] = None,
                 tracer=None):
        self.adapter = adapter
        self.compress = compress
        self.sample_dt_s = sample_dt_s
        self.probe = probe
        self.tracer = tracer           # core.tracing.Tracer or None
        self._lock = threading.Lock()
        self._latencies: list = []
        self._overheads: list = []
        self._drops: dict[str, int] = {}
        self._retries = 0
        self._sample_failures = 0
        self._errors: list = []
        self._mem: list = []
        self._pool: list = []
        self._counts: list = []
        self._peak_pool = 0
        # isolate counters can shrink when a drained runtime shuts down
        # (its Metrics object goes with it); keep the max observed
        self._iso_peak = (0, 0)
        self._t0: Optional[float] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- request accounting -------------------------------------------------
    def record(self, latency_trace_s: float, duration_s: float) -> None:
        with self._lock:
            self._latencies.append(latency_trace_s)
            self._overheads.append(latency_trace_s - duration_s)

    def drop(self, reason: str) -> None:
        with self._lock:
            self._drops[reason] = self._drops.get(reason, 0) + 1

    def retried(self) -> None:
        with self._lock:
            self._retries += 1

    def error(self, exc: Exception) -> None:
        with self._lock:
            if len(self._errors) < 32:       # keep a bounded sample
                self._errors.append(f"{type(exc).__name__}: {exc}")
            self._drops["error"] = self._drops.get("error", 0) + 1

    # -- fleet sampling -----------------------------------------------------
    def start(self, t0_wall: float) -> None:
        self._t0 = t0_wall
        if self.probe is not None:
            self.probe.begin()
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="gateway-recorder")
        self._thread.start()

    def _sample_once(self) -> None:
        s = self.adapter.sample()
        t_trace = (time.monotonic() - self._t0) * self.compress
        iso = self.adapter._isolate_counts()
        if self.probe is not None:
            self.probe.sample(t_trace, s)
        with self._lock:
            self._mem.append((t_trace, s["mem_bytes"]))
            self._pool.append((t_trace, s["pool_bytes"]))
            self._counts.append((t_trace, s["runtimes"]))
            self._peak_pool = max(self._peak_pool, s["pool_bytes"])
            self._iso_peak = (max(self._iso_peak[0], iso[0]),
                              max(self._iso_peak[1], iso[1]))

    def _loop(self) -> None:
        failures = 0
        while not self._stop.wait(self.sample_dt_s):
            try:
                self._sample_once()
                failures = 0
            except Exception:
                # a transient race (e.g. an autoscaler resize shutting a
                # runtime down mid-sample) must not kill sampling for the
                # rest of the replay — and is NOT a request-level drop;
                # only persistent failure stops the thread
                with self._lock:
                    self._sample_failures += 1
                failures += 1
                if failures >= 5:
                    return

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        try:
            self._sample_once()               # closing sample
        except Exception:
            pass

    # -- result -------------------------------------------------------------
    def finish(self, n_nodes: Optional[int] = None) -> SimResult:
        """The live replay as a real ``SimResult``. ``n_nodes`` defaults
        to the adapter's REAL machine count — a cluster replay stamped
        as one node would read N-fold denser than the simulator's
        fleet-wide accounting of the same trace."""
        if n_nodes is None:
            n_nodes = self.adapter.n_nodes
        c = self.adapter.counters()
        with self._lock:
            # HL001: _iso_peak is maintained by the sampler thread
            iso_cold = max(self._iso_peak[0], c["cold_isolate"])
            iso_warm = max(self._iso_peak[1], c["warm_isolate"])
            res = SimResult(
                model=f"live-{self.adapter.kind}",
                latencies=list(self._latencies),
                overheads=list(self._overheads),
                mem_samples=list(self._mem),
                pool_mem_samples=list(self._pool),
                runtime_count_samples=list(self._counts),
                cold_runtime_starts=c["cold_runtime"],
                cold_isolate_starts=iso_cold,
                warm_isolate_starts=iso_warm,
                evicted_runtimes=c["evicted_runtimes"],
                dropped=sum(self._drops.values()),
                pool_claims=c["pool_claims"],
                transfers=c["transfers"],
                peak_pool_mem=self._peak_pool,
                n_nodes=n_nodes,
            )
        return res

    def request_overhead_ms(self) -> dict:
        """Per-request overhead (latency − emulated duration) in WALL
        milliseconds — the request-path cost the gateway itself adds:
        registry lookup, slab claim, dispatch, release. Overheads are
        recorded in trace seconds, so wall ms = trace_s / compress × 1e3.
        This is the number the overhead budget gates on
        (``benchmarks/bench_hotpath.py`` measures the same path without a
        trace)."""
        with self._lock:
            ovh = sorted(self._overheads)
        n = len(ovh)
        if n == 0:
            return {"count": 0, "mean": None, "p99": None}
        to_ms = 1e3 / self.compress
        p99 = ovh[min(n - 1, int(round(0.99 * (n - 1))))]
        return {"count": n,
                "mean": (sum(ovh) / n) * to_ms,
                "p99": p99 * to_ms}

    def extras(self) -> dict:
        overhead = self.request_overhead_ms()
        exe = self.adapter.exe_stats()
        slab = self.adapter.slab_counts()
        with self._lock:
            out = {"drops": dict(self._drops),
                   "retries": self._retries,
                   "sample_failures": self._sample_failures,
                   "errors": list(self._errors),
                   "request_overhead_ms": overhead,
                   "exe_cache": exe,
                   "slab": slab}
        if self.tracer is not None:
            out["tracing"] = self.tracer.summary()
        return out
