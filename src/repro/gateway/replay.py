"""One-call orchestration: trace + live stack -> SimResult.

``replay_trace`` wires the pieces — target adapter, trace workload,
recorder (sampler thread + calibration probe), gateway workers,
optional platform autoscaler or cluster balancer, open-loop load
generator — runs the replay, drains, and returns ``(SimResult,
extras)``. ``extras["probe"]`` carries the ``CalibrationProbe`` payload
that ``core.calibrate.calibration_from_replay`` turns into a
``SimParams`` overlay (the gateway -> calibration -> sim round trip);
cluster replays additionally report mid-burst migration accounting
(``migrations``/``transfer_s``/``transfer_bytes``) for the live-vs-sim
diff.

The caller owns the target's lifecycle: build the
runtime/platform/cluster, replay, then ``target.shutdown()``. That
keeps replays composable (e.g. two traces back-to-back against one
warm platform to measure the warm-path delta).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

from repro.core.tracing import FlightRecorder, Tracer
from repro.gateway.gateway import (Autoscaler, ClusterBalancer, Gateway,
                                   GatewayParams)
from repro.gateway.loadgen import LoadGenerator, ShardedLoadGenerator
from repro.gateway.recorder import CalibrationProbe, Recorder
from repro.gateway.targets import DEFAULT_RUNTIME_BASE, wrap_target
from repro.gateway.workload import TraceWorkload


@dataclass
class ReplayConfig:
    compress: float = 60.0             # trace seconds per wall second
    mem_scale: float = 1.0 / 64        # trace bytes -> live arena bytes
    n_workers: int = 16
    queue_depth: int = 256
    slo_timeout_s: Optional[float] = None   # trace seconds; None disables
    tenant_rate: Optional[float] = None     # trace req/s; None disables
    tenant_burst: float = 16.0
    sample_dt_s: float = 0.25          # wall seconds between fleet samples
    shards: int = 1                    # tenant-sharded load-gen threads
                                       # (high --compress; 1 = single loop)
    autoscale: bool = True             # platform targets only
    pool_min: int = 1
    pool_max: int = 8
    cover_s: float = 1.0               # wall seconds one warm pool absorbs
    runtime_base_bytes: int = DEFAULT_RUNTIME_BASE
    drain_timeout_s: float = 120.0     # wall seconds
    probe: bool = True                 # record the calibration payload
    warm_executables: bool = True      # AOT-compile before the clock starts
    # cluster targets only: burst-time migration/rebalance in the loop
    balance: bool = True
    balance_interval_s: float = 0.25   # wall seconds between balance ticks
    balance_imbalance: float = 0.25    # commit spread / node budget trigger
    balance_min_queue: int = 1         # queued requests = live-burst signal
    balance_max_moves: int = 4         # migrations per rebalance() call
    # request tracing (core/tracing): 0.0 = off (the gateway carries the
    # zero-cost NULL_TRACE); >0 head-samples that fraction of admitted
    # requests deterministically under trace_seed
    trace_sample: float = 0.0
    trace_seed: int = 0
    trace_max: int = 4096              # bounded export window (traces kept)
    flight_dir: Optional[str] = None   # anomaly flight-recorder output dir
    flight_ring: int = 256             # last-N traces dumped per anomaly


def _budget_of(adapter) -> Optional[int]:
    """The per-runtime byte budget of the adapted stack, used to cap the
    emulated workload's arenas so registration always admits."""
    t = adapter.target
    if adapter.kind == "platform":
        return t.params.runtime_budget_bytes
    if adapter.kind == "cluster":
        return t.params.platform.runtime_budget_bytes
    if adapter.kind == "runtime":
        return t.budget.capacity
    return None


def build_workload(adapter, cfg: ReplayConfig) -> TraceWorkload:
    wl = TraceWorkload(mem_scale=cfg.mem_scale)
    budget = _budget_of(adapter)
    if budget is not None:
        # a function's placement estimate is ~2 arenas + O(1 KB); keep
        # even the biggest trace function admissible on one runtime
        cap = max(64 * 1024, (budget - 8 * 1024) // 2)
        wl.max_arena_bytes = cap
        wl.min_arena_bytes = min(wl.min_arena_bytes, cap)
    return wl


def warm_executables(adapter, workload, trace) -> int:
    """AOT-compile the workload's shared executable into the target's
    executable cache(s) before the replay clock starts.

    The paper's platform compiles at deploy time (Native Image analog),
    and the sim's ``fn_register_s`` models a code *install* from the
    shared cache — so the one-time XLA compile of the emulated program
    must not land on the first request of the measured window, where it
    would masquerade as seconds of trace-time cold start and poison both
    the latency gates and the derived calibration. A scratch runtime
    sharing each cache registers one representative spec through the
    real path (same cache key), then shuts down; its budget is sized
    from the spec's own registration reserve so a big-arena workload
    cannot OOM the warm-up. Warming is best-effort — a failure means
    the first request pays the compile (pre-warm behaviour), never an
    aborted replay. Returns the number of caches warmed."""
    from repro.core.runtime import HydraRuntime, registration_budget

    inv = next(iter(trace), None)
    if inv is None:
        return 0
    spec = workload.spec_for(inv.fid, inv.mem_bytes)
    budget = max(64 * (1 << 20), 2 * registration_budget(spec)[0])
    warmed = 0
    for cache in adapter.exe_caches():
        if cache is None:
            continue
        try:
            rt = HydraRuntime(memory_budget_bytes=budget,
                              executable_cache=cache, n_workers=1,
                              janitor=False)
            try:
                rt.register_function("__warm__", spec, tenant="__warm__")
            finally:
                rt.shutdown()
            warmed += 1
        except Exception:
            continue
    return warmed


def replay_trace(trace, target, cfg: Optional[ReplayConfig] = None,
                 tracer: Optional[Tracer] = None):
    """Replay ``trace`` open-loop against ``target`` (a ``HydraRuntime``,
    ``HydraPlatform``, or ``HydraCluster``). Returns ``(SimResult,
    extras)`` — the result in the simulator's schema, plus live-only
    detail (drop reasons, invoke errors, load-generator lag, wall
    time). Pass ``tracer`` (or set ``cfg.trace_sample``/``flight_dir``)
    to span-trace sampled requests; the caller keeps the tracer for
    Chrome export, and ``extras["tracing"]`` carries the per-phase
    aggregate either way."""
    cfg = cfg or ReplayConfig()
    adapter = wrap_target(target, cfg.runtime_base_bytes)
    workload = build_workload(adapter, cfg)
    n_registered = workload.register_all(trace, adapter)
    if cfg.warm_executables:
        warm_executables(adapter, workload, trace)

    if tracer is None and (cfg.trace_sample > 0 or cfg.flight_dir):
        flight = FlightRecorder(cfg.flight_dir, ring=cfg.flight_ring) \
            if cfg.flight_dir else None
        tracer = Tracer(cfg.trace_sample if cfg.trace_sample > 0 else 1.0,
                        seed=cfg.trace_seed, max_traces=cfg.trace_max,
                        flight=flight)
    if tracer is not None:
        # flight dumps embed a fleet snapshot taken at anomaly time
        tracer.set_metrics_provider(
            lambda: {"fleet": adapter.sample(),
                     "counters": adapter.counters()})

    probe = CalibrationProbe(adapter, compress=cfg.compress,
                             tracer=tracer) \
        if cfg.probe else None
    recorder = Recorder(adapter, compress=cfg.compress,
                        sample_dt_s=cfg.sample_dt_s, probe=probe,
                        tracer=tracer)
    autoscaler = balancer = None
    if cfg.autoscale and adapter.kind == "platform":
        autoscaler = Autoscaler(target, pool_min=cfg.pool_min,
                                pool_max=cfg.pool_max, cover_s=cfg.cover_s)
    gw = Gateway(adapter, workload,
                 GatewayParams(n_workers=cfg.n_workers,
                               queue_depth=cfg.queue_depth,
                               slo_timeout_s=cfg.slo_timeout_s,
                               tenant_rate=cfg.tenant_rate,
                               tenant_burst=cfg.tenant_burst,
                               compress=cfg.compress),
                 recorder, autoscaler=autoscaler, tracer=tracer)
    if cfg.balance and adapter.kind == "cluster":
        balancer = ClusterBalancer(target, gw,
                                   interval_s=cfg.balance_interval_s,
                                   imbalance=cfg.balance_imbalance,
                                   min_queue=cfg.balance_min_queue,
                                   max_moves=cfg.balance_max_moves)

    t0 = time.monotonic()
    recorder.start(t0)
    gw.start()
    if autoscaler is not None:
        autoscaler.start()
    if balancer is not None:
        balancer.start()
    try:
        gen = ShardedLoadGenerator(trace, gw, cfg.compress,
                                   n_shards=cfg.shards) \
            if cfg.shards > 1 else LoadGenerator(trace, gw, cfg.compress)
        load = gen.run(t0)
        drained = gw.drain(timeout_s=cfg.drain_timeout_s)
    finally:
        gw.stop()
        if autoscaler is not None:
            autoscaler.stop()
        if balancer is not None:
            balancer.stop()
        recorder.stop()

    res = recorder.finish()        # n_nodes from the adapter's real count
    extras = {
        **recorder.extras(),
        "registered": n_registered,
        "submitted": load.submitted,
        "accepted": load.accepted,
        "late_arrivals": load.late,
        "max_lag_s": load.max_lag_s,
        "wall_s": time.monotonic() - t0,
        "drained": drained,
        "autoscaler_resizes": autoscaler.resizes if autoscaler else 0,
    }
    if probe is not None:
        extras["probe"] = probe.finish()
    if adapter.kind == "cluster":
        # mid-burst migration accounting, diffable against the sim's
        # hydra-cluster transfer modelling (SimResult.transfers)
        cm = target.metrics
        extras["balancer"] = {
            "armed": balancer.armed if balancer else False,
            "ticks": balancer.ticks if balancer else 0,
            "rebalances": balancer.rebalances if balancer else 0,
            "moves": balancer.moves if balancer else 0,
            "errors": balancer.errors if balancer else 0,
            "migrations": cm.counters.get("migrations", 0),
            "transfer_s": cm.hist("transfer_s").sum,
            "transfer_bytes": cm.counters.get("transfer_bytes", 0),
            # HL004: the cluster's own accounting of the same activity the
            # balancer counts above — drift between the two pairs is a
            # replay diagnostic, and the adaptive-pool resizes are the
            # cluster analog of autoscaler_resizes
            "rebalance_calls": cm.counters.get("rebalance.calls", 0),
            "rebalance_moves": cm.counters.get("rebalance.moves", 0),
            "pool_resizes": cm.counters.get("pool.resize", 0),
        }
    return res, extras
