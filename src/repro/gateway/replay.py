"""One-call orchestration: trace + live stack -> SimResult.

``replay_trace`` wires the pieces — target adapter, trace workload,
recorder (sampler thread), gateway workers, optional platform
autoscaler, open-loop load generator — runs the replay, drains, and
returns ``(SimResult, extras)``.

The caller owns the target's lifecycle: build the
runtime/platform/cluster, replay, then ``target.shutdown()``. That
keeps replays composable (e.g. two traces back-to-back against one
warm platform to measure the warm-path delta).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

from repro.gateway.gateway import Autoscaler, Gateway, GatewayParams
from repro.gateway.loadgen import LoadGenerator
from repro.gateway.recorder import Recorder
from repro.gateway.targets import DEFAULT_RUNTIME_BASE, wrap_target
from repro.gateway.workload import TraceWorkload


@dataclass
class ReplayConfig:
    compress: float = 60.0             # trace seconds per wall second
    mem_scale: float = 1.0 / 64        # trace bytes -> live arena bytes
    n_workers: int = 16
    queue_depth: int = 256
    slo_timeout_s: Optional[float] = None   # trace seconds; None disables
    tenant_rate: Optional[float] = None     # trace req/s; None disables
    tenant_burst: float = 16.0
    sample_dt_s: float = 0.25          # wall seconds between fleet samples
    autoscale: bool = True             # platform targets only
    pool_min: int = 1
    pool_max: int = 8
    cover_s: float = 1.0               # wall seconds one warm pool absorbs
    runtime_base_bytes: int = DEFAULT_RUNTIME_BASE
    drain_timeout_s: float = 120.0     # wall seconds


def _budget_of(adapter) -> Optional[int]:
    """The per-runtime byte budget of the adapted stack, used to cap the
    emulated workload's arenas so registration always admits."""
    t = adapter.target
    if adapter.kind == "platform":
        return t.params.runtime_budget_bytes
    if adapter.kind == "cluster":
        return t.params.platform.runtime_budget_bytes
    if adapter.kind == "runtime":
        return t.budget.capacity
    return None


def build_workload(adapter, cfg: ReplayConfig) -> TraceWorkload:
    wl = TraceWorkload(mem_scale=cfg.mem_scale)
    budget = _budget_of(adapter)
    if budget is not None:
        # a function's placement estimate is ~2 arenas + O(1 KB); keep
        # even the biggest trace function admissible on one runtime
        cap = max(64 * 1024, (budget - 8 * 1024) // 2)
        wl.max_arena_bytes = cap
        wl.min_arena_bytes = min(wl.min_arena_bytes, cap)
    return wl


def replay_trace(trace, target, cfg: Optional[ReplayConfig] = None):
    """Replay ``trace`` open-loop against ``target`` (a ``HydraRuntime``,
    ``HydraPlatform``, or ``HydraCluster``). Returns ``(SimResult,
    extras)`` — the result in the simulator's schema, plus live-only
    detail (drop reasons, invoke errors, load-generator lag, wall
    time)."""
    cfg = cfg or ReplayConfig()
    adapter = wrap_target(target, cfg.runtime_base_bytes)
    workload = build_workload(adapter, cfg)
    n_registered = workload.register_all(trace, adapter)

    recorder = Recorder(adapter, compress=cfg.compress,
                        sample_dt_s=cfg.sample_dt_s)
    autoscaler = None
    if cfg.autoscale and adapter.kind == "platform":
        autoscaler = Autoscaler(target, pool_min=cfg.pool_min,
                                pool_max=cfg.pool_max, cover_s=cfg.cover_s)
    gw = Gateway(adapter, workload,
                 GatewayParams(n_workers=cfg.n_workers,
                               queue_depth=cfg.queue_depth,
                               slo_timeout_s=cfg.slo_timeout_s,
                               tenant_rate=cfg.tenant_rate,
                               tenant_burst=cfg.tenant_burst,
                               compress=cfg.compress),
                 recorder, autoscaler=autoscaler)

    t0 = time.monotonic()
    recorder.start(t0)
    gw.start()
    if autoscaler is not None:
        autoscaler.start()
    try:
        load = LoadGenerator(trace, gw, cfg.compress).run(t0)
        drained = gw.drain(timeout_s=cfg.drain_timeout_s)
    finally:
        gw.stop()
        if autoscaler is not None:
            autoscaler.stop()
        recorder.stop()

    n_nodes = len(target.nodes) if adapter.kind == "cluster" else 1
    res = recorder.finish(n_nodes=n_nodes)
    extras = {
        **recorder.extras(),
        "registered": n_registered,
        "submitted": load.submitted,
        "accepted": load.accepted,
        "late_arrivals": load.late,
        "max_lag_s": load.max_lag_s,
        "wall_s": time.monotonic() - t0,
        "drained": drained,
        "autoscaler_resizes": autoscaler.resizes if autoscaler else 0,
    }
    return res, extras
