"""Trace → live functions: materialize a ``Trace``'s integer fids as
real registered functions on a runtime/platform/cluster.

The simulator replays abstract invocations; the live gateway needs each
trace function to exist on the real stack — registered, AOT-compiled,
placeable, snapshotable. Every trace fid becomes a tiny ``CallableSpec``
(one jitted affine program, identical shapes for all functions, so the
whole workload shares ONE compiled executable through the fleet
``ExecutableCache`` — code-cache sharing exactly as the paper's
same-language tenants do) with per-function weights and a per-function
arena sized from the trace's memory column.

Trace memory is scaled by ``mem_scale`` (default 1/64) so a dataset
whose functions average ~140 MB replays on CI hardware: a 128 MB trace
function becomes a 2 MB arena. Scale the runtime/node budgets by the
same factor to preserve the sim's packing ratios
(``scaled_runtime_budget`` does this) — the *shape* of placement,
pool churn, and cold starts is preserved while absolute bytes shrink.

The invocation's *duration* is emulated by the gateway worker (which
sleeps ``duration_s / compress`` after the real invoke), not here: a
jitted program cannot sleep, and the real code path — registry lookup,
arena acquire, executable call — is exactly what we want measured.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.registry import CallableSpec

MB = 1 << 20
VEC = 64                      # element count of the emulated program


def _affine(params, args):
    return {"y": args["x"] * params["w"] + params["b"]}


@dataclass
class TraceWorkload:
    """Registered live twins of a trace's functions.

    ``fid_name``/``tenant_name`` define the stable naming scheme
    (``fn00017`` / ``tenant0003``); ``register_all`` admits every
    function appearing in the trace (placement stays lazy — the first
    live invocation claims/packs a runtime, which is the cold-start
    path under measurement); ``args_for`` builds the invocation payload.
    """
    mem_scale: float = 1.0 / 64
    min_arena_bytes: int = 256 * 1024
    # cap so even the biggest trace function stays admissible on one
    # runtime (a function's placement estimate is ~2 arenas); None = no cap
    max_arena_bytes: Optional[int] = None
    registered: dict = field(default_factory=dict)   # fid -> (name, tenant)

    @staticmethod
    def fid_name(fid: int) -> str:
        return f"fn{fid:05d}"

    @staticmethod
    def tenant_name(tenant: int) -> str:
        return f"tenant{tenant:04d}"

    def arena_bytes(self, mem_bytes: int) -> int:
        nb = max(self.min_arena_bytes, int(mem_bytes * self.mem_scale))
        if self.max_arena_bytes is not None:
            nb = min(nb, self.max_arena_bytes)
        return nb

    def spec_for(self, fid: int, mem_bytes: int) -> CallableSpec:
        # one program name + identical shapes for every function: the
        # executable compiles once and is shared fleet-wide; weights
        # differ per function (they are arguments, not closed over)
        w = jnp.full((VEC,), 1.0 + (fid % 13) * 0.5, jnp.float32)
        b = jnp.full((VEC,), float(fid % 7), jnp.float32)
        return CallableSpec(name="trace-emulated", fn=_affine,
                            example_args={"x": jnp.ones((VEC,), jnp.float32)},
                            params={"w": w, "b": b},
                            arena_bytes=self.arena_bytes(mem_bytes))

    def register_all(self, trace, adapter) -> int:
        """Register every distinct function in ``trace`` on the adapted
        target. Returns the number of functions registered. A trace that
        publishes its workload directly (``StreamingTrace.functions()``)
        registers from that metadata without expanding one invocation."""
        fns = getattr(trace, "functions", None)
        if callable(fns):
            seen = {f.fid: (f.tenant, f.mem_bytes) for f in fns()}
        else:
            seen = {}
            for inv in trace:
                if inv.fid not in seen:
                    seen[inv.fid] = (inv.tenant, inv.mem_bytes)
        n = 0
        for fid, (tenant, mem_bytes) in sorted(seen.items()):
            name = self.fid_name(fid)
            tenant = self.tenant_name(tenant)
            adapter.register(name, self.spec_for(fid, mem_bytes),
                             tenant=tenant)
            self.registered[fid] = (name, tenant)
            n += 1
        return n

    def args_for(self, inv) -> dict:
        # host-side payload, like a real request body arriving over the
        # wire: the compiled executable device_puts it on call. An eager
        # jnp.full here would dispatch a traced op per request (~0.3 ms
        # of pure overhead, GIL-serialized across gateway workers) and
        # throttle high-compression replays far below the open-loop rate
        return {"x": np.full((VEC,), float(inv.fid % 11), np.float32)}

    def name_for(self, inv):
        entry = self.registered.get(inv.fid)
        return entry[0] if entry else None


def scaled_runtime_budget(sim_runtime_cap: int,
                          mem_scale: float = 1.0 / 64,
                          floor_bytes: int = 4 * MB) -> int:
    """Map a simulator per-runtime cap onto a live runtime budget at the
    workload's memory scale, so live packing saturates at the same
    functions-per-runtime ratio the sim models."""
    return max(floor_bytes, int(sim_runtime_cap * mem_scale))
