"""Target adapters: one duck-typed surface over the three live serving
stacks the gateway can replay against.

The gateway needs four things from whatever it fronts — invoke a
function, sample fleet memory/runtime counts, read platform counters,
and shut down. A raw ``HydraRuntime``, a single-node ``HydraPlatform``,
and a multi-node ``HydraCluster`` expose those through different
objects; the adapters normalize them so ``Gateway``/``Recorder`` never
branch on the stack kind (mirroring how the sim engine never branches
on a model name). Arrival-rate estimation needs no hook here: a
cluster feeds its per-node estimators inside ``HydraCluster.invoke``,
and a bare platform's pool is driven by the gateway's ``Autoscaler``.

Memory accounting mirrors the simulator's: live bytes are the stack's
own byte-accurate budget accounting, plus ``runtime_base_bytes`` of RSS
per live runtime (the sim's ``hydra_runtime_base``), plus the same base
for every pre-warmed pool slot — so a live replay and a sim replay of
the same trace report comparable ``mean_mem``/``ops_per_gb_s``.
"""
from __future__ import annotations

from typing import Optional

from repro.core.cluster import HydraCluster
from repro.core.platform import HydraPlatform
from repro.core.runtime import HydraRuntime

MB = 1 << 20
# per-runtime RSS estimate used for live memory accounting; matches the
# sim's SimParams.hydra_runtime_base (paper Fig 5)
DEFAULT_RUNTIME_BASE = 46 * MB


class TargetAdapter:
    """Common surface; see module docstring. ``kind`` names the stack."""

    kind = ""

    def __init__(self, target, runtime_base_bytes: int = DEFAULT_RUNTIME_BASE):
        self.target = target
        self.runtime_base = runtime_base_bytes

    # -- request path ------------------------------------------------------
    def invoke(self, fid: str, args, ctx=None):
        # ctx: the request's RequestTrace (or None/NULL_TRACE); every
        # stack's invoke threads it down to the arena claim
        return self.target.invoke(fid, args, ctx=ctx)

    def register(self, fid: str, spec, *, tenant: str,
                 mem_budget: Optional[int] = None) -> bool:
        return self.target.register_function(fid, spec, tenant=tenant,
                                             mem_budget=mem_budget)

    # -- accounting --------------------------------------------------------
    def _runtimes(self) -> list:
        return []

    @property
    def n_nodes(self) -> int:
        """Real machine count of the adapted stack. ``Recorder.finish``
        stamps this on the live ``SimResult`` so fleet-wide metrics are
        never read as single-node by accident (a cluster replay reported
        as one node would look N-fold denser than the sim's fleet-wide
        accounting)."""
        return 1

    def node_mem(self) -> list:
        """Per-node committed bytes: the ``node_mem_bytes`` series of
        one fresh ``sample()`` (callers already holding a sample should
        read the key directly, as the CalibrationProbe does)."""
        return self.sample()["node_mem_bytes"]

    def platform_metrics(self) -> list:
        """Platform-level ``Metrics`` objects (boot/claim/restore
        timings live here), one per node; empty for a raw runtime."""
        return []

    def exe_caches(self) -> list:
        """Every distinct ``ExecutableCache`` the stack compiles into
        (one fleet-shared cache normally; per-node caches when a
        cluster opted out of sharing). The replay warms the workload's
        shared executable through these before the clock starts: the
        paper's platform AOT-compiles at deploy time, so a first-request
        XLA compile would be measurement noise, not a modeled cost."""
        return [self.target.exe_cache]

    def runtime_metrics(self) -> list:
        """Per-runtime ``Metrics`` objects (code-install timings)."""
        return [rt.metrics for rt in self._runtimes()]

    def exe_stats(self) -> dict:
        """Fleet compile counters summed over ``exe_caches()``:
        ``compiles`` (real XLA runs), ``disk_hits`` (serialized
        executables loaded), ``cache_hits`` (in-process entry reuse),
        ``entries``, and whether jax's persistent compilation cache is
        active. A warm fleet should show compiles == 0 after boot."""
        out = {"compiles": 0, "disk_hits": 0, "cache_hits": 0,
               "entries": 0, "total_compile_s": 0.0,
               "xla_cache_enabled": False}
        for cache in self.exe_caches():
            if cache is None:
                continue
            s = cache.stats()
            out["compiles"] += s["compiles"]
            out["disk_hits"] += s["disk_hits"]
            out["cache_hits"] += s["hits"]
            out["entries"] += s["entries"]
            out["total_compile_s"] += s["total_compile_s"]
            out["xla_cache_enabled"] |= bool(s.get("xla_cache_enabled"))
        return out

    def sample(self) -> dict:
        """Point-in-time fleet sample: mem/pool bytes + runtime count,
        plus the per-node ``node_mem_bytes`` series (one stats pass
        covers both — the recorder grid and the CalibrationProbe share
        a single sample per tick)."""
        raise NotImplementedError

    def counters(self) -> dict:
        """Platform-level counters mapped onto the SimResult vocabulary:
        ``cold_runtime`` (request-path boots), ``pool_claims``,
        ``evicted_runtimes``, ``transfers``, plus summed per-runtime
        isolate counters ``cold_isolate``/``warm_isolate``."""
        raise NotImplementedError

    def _isolate_counts(self) -> tuple:
        cold = warm = 0
        for rt in self._runtimes():
            c = rt.metrics.counters
            cold += c.get("arena.cold", 0)
            warm += c.get("arena.warm", 0)
        return cold, warm

    def slab_counts(self) -> dict:
        """Warm-claim breakdown summed fleet-wide: ``arena.reuse``
        (donated slab handed back to its owner untouched) vs
        ``arena.zeroed`` (cross-owner handover scrubbed on-device by the
        jitted fill). Their sum tracks ``warm_isolate``; the ratio says
        how often colocation actually pays."""
        reuse = zeroed = 0
        for rt in self._runtimes():
            c = rt.metrics.counters
            reuse += c.get("arena.reuse", 0)
            zeroed += c.get("arena.zeroed", 0)
        return {"reuse": reuse, "zeroed": zeroed}

    def shutdown(self) -> None:
        self.target.shutdown()


class RuntimeTarget(TargetAdapter):
    """One raw ``HydraRuntime``: no pool, no platform cold starts — the
    single-process baseline."""

    kind = "runtime"

    def _runtimes(self) -> list:
        return [self.target]

    def sample(self) -> dict:
        rt: HydraRuntime = self.target
        mem = rt.budget.used + self.runtime_base
        return {"mem_bytes": mem, "pool_bytes": 0, "runtimes": 1,
                "node_mem_bytes": [mem]}

    def counters(self) -> dict:
        cold_iso, warm_iso = self._isolate_counts()
        return {"cold_runtime": 0, "pool_claims": 0,
                "evicted_runtimes": 0, "transfers": 0,
                "cold_isolate": cold_iso, "warm_isolate": warm_iso}


class PlatformTarget(TargetAdapter):
    """A single-node ``HydraPlatform``: ``pool.miss`` is the live analog
    of the sim's request-path runtime cold start (the pool was dry and a
    runtime booted inline); ``pool.claim`` is a warm pool handover."""

    kind = "platform"

    def _runtimes(self) -> list:
        return self.target.runtimes()

    def platform_metrics(self) -> list:
        return [self.target.metrics]

    def sample(self) -> dict:
        plat: HydraPlatform = self.target
        s = plat.stats()
        total = s["runtimes_active"] + s["runtimes_pooled"]
        mem = s["budget_used"] + total * self.runtime_base
        return {"mem_bytes": mem,
                "pool_bytes": s["runtimes_pooled"] * self.runtime_base,
                "runtimes": total, "node_mem_bytes": [mem]}

    def counters(self) -> dict:
        c = self.target.metrics.counters
        cold_iso, warm_iso = self._isolate_counts()
        return {"cold_runtime": c.get("pool.miss", 0),
                "pool_claims": c.get("pool.claim", 0),
                "evicted_runtimes": c.get("runtime.shutdowns", 0),
                "transfers": 0,
                "cold_isolate": cold_iso, "warm_isolate": warm_iso}


class ClusterTarget(TargetAdapter):
    """A multi-node ``HydraCluster``: per-node platform counters are
    summed fleet-wide; arrivals feed the cluster's own per-node adaptive
    pool sizing (so no gateway Autoscaler is attached)."""

    kind = "cluster"

    def _platforms(self) -> list:
        return [node.platform for node in self.target.nodes]

    def _runtimes(self) -> list:
        return [rt for p in self._platforms() for rt in p.runtimes()]

    @property
    def n_nodes(self) -> int:
        return len(self.target.nodes)

    def platform_metrics(self) -> list:
        return [p.metrics for p in self._platforms()]

    def exe_caches(self) -> list:
        if self.target.exe_cache is not None:     # fleet-shared cache
            return [self.target.exe_cache]
        return [p.exe_cache for p in self._platforms()]

    def sample(self) -> dict:
        per_node = []
        pool = runtimes = 0
        for p in self._platforms():
            s = p.stats()
            total = s["runtimes_active"] + s["runtimes_pooled"]
            per_node.append(s["budget_used"] + total * self.runtime_base)
            pool += s["runtimes_pooled"] * self.runtime_base
            runtimes += total
        return {"mem_bytes": sum(per_node), "pool_bytes": pool,
                "runtimes": runtimes, "node_mem_bytes": per_node}

    def counters(self) -> dict:
        cold = claims = evicted = 0
        for p in self._platforms():
            c = p.metrics.counters
            cold += c.get("pool.miss", 0)
            claims += c.get("pool.claim", 0)
            evicted += c.get("runtime.shutdowns", 0)
        cold_iso, warm_iso = self._isolate_counts()
        cluster: HydraCluster = self.target
        return {"cold_runtime": cold, "pool_claims": claims,
                "evicted_runtimes": evicted,
                "transfers": cluster.metrics.counters.get("migrations", 0),
                "cold_isolate": cold_iso, "warm_isolate": warm_iso}


def wrap_target(target, runtime_base_bytes: int = DEFAULT_RUNTIME_BASE
                ) -> TargetAdapter:
    """Adapter for a runtime/platform/cluster instance."""
    if isinstance(target, HydraCluster):
        return ClusterTarget(target, runtime_base_bytes)
    if isinstance(target, HydraPlatform):
        return PlatformTarget(target, runtime_base_bytes)
    if isinstance(target, HydraRuntime):
        return RuntimeTarget(target, runtime_base_bytes)
    raise TypeError(f"gateway cannot front {type(target).__name__}; "
                    "expected HydraRuntime, HydraPlatform, or HydraCluster")
