"""Sim-vs-real validation: replay ONE trace through both the live
gateway stack and the discrete-event simulator, and diff the results.

``core/calibrate.py`` closes the loop in one direction (measured costs
flow into the simulator's constants); this harness closes it in the
other: the simulator's *predictions* are checked against the real
``HydraPlatform`` under the identical (thinned) trace. Per-metric
deltas are reported for cold starts, pool claims, p50/p99, memory, and
density; the **cold-start count** is the enforced gate —

    |live_cold - sim_cold| <= atol + rtol * sim_cold

with ``atol=8``/``rtol=1.0`` by default (documented in
docs/benchmarks.md). The gate is deliberately coarse: live timing
jitters and the sim packs by per-invocation memory while the platform
packs by per-function estimate, so exact counts never match — but a
regression that defeats the warm pool (every request cold-booting)
blows past any sane tolerance, and that regression class is what CI's
``gateway-smoke`` job exists to catch. Latency deltas are reported, not
enforced: real startup costs do not compress with the replay clock, so
live trace-time percentiles carry a known ``compress``-amplified
startup term.

For comparability the live side runs with a FIXED pool (autoscaling
off) sized like the sim model's, no SLO timeout, and no tenant
throttling; the sim side gets ``keepalive_s`` stretched past the trace
horizon because a live platform never expires a placed function.

CLI::

    PYTHONPATH=src python -m repro.gateway.validate \\
        --trace-file benchmarks/data/azure_sample.csv \\
        --target-rps 2 --max-minutes 10 --compress 120
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import sys
from typing import Optional

from repro.core.platform import HydraPlatform, PlatformParams
from repro.core.sim import SimParams, simulate
from repro.core.traces import Trace, discover_azure_tables
from repro.gateway.replay import ReplayConfig, replay_trace

# enforced cold-start gate: |live - sim| <= COLD_ATOL + COLD_RTOL * sim
COLD_ATOL = 8
COLD_RTOL = 1.0

# per-metric deltas reported (summary-schema keys)
DELTA_KEYS = ("requests", "dropped", "cold_runtime", "pool_claims",
              "p50_s", "p99_s", "mean_mem_mb", "ops_per_gb_s")


def load_trace(trace_file: Optional[str] = None,
               target_rps: Optional[float] = None,
               max_minutes: Optional[int] = None,
               seed: int = 0, **synthetic_kw) -> Trace:
    """An Azure-format trace (sibling duration/memory tables
    auto-discovered) when ``trace_file`` is given, else the synthetic
    Shahrad-calibrated generator."""
    if trace_file:
        return Trace.from_azure(trace_file,
                                **discover_azure_tables(trace_file),
                                target_rps=target_rps,
                                max_minutes=max_minutes, seed=seed)
    kw = dict(n_functions=24, n_tenants=8, duration_s=120.0, mean_rps=3.0,
              seed=seed)
    kw.update(synthetic_kw)
    return Trace.synthetic(**kw)


def sim_params_for_live(trace, *, pool_size: int,
                        live_runtime_budget: int, mem_scale: float,
                        base: Optional[SimParams] = None) -> SimParams:
    """Map the live platform's configuration onto ``SimParams`` so the
    two replays model the same deployment: same pool target, the
    per-runtime cap un-scaled back to trace bytes, and keep-alive
    stretched past the horizon (a live platform never expires a placed
    function — only idle arenas TTL out)."""
    base = base or SimParams()
    return dataclasses.replace(
        base,
        pool_size=pool_size,
        runtime_cap=max(base.runtime_cap,
                        int(live_runtime_budget / mem_scale)),
        keepalive_s=max(base.keepalive_s, trace.duration_s + 120.0),
    )


def run_validation(trace, *, compress: float = 60.0, pool_size: int = 4,
                   mem_scale: float = 1.0 / 64,
                   runtime_budget: Optional[int] = None,
                   model: str = "hydra-pool",
                   atol: int = COLD_ATOL, rtol: float = COLD_RTOL,
                   n_workers: int = 8,
                   sim_base: Optional[SimParams] = None) -> dict:
    """Replay ``trace`` live and simulated; return the delta report."""
    base = sim_base or SimParams()
    live_budget = runtime_budget or max(
        4 << 20, int(base.runtime_cap * mem_scale))
    # isolate TTLs are trace-time semantics: compress them with the
    # replay clock, or idle arenas pin runtime budgets for the entire
    # compressed replay and every burst OOMs
    platform = HydraPlatform(PlatformParams(
        pool_size=pool_size, runtime_budget_bytes=live_budget,
        arena_ttl_s=base.isolate_ttl_s / compress, n_workers=4))
    cfg = ReplayConfig(compress=compress, mem_scale=mem_scale,
                       n_workers=n_workers, autoscale=False,
                       slo_timeout_s=None, tenant_rate=None)
    try:
        live, extras = replay_trace(trace, platform, cfg)
    finally:
        platform.shutdown()

    params = sim_params_for_live(trace, pool_size=pool_size,
                                 live_runtime_budget=live_budget,
                                 mem_scale=mem_scale, base=base)
    sim = simulate(trace, model, params)

    live_s, sim_s = live.summary(), sim.summary()
    deltas = {}
    for k in DELTA_KEYS:
        lv, sv = live_s.get(k), sim_s.get(k)
        deltas[k] = {"live": lv, "sim": sv,
                     "delta": (lv - sv)
                     if isinstance(lv, (int, float))
                     and isinstance(sv, (int, float)) else None}

    cold_live = live.cold_runtime_starts
    cold_sim = sim.cold_runtime_starts
    cold_limit = atol + rtol * cold_sim
    cold_delta = abs(cold_live - cold_sim)

    failures = []
    if not live_s["requests"]:
        failures.append("live replay served zero requests")
    if not sim_s["requests"]:
        failures.append("sim replay served zero requests")
    for side, s in (("live", live_s), ("sim", sim_s)):
        for k in ("p50_s", "p99_s", "mean_mem_mb"):
            v = s.get(k)
            if v is None or not math.isfinite(v):
                failures.append(f"{side} {k} is not finite ({v})")
    if not extras.get("drained", True):
        failures.append("gateway did not drain before the timeout")
    err_n = extras.get("drops", {}).get("error", 0)
    if err_n > max(1, 0.01 * len(trace)):
        failures.append(f"{err_n} invoke errors (>1% of the trace): "
                        f"{extras.get('errors', [])[:3]}")
    if cold_delta > cold_limit:
        failures.append(
            f"cold-start divergence {cold_delta} beyond tolerance "
            f"{cold_limit:.1f} (live={cold_live}, sim={cold_sim}, "
            f"atol={atol}, rtol={rtol})")

    return {
        "trace": trace.describe(),
        "live": live_s, "sim": sim_s, "deltas": deltas,
        "extras": extras,
        "tolerance": {"atol": atol, "rtol": rtol, "limit": cold_limit,
                      "cold_live": cold_live, "cold_sim": cold_sim,
                      "cold_delta": cold_delta,
                      "passed": cold_delta <= cold_limit},
        "failures": failures,
        "ok": not failures,
    }


def format_report(report: dict) -> str:
    lines = [f"{'metric':>14s} {'live':>12s} {'sim':>12s} {'delta':>12s}"]
    for k, d in report["deltas"].items():
        def fmt(v):
            if v is None:
                return "-"
            return f"{v:.4f}" if isinstance(v, float) else str(v)
        lines.append(f"{k:>14s} {fmt(d['live']):>12s} {fmt(d['sim']):>12s} "
                     f"{fmt(d['delta']):>12s}")
    tol = report["tolerance"]
    lines.append(f"cold-start gate: |{tol['cold_live']} - {tol['cold_sim']}|"
                 f" = {tol['cold_delta']} <= {tol['limit']:.1f} -> "
                 f"{'PASS' if tol['passed'] else 'FAIL'}")
    for f in report["failures"]:
        lines.append(f"FAIL: {f}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Replay one trace through the live gateway stack AND "
                    "the simulator; report per-metric deltas and enforce "
                    "the cold-start tolerance.")
    ap.add_argument("--trace-file", default=None,
                    help="Azure Functions 2019-format invocations CSV "
                         "(default: a small synthetic trace)")
    ap.add_argument("--target-rps", type=float, default=None)
    ap.add_argument("--max-minutes", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compress", type=float, default=60.0,
                    help="trace seconds replayed per wall second")
    ap.add_argument("--pool", type=int, default=4,
                    help="pre-warmed pool size (live and sim)")
    ap.add_argument("--mem-scale", type=float, default=1.0 / 64)
    ap.add_argument("--model", default="hydra-pool")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--atol", type=int, default=COLD_ATOL)
    ap.add_argument("--rtol", type=float, default=COLD_RTOL)
    ap.add_argument("--json", action="store_true",
                    help="emit the full report as JSON")
    args = ap.parse_args(argv)

    trace = load_trace(args.trace_file, target_rps=args.target_rps,
                       max_minutes=args.max_minutes, seed=args.seed)
    d = trace.describe()
    print(f"[validate] trace: {d['invocations']} invocations, "
          f"{d['functions']} fns, {d['tenants']} tenants over "
          f"{d['duration_s']:.0f}s (compress {args.compress:g}x -> "
          f"~{d['duration_s'] / args.compress:.1f}s wall)")
    report = run_validation(trace, compress=args.compress,
                            pool_size=args.pool, mem_scale=args.mem_scale,
                            model=args.model, n_workers=args.workers,
                            atol=args.atol, rtol=args.rtol)
    print(format_report(report))
    if args.json:
        print(json.dumps(report, indent=1, sort_keys=True, default=str))
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
